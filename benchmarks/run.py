"""Benchmark harness — one function per paper figure/claim.

Paper: Figure 1 has two panels: (left) runtime vs resolution, serial vs
parallel; (right) runtime vs hyperedge count at fixed resolution. Claims:
  C1 serial runtime linear to ~2000^2, inflecting above; crossover exists
  C2 parallel wins 2x-10x at high resolution
  C3 runtime invariant to hyperedge count (147 -> 4.1M)

Hardware note: the paper compares a GeForce 310M (16 CUDA cores) against an
i5-480M. This container is a single CPU core: "parallel" here is the
data-parallel formulation (vectorized JAX / Pallas-interpret); "serial" is
the paper's scalar column walk (core/serial.py). The speedup numbers are
therefore formulation speedups, not device speedups; curve *shapes* and the
invariance claim are the reproduction targets (EXPERIMENTS.md §Paper-claims).

Output: ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import time

import numpy as np

import jax

from repro.core import serial, ychg
from repro.data import modis
from repro.engine import Engine, YCHGConfig, get_backend
from repro.kernels import ops as kops


def _t(fn, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median wall time (us) of fn(*args) with jax sync."""
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r) if hasattr(r, "block_until_ready") or isinstance(
            r, (jax.Array, tuple, dict)) else None
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        r = fn(*args)
        if isinstance(r, (jax.Array, tuple, dict)):
            jax.block_until_ready(r)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def bench_resolution_sweep() -> list[str]:
    """Figure 1 (left): runtime vs resolution, serial vs data-parallel."""
    rows = []
    jit_analyze = jax.jit(ychg.analyze)
    for res in (250, 500, 1000, 2000, 4000):
        img = modis.snowfield(res, seed=0)
        jimg = jax.device_put(img)
        t_par = _t(lambda x: jit_analyze(x).n_hyperedges, jimg)
        t_ser = _t(serial.analyze_numpy, img, reps=3)
        if res <= 500:
            t_scalar = _t(serial.analyze_scalar, img, reps=1, warmup=0)
            rows.append(f"ychg_scalar_res{res},{t_scalar:.1f},"
                        f"speedup_vs_parallel={t_scalar / t_par:.1f}x")
        rows.append(f"ychg_serial_res{res},{t_ser:.1f},")
        rows.append(f"ychg_parallel_res{res},{t_par:.1f},"
                    f"speedup={t_ser / t_par:.2f}x")
    return rows


def bench_hyperedge_sweep() -> list[str]:
    """Figure 1 (right): runtime vs hyperedge count at fixed resolution (C3)."""
    rows = []
    res = 2048
    jit_analyze = jax.jit(ychg.analyze)
    times = []
    for n in (147, 1_000, 10_000, 100_000, 1_000_000):
        img = jax.device_put(modis.striped(res, n))
        t = _t(lambda x: jit_analyze(x).n_hyperedges, img)
        times.append(t)
        rows.append(f"ychg_hyperedges_{n},{t:.1f},n_hyperedges={n}")
    spread = max(times) / min(times)
    rows.append(f"ychg_hyperedge_invariance,{np.mean(times):.1f},"
                f"max_over_min={spread:.3f}")
    return rows


def bench_kernel_colscan() -> list[str]:
    """Step-1 kernel (Pallas interpret on CPU) vs jnp production path."""
    rows = []
    img = modis.snowfield(1024, seed=1)
    jimg = jax.device_put(img)
    t_jnp = _t(lambda x: ychg.column_runs(x), jimg)
    t_pal = _t(lambda x: kops.colscan_runs(x), jimg)
    rows.append(f"kernel_colscan_jnp_1024,{t_jnp:.1f},")
    rows.append(f"kernel_colscan_pallas_interp_1024,{t_pal:.1f},"
                "note=interpret-mode-correctness-only")
    return rows


def bench_fused_batch_sweep() -> list[str]:
    """Fused single-launch batched kernel vs the two-pass Pallas pipeline vs
    pure jnp, over batch size x resolution (the paper's serial/parallel
    crossover, measured as a curve).

    Launch accounting (the fusion claim): the fused pipeline issues ONE
    pallas_call per batch; the two-pass pipeline issues two per image
    (step-1 colscan + step-2 diff after an HBM round-trip of the counts
    vector), i.e. 2*B per batch. The serial column walk (core/serial.py)
    anchors the crossover threshold.
    """
    rows = []
    eng_fused = Engine(YCHGConfig(backend="fused"))
    for res in (128, 256, 512):
        for bsz in (1, 8, 32):
            imgs = np.stack([modis.snowfield(res, seed=s) for s in range(bsz)])
            jimgs = jax.device_put(imgs)

            def two_pass(x):
                # tuple so _t's block_until_ready sees and syncs the results
                return tuple(kops.analyze(x[i])["n_hyperedges"] for i in range(bsz))

            t_fused = _t(lambda x: eng_fused.analyze_batch(x).n_hyperedges, jimgs)
            t_two = _t(two_pass, jimgs)
            t_jnp = _t(lambda x: ychg.analyze_jit(x).n_hyperedges, jimgs)
            t_ser = _t(
                lambda x: [serial.analyze_numpy(x[i]) for i in range(bsz)], imgs
            )
            rows.append(f"ychg_fused_b{bsz}_res{res},{t_fused:.1f},launches=1")
            rows.append(
                f"ychg_twopass_b{bsz}_res{res},{t_two:.1f},launches={2 * bsz}"
            )
            rows.append(
                f"ychg_jnp_b{bsz}_res{res},{t_jnp:.1f},"
                f"fused_vs_twopass={t_two / t_fused:.2f}x"
            )
            rows.append(
                f"ychg_serial_b{bsz}_res{res},{t_ser:.1f},"
                f"fused_vs_serial={t_ser / t_fused:.2f}x"
            )
    return rows


def bench_engine_dispatch() -> list[str]:
    """Per-call overhead of the Engine dispatch layer.

    The engine's acceptance bar is <= 5 us/call over invoking the backend
    callable directly. Real kernels jitter by tens of us per call in
    interpret mode, which swamps a few-us delta, so the overhead row is
    measured against a registered *null* backend (returns a precomputed
    summary): the engine-vs-direct difference is then pure dispatch —
    ingest + registry resolution + result wrapping. The fused/jax rows give
    the real-path per-call context the overhead sits on top of.
    """
    from repro.engine import registry

    def per_call_us(fn, calls: int, trials: int = 5) -> float:
        # total-over-calls, best of trials: per-call medians cannot resolve
        # a few-us delta
        fn(), fn()
        best = float("inf")
        for _ in range(trials):
            t0 = time.perf_counter()
            for _ in range(calls):
                r = fn()
            jax.block_until_ready(r)
            best = min(best, (time.perf_counter() - t0) / calls * 1e6)
        return best

    rows = []
    imgs = np.stack([modis.snowfield(64, seed=s) for s in range(4)])
    jimgs = jax.device_put(imgs)

    fixed = jax.block_until_ready(ychg.analyze_jit(jimgs))
    registry.register_backend(registry.BackendSpec(
        name="_bench_null", run=lambda x, c: fixed, supports_batch=True,
        supports_mesh=False, device_kinds=("cpu", "gpu", "tpu"),
    ))
    try:
        eng = Engine(YCHGConfig(backend="_bench_null"))
        direct, cfg = get_backend("_bench_null").run, eng.config
        t_direct = per_call_us(lambda: direct(jimgs, cfg).n_hyperedges,
                               calls=10000)
        t_engine = per_call_us(lambda: eng.analyze_batch(jimgs).n_hyperedges,
                               calls=10000)
    finally:
        # the stub must not outlive the bench: it would pollute
        # backend_names()/auto-resolution for everything after it in main()
        registry.unregister_backend("_bench_null")
    rows.append(f"engine_dispatch_overhead,{t_engine - t_direct:.2f},"
                f"null_backend_isolated_budget_us=5")

    for backend in ("fused", "jax"):
        beng = Engine(YCHGConfig(backend=backend))
        t_real = per_call_us(
            lambda: beng.analyze_batch(jimgs).n_hyperedges, calls=100)
        rows.append(f"engine_dispatch_engine_{backend},{t_real:.1f},"
                    f"real_path_context")
    return rows


def bench_kernel_packed() -> list[str]:
    """§Perf iteration on the paper's kernel: 1-bit row packing (8x less HBM
    traffic on the memory-bound scan). CPU wall time + the v5e roofline terms
    both reported; correctness asserted inline."""
    import jax.numpy as jnp

    from repro.core import ychg
    from repro.kernels.ychg_packed import pack_rows, packed_analyze

    rows = []
    res = 4096
    img = modis.snowfield(res, seed=2)
    jimg = jax.device_put(img)
    base = jax.jit(ychg.analyze)
    n_base = int(base(jimg).n_hyperedges)
    n_pack = int(packed_analyze(jimg)["n_hyperedges"])
    assert n_base == n_pack, (n_base, n_pack)
    packed = jax.block_until_ready(pack_rows(jimg))
    t_unpacked = _t(lambda x: base(x).n_hyperedges, jimg)
    t_packed_jit = _t(
        lambda x: packed_analyze(x)["n_hyperedges"], jimg
    )
    # v5e roofline (memory term dominates both): bytes / 819 GB/s
    hbm = 819e9
    t_roof_base = res * res / hbm
    t_roof_pack = res * res / 8 / hbm
    rows.append(f"ychg_kernel_baseline_4096,{t_unpacked:.1f},"
                f"v5e_mem_term_us={t_roof_base * 1e6:.1f}")
    rows.append(f"ychg_kernel_bitpacked_4096,{t_packed_jit:.1f},"
                f"v5e_mem_term_us={t_roof_pack * 1e6:.1f}_(8x_less_traffic)")
    return rows


def bench_lm_train_microstep() -> list[str]:
    """Tiny LM train step (the framework's hot loop on this box)."""
    import jax.numpy as jnp

    from repro.configs.base import ModelConfig
    from repro.models import init_params
    from repro.optim import adamw_init
    from repro.train.step import make_train_step

    cfg = ModelConfig(
        name="bench-tiny", family="dense", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
        param_dtype="float32", activation_dtype="float32", remat="none",
        attn_chunk=128,
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jax.device_put(rng.integers(0, 512, (8, 128)).astype(np.int32)),
        "labels": jax.device_put(rng.integers(0, 512, (8, 128)).astype(np.int32)),
    }
    t = _t(lambda p, o, b: step(p, o, b)[2]["loss"], params, opt, batch)
    toks = 8 * 128
    return [f"lm_train_microstep_1M,{t:.1f},tokens_per_s={toks / (t / 1e6):.0f}"]


def bench_serve_decode() -> list[str]:
    import jax.numpy as jnp

    from repro.configs.base import ModelConfig
    from repro.models import init_cache, init_params
    from repro.train.step import make_serve_step

    cfg = ModelConfig(
        name="bench-decode", family="dense", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
        param_dtype="float32", activation_dtype="float32", remat="none",
    )
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, 8, 256)
    step = jax.jit(make_serve_step(cfg))
    tok = jax.device_put(np.ones((8, 1), np.int32))
    t = _t(lambda: step(params, cache, tok, jnp.int32(5))[0])
    return [f"lm_serve_decode_b8,{t:.1f},tokens_per_s={8 / (t / 1e6):.0f}"]


def main() -> None:
    print("name,us_per_call,derived")
    for fn in (
        bench_resolution_sweep,
        bench_hyperedge_sweep,
        bench_kernel_colscan,
        bench_fused_batch_sweep,
        bench_engine_dispatch,
        bench_kernel_packed,
        bench_lm_train_microstep,
        bench_serve_decode,
    ):
        for row in fn():
            print(row, flush=True)


if __name__ == "__main__":
    main()
