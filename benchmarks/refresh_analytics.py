"""Recompute analytic flops/bytes + roofline terms in existing dry-run JSONs
(collectives stay as measured; no recompilation needed)."""
import glob, json, sys

from repro.configs import get_config
from repro.configs.base import ALL_SHAPES
from repro.launch import analytic, roofline
from repro.launch.dryrun import VARIANTS

SHAPES = {s.name: s for s in ALL_SHAPES}

def main(dirname="results/dryrun"):
    for p in sorted(glob.glob(dirname + "/*.json")):
        with open(p) as f:
            r = json.load(f)
        if not r.get("ok"):
            continue
        parts = p.split("/")[-1][:-5].split("__")
        variant = parts[3] if len(parts) > 3 else "base"
        cfg = VARIANTS[variant](get_config(r["arch"]))
        shape = SHAPES[r["shape"]]
        an = analytic.report(cfg, shape)
        coll_pp = r["collectives"].get("total", 0.0)
        r["analytic"] = an
        r["roofline"] = roofline.terms(
            flops_global=an["flops"], bytes_global=an["hbm_bytes"],
            coll_bytes_per_partition=coll_pp, n_partitions=r["chips"])
        r["model_flops"] = roofline.model_flops(cfg, shape)
        r["useful_compute_ratio"] = r["model_flops"] / an["flops"]
        r["dominant"] = roofline.dominant(r["roofline"])
        with open(p, "w") as f:
            json.dump(r, f, indent=1)
    print("refreshed")

if __name__ == "__main__":
    main(*sys.argv[1:])
