"""Multi-op platform benchmark: per-op serving + compound pipeline.

Two scenario families, written to ``BENCH_ops.json``:

  **{op}_serving** (one row per registered op: ychg, ccl, denoise) —
  N distinct inputs served through the HTTP front end with
  ``POST /v1/{op}``, every wire result compared bit for bit against the
  op's in-repo jnp reference (``OpSpec.reference``) — the same parity
  bar the tests hold every backend to, re-checked here on the numbers
  the bench is about to publish. The row records throughput and the
  ``bit_identical`` verdict (hard-asserted: a bench that serves wrong
  answers fast is not a result).

  **pipeline_vs_sequential** — the payoff row. The SAME pool of
  speckled float images pushed through ``denoise -> ychg`` two ways:
  (a) two wire requests per image, the host feeding stage 1's filtered
  image back in for stage 2 (today's compose-by-hand path), and (b) one
  ``POST /v1/pipeline`` compound request per image, the stages chained
  device-resident by the engine. Both arms are warmed on a DISJOINT
  image set (rungs compile outside timing; no timed input pre-cached)
  and every compound result is compared bit for bit against its
  sequential twin.

  **Honesty about cores**: the compound path saves a host round trip
  and a second scheduler pass, not CPU work — on a core-starved box the
  timings are noise-dominated. The row records ``cores``
  (``os.cpu_count()``); the ``>= 1.0x`` acceptance bar is asserted only
  when ``cores >= 4`` — smaller boxes record the measured ratio with a
  ``cpu_limited`` note instead of a fake pass or a guaranteed failure.

Run:  PYTHONPATH=src python benchmarks/bench_ops.py [--out BENCH_ops.json]
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np

import jax
import jax.numpy as jnp

from repro.data import modis
from repro.engine import Engine
from repro.engine.ops import get_op, op_names
from repro.frontend import ServerThread, YCHGClient
from repro.service import Service, ServiceConfig

RES = 64
MAX_BATCH = 8


def _mask_inputs(n: int, seed0: int) -> List[np.ndarray]:
    return [modis.snowfield(RES, seed=seed0 + i) for i in range(n)]


def _float_inputs(n: int, seed0: int) -> List[np.ndarray]:
    """Speckled smooth fields: the denoise stage has real outliers to
    strike and the filtered image still has structure for yCHG."""
    out = []
    for i in range(n):
        rng = np.random.default_rng(seed0 + i)
        yy, xx = np.mgrid[0:RES, 0:RES]
        img = np.maximum(
            0.0, 0.55 * np.sin(yy / 9.0) * np.cos(xx / 13.0) - 0.05
        ).astype(np.float32)
        spikes = rng.random(img.shape) < 0.02
        img[spikes] = rng.random(spikes.sum()).astype(np.float32) * 4.0
        out.append(img)
    return out


def _inputs(op: str, n: int, seed0: int) -> List[np.ndarray]:
    return (_float_inputs(n, seed0) if op == "denoise"
            else _mask_inputs(n, seed0))


def _host_equal(got: Dict[str, np.ndarray],
                want: Dict[str, np.ndarray]) -> bool:
    if set(got) != set(want):
        return False
    for field in want:
        a, b = np.asarray(want[field]), np.asarray(got[field])
        if not (np.array_equal(a, b) and a.dtype == b.dtype
                and a.shape == b.shape):
            return False
    return True


def run_op_serving(op: str, client: YCHGClient, n_requests: int) -> dict:
    spec = get_op(op)
    timed = _inputs(op, n_requests, seed0=3000)
    warm = _inputs(op, n_requests, seed0=9000)   # compiles only
    # the parity bar: single-request (batched=False) reference layout,
    # exactly what the wire hands back
    want = [spec.from_summary(spec.reference(jnp.asarray(x)[None]),
                              False).to_host()
            for x in timed]
    for x in warm:
        client.analyze(x, op=op)
    t0 = time.perf_counter()
    got = [client.analyze(x, op=op) for x in timed]
    dt = time.perf_counter() - t0
    bit_identical = all(_host_equal(g, w) for g, w in zip(got, want))
    assert bit_identical, f"{op}: wire results drifted from the reference"
    return {
        "scenario": f"{op}_serving",
        "op": op,
        "n_requests": n_requests,
        "resolutions": [RES],
        "rps": round(n_requests / dt, 1),
        "bit_identical": bit_identical,
    }


def run_pipeline_vs_sequential(client: YCHGClient, n_requests: int) -> dict:
    stages = ["denoise", "ychg"]
    timed = _float_inputs(n_requests, seed0=3000)
    warm = _float_inputs(n_requests, seed0=9000)
    cores = os.cpu_count() or 1

    def sequential(img: np.ndarray) -> Dict[str, np.ndarray]:
        filtered = client.analyze(img, op="denoise")
        return client.analyze(filtered["image"], op="ychg")

    # warm both arms (disjoint images: compiles land, no timed input cached)
    for img in warm:
        sequential(img)
        client.pipeline(img, stages)

    t0 = time.perf_counter()
    want = [sequential(img) for img in timed]
    sequential_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    got = [client.pipeline(img, stages) for img in timed]
    pipeline_s = time.perf_counter() - t0

    bit_identical = all(_host_equal(g, w) for g, w in zip(got, want))
    assert bit_identical, (
        "compound pipeline drifted from the stages issued sequentially")

    ratio = round((n_requests / pipeline_s) / (n_requests / sequential_s), 2)
    row = {
        "scenario": "pipeline_vs_sequential",
        "stages": stages,
        "n_requests": n_requests,
        "cores": cores,
        "resolutions": [RES],
        "sequential_rps": round(n_requests / sequential_s, 1),
        "pipeline_rps": round(n_requests / pipeline_s, 1),
        "pipeline_vs_sequential_ratio": ratio,
        "bit_identical": bit_identical,
    }
    if cores >= 4:
        assert ratio >= 1.0, (
            f"compound pipeline only {ratio}x the sequential arm on "
            f"{cores} cores (bar: 1x — it removes a host round trip, it "
            "must never be slower)")
    else:
        row["note"] = (
            f"cpu_limited: {cores} core(s) — timings noise-dominated, so "
            "the >= 1x bar is asserted only on >= 4 cores; ratio recorded "
            "as measured")
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_ops.json")
    ap.add_argument("--requests", type=int, default=24)
    args = ap.parse_args()

    cfg = ServiceConfig(bucket_sides=(RES,), max_batch=MAX_BATCH,
                        max_delay_ms=2.0)
    rows = []
    with Service(Engine(), cfg) as svc, ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        for op in sorted(op_names()):
            rows.append(run_op_serving(op, client, args.requests))
            print(json.dumps(rows[-1]), flush=True)
        rows.append(run_pipeline_vs_sequential(client, args.requests))
        print(json.dumps(rows[-1]), flush=True)

    report = {
        "bench": "multi_op_platform",
        "platform": jax.default_backend(),
        "backend": Engine().resolve_backend(),
        "note": (
            "per-op serving rows hold every wire result to the op's jnp "
            "reference (bit-identical, hard-asserted); "
            "pipeline_vs_sequential pushes the same image pool through "
            "denoise->ychg as two wire requests per image and as one "
            "compound POST /v1/pipeline request (warm images disjoint "
            "from timed; compound results compared bit for bit against "
            "their sequential twins). The >= 1x throughput bar is "
            "asserted only when cores >= 4, recorded as measured "
            "(cpu_limited) otherwise."
        ),
        "scenarios": rows,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(rows)} scenarios)")


if __name__ == "__main__":
    main()
