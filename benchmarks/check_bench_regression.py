"""CI perf-regression gate for the yCHG service benchmarks.

Compares a fresh ``bench_service.py --quick`` run against the quick
baselines committed in ``BENCH_service.json`` (its ``"quick"`` section)
under the tolerances committed next to them (its ``"gate"`` section), and
exits nonzero on any regression — turning the JSON from an archive into
an enforced contract. Two families of checks:

  * **speedup** — each quick scenario's service/naive speedup must stay
    at least ``min_speedup_ratio`` x its baseline (wide tolerance: CI
    boxes are noisy, interpret-mode numbers doubly so; the gate exists to
    catch "the service stopped batching/caching", not 10% jitter);
  * **pad fraction** — each scenario's pad_fraction may grow by at most
    ``max_pad_fraction_increase`` over baseline, and ``low_occupancy``
    must keep sub-bucket padding at least ``min_low_occupancy_pad_gap``
    below the pad-to-max arm (the sub-batch ladder's whole point).

A third family gates the fleet archive: ``--fleet BENCH_fleet.json``
checks the committed ``fleet_vs_single`` row's hard invariants — the
router path stayed **bit-identical**, repeat traffic after a restart hit
a **sibling cache** (``peer_hits > 0``), and when the recording box had
``cores >= 4`` the throughput ratio met the ``min_fleet_ratio`` bar
(core-starved recordings must carry their ``cpu_limited`` note instead).
``--fleet`` may run standalone (no ``--fresh``) so the fleet-smoke CI
job can gate the archive without re-running the service bench.

A fourth family gates the scene archive the same way: ``--scene
BENCH_scene.json`` (standalone-capable, run by the scene-smoke CI job)
requires ``scene_stitch.bit_identical`` and
``checkpoint_overhead.resume_bit_identical`` to be true — no escape
hatch, these are correctness, not speed — and holds the two same-box
relative ratios: stitched throughput at least ``min_scene_stitch_ratio``
of per-tile-naive (batching strips must not be slower than not
batching), and ``checkpoint_overhead_fraction`` at most
``max_checkpoint_overhead`` (kill-anywhere resumability must stay
affordable). A ``cpu_limited`` note on a row waives only its ratio bar.

A fifth family gates the multi-op archive: ``--ops BENCH_ops.json``
(standalone-capable, run by the op-smoke CI job) requires every
``{op}_serving`` row and the ``pipeline_vs_sequential`` row to be
``bit_identical`` (correctness, no escape hatch) and holds the
compound-pipeline throughput at least ``min_ops_pipeline_ratio`` of the
compose-by-hand sequential arm — the device-resident chain removes a
host round trip and must never be slower. As everywhere, a
``cpu_limited`` note waives only the ratio bar, never bit-identity.

A sixth family gates the traffic-SLO archive: ``--slo BENCH_slo.json``
(standalone-capable, run by the slo-smoke CI job) requires the
``traffic_classes`` row to show a working admission policy — batch-class
sheds at ``min_batch_sheds`` or more, ZERO interactive sheds (hard, no
escape hatch: the protected class must never be collateral damage) and,
on boxes with ``cores >= 4``, an overload/baseline interactive p95 ratio
at most ``max_interactive_p95_ratio`` (a ``cpu_limited`` note waives
only the ratio bar). The ``deadline_shed`` and ``tenant_quota`` rows
must each record at least ``min_deadline_sheds`` / ``min_quota_sheds``
sheds with a positive Retry-After, and the under-quota tenant must have
shed nothing.

``--simulate-regression`` degrades the fresh numbers before comparison
(speedups halved-and-halved-again, pad fractions inflated; the SLO
archive's sheds zeroed and its p95 ratio blown out) so CI can prove the
gate actually trips — the bench-gate and slo-smoke jobs run that first
and require a nonzero exit, then run the real comparison.

Every REQUESTED section is load-bearing: a section file that is
missing, unreadable, not JSON, not a JSON object, or empty of the
scenarios the gate checks is itself a failure and exits nonzero — a
gate that silently passes on a malformed archive is worse than no gate
(tests/test_bench_gate.py pins this, including the empty-baseline case
that used to pass silently).

Run:  PYTHONPATH=src python benchmarks/check_bench_regression.py \\
          --baseline BENCH_service.json --fresh /tmp/fresh_quick.json
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

# used when BENCH_service.json predates the gate section (first rollout)
DEFAULT_GATE = {
    "min_speedup_ratio": 0.3,
    "max_pad_fraction_increase": 0.4,
    "min_low_occupancy_pad_gap": 0.5,
    "min_fleet_ratio": 2.0,
    "min_scene_stitch_ratio": 0.5,
    "max_checkpoint_overhead": 0.5,
    "min_ops_pipeline_ratio": 1.0,
    # traffic-SLO bars: the p95 ratio is wide on purpose (CI boxes are
    # noisy; the gate catches "priority stopped protecting interactive",
    # not jitter), the shed bars are exact policy
    "max_interactive_p95_ratio": 10.0,
    "min_batch_sheds": 1,
    "min_deadline_sheds": 1,
    "min_quota_sheds": 1,
}


def load_report(path: str, what: str) -> "tuple[Dict[str, Any], List[str]]":
    """Read one requested section's JSON report; a file that is missing,
    unreadable, not JSON, or not a JSON object is a FAILURE of that
    section (never a silent pass, never a bare traceback)."""
    try:
        with open(path) as f:
            report = json.load(f)
    except OSError as e:
        return {}, [f"{what}: cannot read {path}: {e}"]
    except ValueError as e:
        return {}, [f"{what}: {path} is not valid JSON: {e}"]
    if not isinstance(report, dict):
        return {}, [f"{what}: {path} is not a JSON object "
                    f"(got {type(report).__name__})"]
    return report, []


def load_quick_rows(report: Dict[str, Any]) -> Dict[str, Dict[str, Any]]:
    """Scenario rows keyed by name, from either a quick-mode report
    (top-level scenarios) or a full report carrying a 'quick' section."""
    if report.get("mode") == "quick":
        rows = report["scenarios"]
    else:
        quick = report.get("quick")
        if quick is None:
            raise SystemExit(
                "baseline has no quick-mode scenarios ('quick' section "
                "missing and mode != 'quick'); re-record with "
                "bench_service.py --quick")
        rows = quick["scenarios"]
    return {row["scenario"]: row for row in rows}


def simulate_regression(rows: Dict[str, Dict[str, Any]]) -> None:
    """Degrade fresh numbers enough to trip every family of check."""
    for row in rows.values():
        if "speedup" in row:
            row["speedup"] = round(row["speedup"] * 0.25, 2)
        if "pad_fraction" in row:
            row["pad_fraction"] = min(
                1.0, round(row["pad_fraction"] + 0.5, 3))
        if "sub_buckets_pad_fraction" in row:
            # sub-batching "broken": pads like the pad-to-max arm again
            row["sub_buckets_pad_fraction"] = row.get(
                "pad_to_max_pad_fraction", 0.875)


def check(baseline: Dict[str, Dict[str, Any]],
          fresh: Dict[str, Dict[str, Any]],
          gate: Dict[str, Any]) -> List[str]:
    failures: List[str] = []
    if not baseline:
        # the pre-fix gate compared zero scenarios and printed "passed"
        failures.append(
            "baseline has no scenarios to gate — an empty archive is a "
            "broken recording, not a pass")
    ratio = gate["min_speedup_ratio"]
    pad_tol = gate["max_pad_fraction_increase"]
    pad_gap = gate["min_low_occupancy_pad_gap"]
    for name, base in baseline.items():
        row = fresh.get(name)
        if row is None:
            failures.append(f"{name}: scenario missing from the fresh run")
            continue
        if "speedup" in base:
            floor = round(base["speedup"] * ratio, 2)
            if row["speedup"] < floor:
                failures.append(
                    f"{name}: speedup {row['speedup']} < {floor} "
                    f"(= baseline {base['speedup']} x {ratio})")
        if "pad_fraction" in base:
            ceil = round(base["pad_fraction"] + pad_tol, 3)
            if row["pad_fraction"] > ceil:
                failures.append(
                    f"{name}: pad_fraction {row['pad_fraction']} > {ceil} "
                    f"(= baseline {base['pad_fraction']} + {pad_tol})")
        if "sub_buckets_pad_fraction" in base:
            gap = (row["pad_to_max_pad_fraction"]
                   - row["sub_buckets_pad_fraction"])
            if gap < pad_gap:
                failures.append(
                    f"{name}: sub-bucket pad advantage {gap:.3f} < "
                    f"{pad_gap} (sub_buckets {row['sub_buckets_pad_fraction']}"
                    f" vs pad_to_max {row['pad_to_max_pad_fraction']})")
    return failures


def check_fleet(report: Dict[str, Any], gate: Dict[str, Any]) -> List[str]:
    """Hard invariants of the committed fleet archive (no fresh run
    needed: these are properties a recording must have to be committed)."""
    failures: List[str] = []
    rows = {row["scenario"]: row for row in report.get("scenarios", [])}
    row = rows.get("fleet_vs_single")
    if row is None:
        return ["fleet archive has no fleet_vs_single scenario"]
    if row.get("bit_identical") is not True:
        failures.append("fleet_vs_single: router path not bit-identical")
    if not row.get("peer_hits", 0) > 0:
        failures.append(
            "fleet_vs_single: peer_hits == 0 — repeat traffic after a "
            "restart was recomputed instead of served from a sibling cache")
    cores, ratio = row.get("cores", 0), row.get("fleet_throughput_ratio")
    if cores >= 4:
        if ratio is None or ratio < gate["min_fleet_ratio"]:
            failures.append(
                f"fleet_vs_single: ratio {ratio} < {gate['min_fleet_ratio']} "
                f"on {cores} cores")
    elif "cpu_limited" not in row.get("note", ""):
        failures.append(
            f"fleet_vs_single: recorded on {cores} core(s) without the "
            "cpu_limited note — re-record with bench_fleet.py")
    return failures


def check_scene(report: Dict[str, Any], gate: Dict[str, Any]) -> List[str]:
    """Hard invariants of the committed scene archive. Bit-identity
    verdicts have no escape; the same-box ratio bars can be waived only
    by a ``cpu_limited`` note on the row."""
    failures: List[str] = []
    rows = {row["scenario"]: row for row in report.get("scenarios", [])}

    stitch = rows.get("scene_stitch")
    if stitch is None:
        failures.append("scene archive has no scene_stitch scenario")
    else:
        if stitch.get("bit_identical") is not True:
            failures.append(
                "scene_stitch: stitched result not bit-identical to the "
                "whole-scene analysis")
        ratio = stitch.get("stitched_vs_naive_ratio")
        floor = gate["min_scene_stitch_ratio"]
        if "cpu_limited" not in stitch.get("note", ""):
            if ratio is None or ratio < floor:
                failures.append(
                    f"scene_stitch: stitched_vs_naive_ratio {ratio} < "
                    f"{floor} without a cpu_limited note — strip batching "
                    f"became slower than per-tile calls")

    ckpt = rows.get("checkpoint_overhead")
    if ckpt is None:
        failures.append("scene archive has no checkpoint_overhead scenario")
    else:
        if ckpt.get("resume_bit_identical") is not True:
            failures.append(
                "checkpoint_overhead: interrupt->resume output not "
                "byte-identical to the uninterrupted run")
        frac = ckpt.get("checkpoint_overhead_fraction")
        ceil = gate["max_checkpoint_overhead"]
        if "cpu_limited" not in ckpt.get("note", ""):
            if frac is None or frac > ceil:
                failures.append(
                    f"checkpoint_overhead: overhead fraction {frac} > "
                    f"{ceil} without a cpu_limited note — per-stack "
                    f"checkpointing became unaffordable")
    return failures


def check_ops(report: Dict[str, Any], gate: Dict[str, Any]) -> List[str]:
    """Hard invariants of the committed multi-op archive. Every serving
    row and the compound-pipeline row must be bit-identical (no escape
    hatch); the pipeline-vs-sequential throughput bar can be waived only
    by a ``cpu_limited`` note on the row."""
    failures: List[str] = []
    rows = {row["scenario"]: row for row in report.get("scenarios", [])}

    for op in ("ychg", "ccl", "denoise"):
        row = rows.get(f"{op}_serving")
        if row is None:
            failures.append(f"ops archive has no {op}_serving scenario")
        elif row.get("bit_identical") is not True:
            failures.append(
                f"{op}_serving: wire results not bit-identical to the "
                f"op's jnp reference")

    pipe = rows.get("pipeline_vs_sequential")
    if pipe is None:
        failures.append("ops archive has no pipeline_vs_sequential scenario")
    else:
        if pipe.get("bit_identical") is not True:
            failures.append(
                "pipeline_vs_sequential: compound results not bit-identical "
                "to the stages issued as separate requests")
        cores = pipe.get("cores", 0)
        ratio = pipe.get("pipeline_vs_sequential_ratio")
        floor = gate["min_ops_pipeline_ratio"]
        if cores >= 4:
            if ratio is None or ratio < floor:
                failures.append(
                    f"pipeline_vs_sequential: ratio {ratio} < {floor} on "
                    f"{cores} cores — the compound path (which removes a "
                    f"host round trip) became slower than composing by hand")
        elif "cpu_limited" not in pipe.get("note", ""):
            failures.append(
                f"pipeline_vs_sequential: recorded on {cores} core(s) "
                "without the cpu_limited note — re-record with bench_ops.py")
    return failures


def check_slo(report: Dict[str, Any], gate: Dict[str, Any]) -> List[str]:
    """Hard invariants of the committed traffic-SLO archive. Shed counts
    and quota algebra are policy, asserted on any box; only the p95
    ratio bar is waivable, by a ``cpu_limited`` note on the row."""
    failures: List[str] = []
    rows = {row["scenario"]: row
            for row in report.get("scenarios", [])
            if isinstance(row, dict) and "scenario" in row}

    tc = rows.get("traffic_classes")
    if tc is None:
        failures.append("slo archive has no traffic_classes scenario")
    else:
        if tc.get("batch_sheds", 0) < gate["min_batch_sheds"]:
            failures.append(
                f"traffic_classes: batch_sheds {tc.get('batch_sheds')} < "
                f"{gate['min_batch_sheds']} — the overload flood was not "
                f"shed, admission control is not engaging")
        if tc.get("interactive_sheds") != 0:
            failures.append(
                f"traffic_classes: interactive_sheds "
                f"{tc.get('interactive_sheds')} != 0 — the protected "
                f"class was collateral damage of the batch flood")
        cores = tc.get("cores", 0)
        ratio = tc.get("interactive_p95_ratio")
        ceil = gate["max_interactive_p95_ratio"]
        if cores >= 4:
            if ratio is None or ratio > ceil:
                failures.append(
                    f"traffic_classes: interactive p95 ratio {ratio} > "
                    f"{ceil} on {cores} cores — class priority stopped "
                    f"protecting interactive latency under overload")
        elif "cpu_limited" not in tc.get("note", ""):
            failures.append(
                f"traffic_classes: recorded on {cores} core(s) without "
                f"the cpu_limited note — re-record with bench_slo.py")

    dl = rows.get("deadline_shed")
    if dl is None:
        failures.append("slo archive has no deadline_shed scenario")
    else:
        if dl.get("dead_sheds", 0) < gate["min_deadline_sheds"]:
            failures.append(
                f"deadline_shed: dead_sheds {dl.get('dead_sheds')} < "
                f"{gate['min_deadline_sheds']} — dead-on-arrival requests "
                f"were admitted instead of shed")
        if not (dl.get("retry_after_s") or 0) > 0:
            failures.append(
                f"deadline_shed: retry_after_s "
                f"{dl.get('retry_after_s')} — a deadline shed must quote "
                f"a positive Retry-After")

    tq = rows.get("tenant_quota")
    if tq is None:
        failures.append("slo archive has no tenant_quota scenario")
    else:
        if tq.get("quota_sheds", 0) < gate["min_quota_sheds"]:
            failures.append(
                f"tenant_quota: quota_sheds {tq.get('quota_sheds')} < "
                f"{gate['min_quota_sheds']} — the over-quota tenant was "
                f"never shed")
        if tq.get("other_tenant_sheds") != 0:
            failures.append(
                f"tenant_quota: other_tenant_sheds "
                f"{tq.get('other_tenant_sheds')} != 0 — one tenant's "
                f"quota punished another tenant")
        if not (tq.get("retry_after_s") or 0) > 0:
            failures.append(
                f"tenant_quota: retry_after_s {tq.get('retry_after_s')} "
                f"— a quota shed must quote a positive Retry-After")
    return failures


def simulate_slo_regression(report: Dict[str, Any]) -> None:
    """Degrade the SLO archive enough to trip every check family: sheds
    zeroed (admission 'stopped engaging'), the p95 ratio blown out, the
    Retry-After quotes dropped."""
    for row in report.get("scenarios", []):
        if not isinstance(row, dict):
            continue
        if row.get("scenario") == "traffic_classes":
            row["batch_sheds"] = 0
            row["interactive_sheds"] = 5
            row["interactive_p95_ratio"] = 99.0
            row.pop("note", None)
        elif row.get("scenario") == "deadline_shed":
            row["dead_sheds"] = 0
            row["retry_after_s"] = None
        elif row.get("scenario") == "tenant_quota":
            row["quota_sheds"] = 0
            row["retry_after_s"] = None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_service.json")
    ap.add_argument("--fresh", default=None,
                    help="report written by bench_service.py --quick")
    ap.add_argument("--fleet", default=None,
                    help="BENCH_fleet.json to check invariants of (may "
                         "run standalone, without --fresh)")
    ap.add_argument("--scene", default=None,
                    help="BENCH_scene.json to check invariants of (may "
                         "run standalone, without --fresh)")
    ap.add_argument("--ops", default=None,
                    help="BENCH_ops.json to check invariants of (may "
                         "run standalone, without --fresh)")
    ap.add_argument("--slo", default=None,
                    help="BENCH_slo.json to check invariants of (may "
                         "run standalone, without --fresh)")
    ap.add_argument("--simulate-regression", action="store_true",
                    help="degrade the fresh numbers first; the gate MUST "
                         "exit nonzero (CI self-test)")
    args = ap.parse_args()
    if (args.fresh is None and args.fleet is None and args.scene is None
            and args.ops is None and args.slo is None):
        ap.error("nothing to do: pass --fresh, --fleet, --scene, "
                 "--ops, and/or --slo")
    failures: List[str] = []
    baseline_report, baseline_failures = load_report(
        args.baseline, "baseline")
    # the baseline is load-bearing only for --fresh (the standalone
    # archive gates read only its 'gate' overrides): a broken baseline
    # fails the run exactly when a fresh comparison needs it
    if args.fresh is not None:
        failures += baseline_failures
    gate = {**DEFAULT_GATE, **baseline_report.get("gate", {})}
    if args.fresh is not None and not baseline_failures:
        fresh_report, fresh_failures = load_report(args.fresh, "fresh")
        failures += fresh_failures
        if not fresh_failures:
            baseline = load_quick_rows(baseline_report)
            fresh = load_quick_rows(fresh_report)
            if args.simulate_regression:
                simulate_regression(fresh)
                print("simulate-regression: fresh numbers degraded "
                      "before check")
            failures += check(baseline, fresh, gate)
            print(f"gate: {len(baseline)} scenarios, thresholds {gate}")
            for name in baseline:
                row = fresh.get(name, {})
                print(f"  {name}: speedup {row.get('speedup', '-')} "
                      f"(baseline {baseline[name].get('speedup', '-')}), "
                      f"pad {row.get('pad_fraction', '-')} "
                      f"(baseline {baseline[name].get('pad_fraction', '-')})")
    for flag, what, checker in (
            (args.fleet, "fleet", check_fleet),
            (args.scene, "scene", check_scene),
            (args.ops, "ops", check_ops),
            (args.slo, "slo", check_slo)):
        if flag is None:
            continue
        report, section_failures = load_report(flag, what)
        if not section_failures:
            if what == "slo" and args.simulate_regression:
                simulate_slo_regression(report)
                print("simulate-regression: slo archive degraded "
                      "before check")
            section_failures = checker(report, gate)
        failures += section_failures
        print(f"{what} gate: {flag} "
              f"{'FAILED' if section_failures else 'ok'}")
    if failures:
        print("\nPERF REGRESSION:")
        for f_ in failures:
            print(f"  FAIL {f_}")
        sys.exit(1)
    print("bench gate passed")


if __name__ == "__main__":
    main()
