"""CI perf-regression gate for the yCHG service benchmarks.

Compares a fresh ``bench_service.py --quick`` run against the quick
baselines committed in ``BENCH_service.json`` (its ``"quick"`` section)
under the tolerances committed next to them (its ``"gate"`` section), and
exits nonzero on any regression — turning the JSON from an archive into
an enforced contract. Two families of checks:

  * **speedup** — each quick scenario's service/naive speedup must stay
    at least ``min_speedup_ratio`` x its baseline (wide tolerance: CI
    boxes are noisy, interpret-mode numbers doubly so; the gate exists to
    catch "the service stopped batching/caching", not 10% jitter);
  * **pad fraction** — each scenario's pad_fraction may grow by at most
    ``max_pad_fraction_increase`` over baseline, and ``low_occupancy``
    must keep sub-bucket padding at least ``min_low_occupancy_pad_gap``
    below the pad-to-max arm (the sub-batch ladder's whole point).

``--simulate-regression`` degrades the fresh numbers before comparison
(speedups halved-and-halved-again, pad fractions inflated) so CI can
prove the gate actually trips — the bench-gate job runs that first and
requires a nonzero exit, then runs the real comparison.

Run:  PYTHONPATH=src python benchmarks/check_bench_regression.py \\
          --baseline BENCH_service.json --fresh /tmp/fresh_quick.json
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

# used when BENCH_service.json predates the gate section (first rollout)
DEFAULT_GATE = {
    "min_speedup_ratio": 0.3,
    "max_pad_fraction_increase": 0.4,
    "min_low_occupancy_pad_gap": 0.5,
}


def load_quick_rows(report: Dict[str, Any]) -> Dict[str, Dict[str, Any]]:
    """Scenario rows keyed by name, from either a quick-mode report
    (top-level scenarios) or a full report carrying a 'quick' section."""
    if report.get("mode") == "quick":
        rows = report["scenarios"]
    else:
        quick = report.get("quick")
        if quick is None:
            raise SystemExit(
                "baseline has no quick-mode scenarios ('quick' section "
                "missing and mode != 'quick'); re-record with "
                "bench_service.py --quick")
        rows = quick["scenarios"]
    return {row["scenario"]: row for row in rows}


def simulate_regression(rows: Dict[str, Dict[str, Any]]) -> None:
    """Degrade fresh numbers enough to trip every family of check."""
    for row in rows.values():
        if "speedup" in row:
            row["speedup"] = round(row["speedup"] * 0.25, 2)
        if "pad_fraction" in row:
            row["pad_fraction"] = min(
                1.0, round(row["pad_fraction"] + 0.5, 3))
        if "sub_buckets_pad_fraction" in row:
            # sub-batching "broken": pads like the pad-to-max arm again
            row["sub_buckets_pad_fraction"] = row.get(
                "pad_to_max_pad_fraction", 0.875)


def check(baseline: Dict[str, Dict[str, Any]],
          fresh: Dict[str, Dict[str, Any]],
          gate: Dict[str, Any]) -> List[str]:
    failures: List[str] = []
    ratio = gate["min_speedup_ratio"]
    pad_tol = gate["max_pad_fraction_increase"]
    pad_gap = gate["min_low_occupancy_pad_gap"]
    for name, base in baseline.items():
        row = fresh.get(name)
        if row is None:
            failures.append(f"{name}: scenario missing from the fresh run")
            continue
        if "speedup" in base:
            floor = round(base["speedup"] * ratio, 2)
            if row["speedup"] < floor:
                failures.append(
                    f"{name}: speedup {row['speedup']} < {floor} "
                    f"(= baseline {base['speedup']} x {ratio})")
        if "pad_fraction" in base:
            ceil = round(base["pad_fraction"] + pad_tol, 3)
            if row["pad_fraction"] > ceil:
                failures.append(
                    f"{name}: pad_fraction {row['pad_fraction']} > {ceil} "
                    f"(= baseline {base['pad_fraction']} + {pad_tol})")
        if "sub_buckets_pad_fraction" in base:
            gap = (row["pad_to_max_pad_fraction"]
                   - row["sub_buckets_pad_fraction"])
            if gap < pad_gap:
                failures.append(
                    f"{name}: sub-bucket pad advantage {gap:.3f} < "
                    f"{pad_gap} (sub_buckets {row['sub_buckets_pad_fraction']}"
                    f" vs pad_to_max {row['pad_to_max_pad_fraction']})")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_service.json")
    ap.add_argument("--fresh", required=True,
                    help="report written by bench_service.py --quick")
    ap.add_argument("--simulate-regression", action="store_true",
                    help="degrade the fresh numbers first; the gate MUST "
                         "exit nonzero (CI self-test)")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline_report = json.load(f)
    with open(args.fresh) as f:
        fresh_report = json.load(f)
    gate = {**DEFAULT_GATE, **baseline_report.get("gate", {})}
    baseline = load_quick_rows(baseline_report)
    fresh = load_quick_rows(fresh_report)
    if args.simulate_regression:
        simulate_regression(fresh)
        print("simulate-regression: fresh numbers degraded before check")
    failures = check(baseline, fresh, gate)
    print(f"gate: {len(baseline)} scenarios, thresholds {gate}")
    for name in baseline:
        row = fresh.get(name, {})
        print(f"  {name}: speedup {row.get('speedup', '-')} "
              f"(baseline {baseline[name].get('speedup', '-')}), "
              f"pad {row.get('pad_fraction', '-')} "
              f"(baseline {baseline[name].get('pad_fraction', '-')})")
    if failures:
        print("\nPERF REGRESSION:")
        for f_ in failures:
            print(f"  FAIL {f_}")
        sys.exit(1)
    print("bench gate passed")


if __name__ == "__main__":
    main()
