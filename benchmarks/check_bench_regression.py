"""CI perf-regression gate for the yCHG service benchmarks.

Compares a fresh ``bench_service.py --quick`` run against the quick
baselines committed in ``BENCH_service.json`` (its ``"quick"`` section)
under the tolerances committed next to them (its ``"gate"`` section), and
exits nonzero on any regression — turning the JSON from an archive into
an enforced contract. Two families of checks:

  * **speedup** — each quick scenario's service/naive speedup must stay
    at least ``min_speedup_ratio`` x its baseline (wide tolerance: CI
    boxes are noisy, interpret-mode numbers doubly so; the gate exists to
    catch "the service stopped batching/caching", not 10% jitter);
  * **pad fraction** — each scenario's pad_fraction may grow by at most
    ``max_pad_fraction_increase`` over baseline, and ``low_occupancy``
    must keep sub-bucket padding at least ``min_low_occupancy_pad_gap``
    below the pad-to-max arm (the sub-batch ladder's whole point).

A third family gates the fleet archive: ``--fleet BENCH_fleet.json``
checks the committed ``fleet_vs_single`` row's hard invariants — the
router path stayed **bit-identical**, repeat traffic after a restart hit
a **sibling cache** (``peer_hits > 0``), and when the recording box had
``cores >= 4`` the throughput ratio met the ``min_fleet_ratio`` bar
(core-starved recordings must carry their ``cpu_limited`` note instead).
``--fleet`` may run standalone (no ``--fresh``) so the fleet-smoke CI
job can gate the archive without re-running the service bench.

A fourth family gates the scene archive the same way: ``--scene
BENCH_scene.json`` (standalone-capable, run by the scene-smoke CI job)
requires ``scene_stitch.bit_identical`` and
``checkpoint_overhead.resume_bit_identical`` to be true — no escape
hatch, these are correctness, not speed — and holds the two same-box
relative ratios: stitched throughput at least ``min_scene_stitch_ratio``
of per-tile-naive (batching strips must not be slower than not
batching), and ``checkpoint_overhead_fraction`` at most
``max_checkpoint_overhead`` (kill-anywhere resumability must stay
affordable). A ``cpu_limited`` note on a row waives only its ratio bar.

A fifth family gates the multi-op archive: ``--ops BENCH_ops.json``
(standalone-capable, run by the op-smoke CI job) requires every
``{op}_serving`` row and the ``pipeline_vs_sequential`` row to be
``bit_identical`` (correctness, no escape hatch) and holds the
compound-pipeline throughput at least ``min_ops_pipeline_ratio`` of the
compose-by-hand sequential arm — the device-resident chain removes a
host round trip and must never be slower. As everywhere, a
``cpu_limited`` note waives only the ratio bar, never bit-identity.

``--simulate-regression`` degrades the fresh numbers before comparison
(speedups halved-and-halved-again, pad fractions inflated) so CI can
prove the gate actually trips — the bench-gate job runs that first and
requires a nonzero exit, then runs the real comparison.

Run:  PYTHONPATH=src python benchmarks/check_bench_regression.py \\
          --baseline BENCH_service.json --fresh /tmp/fresh_quick.json
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

# used when BENCH_service.json predates the gate section (first rollout)
DEFAULT_GATE = {
    "min_speedup_ratio": 0.3,
    "max_pad_fraction_increase": 0.4,
    "min_low_occupancy_pad_gap": 0.5,
    "min_fleet_ratio": 2.0,
    "min_scene_stitch_ratio": 0.5,
    "max_checkpoint_overhead": 0.5,
    "min_ops_pipeline_ratio": 1.0,
}


def load_quick_rows(report: Dict[str, Any]) -> Dict[str, Dict[str, Any]]:
    """Scenario rows keyed by name, from either a quick-mode report
    (top-level scenarios) or a full report carrying a 'quick' section."""
    if report.get("mode") == "quick":
        rows = report["scenarios"]
    else:
        quick = report.get("quick")
        if quick is None:
            raise SystemExit(
                "baseline has no quick-mode scenarios ('quick' section "
                "missing and mode != 'quick'); re-record with "
                "bench_service.py --quick")
        rows = quick["scenarios"]
    return {row["scenario"]: row for row in rows}


def simulate_regression(rows: Dict[str, Dict[str, Any]]) -> None:
    """Degrade fresh numbers enough to trip every family of check."""
    for row in rows.values():
        if "speedup" in row:
            row["speedup"] = round(row["speedup"] * 0.25, 2)
        if "pad_fraction" in row:
            row["pad_fraction"] = min(
                1.0, round(row["pad_fraction"] + 0.5, 3))
        if "sub_buckets_pad_fraction" in row:
            # sub-batching "broken": pads like the pad-to-max arm again
            row["sub_buckets_pad_fraction"] = row.get(
                "pad_to_max_pad_fraction", 0.875)


def check(baseline: Dict[str, Dict[str, Any]],
          fresh: Dict[str, Dict[str, Any]],
          gate: Dict[str, Any]) -> List[str]:
    failures: List[str] = []
    ratio = gate["min_speedup_ratio"]
    pad_tol = gate["max_pad_fraction_increase"]
    pad_gap = gate["min_low_occupancy_pad_gap"]
    for name, base in baseline.items():
        row = fresh.get(name)
        if row is None:
            failures.append(f"{name}: scenario missing from the fresh run")
            continue
        if "speedup" in base:
            floor = round(base["speedup"] * ratio, 2)
            if row["speedup"] < floor:
                failures.append(
                    f"{name}: speedup {row['speedup']} < {floor} "
                    f"(= baseline {base['speedup']} x {ratio})")
        if "pad_fraction" in base:
            ceil = round(base["pad_fraction"] + pad_tol, 3)
            if row["pad_fraction"] > ceil:
                failures.append(
                    f"{name}: pad_fraction {row['pad_fraction']} > {ceil} "
                    f"(= baseline {base['pad_fraction']} + {pad_tol})")
        if "sub_buckets_pad_fraction" in base:
            gap = (row["pad_to_max_pad_fraction"]
                   - row["sub_buckets_pad_fraction"])
            if gap < pad_gap:
                failures.append(
                    f"{name}: sub-bucket pad advantage {gap:.3f} < "
                    f"{pad_gap} (sub_buckets {row['sub_buckets_pad_fraction']}"
                    f" vs pad_to_max {row['pad_to_max_pad_fraction']})")
    return failures


def check_fleet(report: Dict[str, Any], gate: Dict[str, Any]) -> List[str]:
    """Hard invariants of the committed fleet archive (no fresh run
    needed: these are properties a recording must have to be committed)."""
    failures: List[str] = []
    rows = {row["scenario"]: row for row in report.get("scenarios", [])}
    row = rows.get("fleet_vs_single")
    if row is None:
        return ["fleet archive has no fleet_vs_single scenario"]
    if row.get("bit_identical") is not True:
        failures.append("fleet_vs_single: router path not bit-identical")
    if not row.get("peer_hits", 0) > 0:
        failures.append(
            "fleet_vs_single: peer_hits == 0 — repeat traffic after a "
            "restart was recomputed instead of served from a sibling cache")
    cores, ratio = row.get("cores", 0), row.get("fleet_throughput_ratio")
    if cores >= 4:
        if ratio is None or ratio < gate["min_fleet_ratio"]:
            failures.append(
                f"fleet_vs_single: ratio {ratio} < {gate['min_fleet_ratio']} "
                f"on {cores} cores")
    elif "cpu_limited" not in row.get("note", ""):
        failures.append(
            f"fleet_vs_single: recorded on {cores} core(s) without the "
            "cpu_limited note — re-record with bench_fleet.py")
    return failures


def check_scene(report: Dict[str, Any], gate: Dict[str, Any]) -> List[str]:
    """Hard invariants of the committed scene archive. Bit-identity
    verdicts have no escape; the same-box ratio bars can be waived only
    by a ``cpu_limited`` note on the row."""
    failures: List[str] = []
    rows = {row["scenario"]: row for row in report.get("scenarios", [])}

    stitch = rows.get("scene_stitch")
    if stitch is None:
        failures.append("scene archive has no scene_stitch scenario")
    else:
        if stitch.get("bit_identical") is not True:
            failures.append(
                "scene_stitch: stitched result not bit-identical to the "
                "whole-scene analysis")
        ratio = stitch.get("stitched_vs_naive_ratio")
        floor = gate["min_scene_stitch_ratio"]
        if "cpu_limited" not in stitch.get("note", ""):
            if ratio is None or ratio < floor:
                failures.append(
                    f"scene_stitch: stitched_vs_naive_ratio {ratio} < "
                    f"{floor} without a cpu_limited note — strip batching "
                    f"became slower than per-tile calls")

    ckpt = rows.get("checkpoint_overhead")
    if ckpt is None:
        failures.append("scene archive has no checkpoint_overhead scenario")
    else:
        if ckpt.get("resume_bit_identical") is not True:
            failures.append(
                "checkpoint_overhead: interrupt->resume output not "
                "byte-identical to the uninterrupted run")
        frac = ckpt.get("checkpoint_overhead_fraction")
        ceil = gate["max_checkpoint_overhead"]
        if "cpu_limited" not in ckpt.get("note", ""):
            if frac is None or frac > ceil:
                failures.append(
                    f"checkpoint_overhead: overhead fraction {frac} > "
                    f"{ceil} without a cpu_limited note — per-stack "
                    f"checkpointing became unaffordable")
    return failures


def check_ops(report: Dict[str, Any], gate: Dict[str, Any]) -> List[str]:
    """Hard invariants of the committed multi-op archive. Every serving
    row and the compound-pipeline row must be bit-identical (no escape
    hatch); the pipeline-vs-sequential throughput bar can be waived only
    by a ``cpu_limited`` note on the row."""
    failures: List[str] = []
    rows = {row["scenario"]: row for row in report.get("scenarios", [])}

    for op in ("ychg", "ccl", "denoise"):
        row = rows.get(f"{op}_serving")
        if row is None:
            failures.append(f"ops archive has no {op}_serving scenario")
        elif row.get("bit_identical") is not True:
            failures.append(
                f"{op}_serving: wire results not bit-identical to the "
                f"op's jnp reference")

    pipe = rows.get("pipeline_vs_sequential")
    if pipe is None:
        failures.append("ops archive has no pipeline_vs_sequential scenario")
    else:
        if pipe.get("bit_identical") is not True:
            failures.append(
                "pipeline_vs_sequential: compound results not bit-identical "
                "to the stages issued as separate requests")
        cores = pipe.get("cores", 0)
        ratio = pipe.get("pipeline_vs_sequential_ratio")
        floor = gate["min_ops_pipeline_ratio"]
        if cores >= 4:
            if ratio is None or ratio < floor:
                failures.append(
                    f"pipeline_vs_sequential: ratio {ratio} < {floor} on "
                    f"{cores} cores — the compound path (which removes a "
                    f"host round trip) became slower than composing by hand")
        elif "cpu_limited" not in pipe.get("note", ""):
            failures.append(
                f"pipeline_vs_sequential: recorded on {cores} core(s) "
                "without the cpu_limited note — re-record with bench_ops.py")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_service.json")
    ap.add_argument("--fresh", default=None,
                    help="report written by bench_service.py --quick")
    ap.add_argument("--fleet", default=None,
                    help="BENCH_fleet.json to check invariants of (may "
                         "run standalone, without --fresh)")
    ap.add_argument("--scene", default=None,
                    help="BENCH_scene.json to check invariants of (may "
                         "run standalone, without --fresh)")
    ap.add_argument("--ops", default=None,
                    help="BENCH_ops.json to check invariants of (may "
                         "run standalone, without --fresh)")
    ap.add_argument("--simulate-regression", action="store_true",
                    help="degrade the fresh numbers first; the gate MUST "
                         "exit nonzero (CI self-test)")
    args = ap.parse_args()
    if (args.fresh is None and args.fleet is None and args.scene is None
            and args.ops is None):
        ap.error("nothing to do: pass --fresh, --fleet, --scene, "
                 "and/or --ops")
    with open(args.baseline) as f:
        baseline_report = json.load(f)
    gate = {**DEFAULT_GATE, **baseline_report.get("gate", {})}
    failures: List[str] = []
    if args.fresh is not None:
        with open(args.fresh) as f:
            fresh_report = json.load(f)
        baseline = load_quick_rows(baseline_report)
        fresh = load_quick_rows(fresh_report)
        if args.simulate_regression:
            simulate_regression(fresh)
            print("simulate-regression: fresh numbers degraded before check")
        failures += check(baseline, fresh, gate)
        print(f"gate: {len(baseline)} scenarios, thresholds {gate}")
        for name in baseline:
            row = fresh.get(name, {})
            print(f"  {name}: speedup {row.get('speedup', '-')} "
                  f"(baseline {baseline[name].get('speedup', '-')}), "
                  f"pad {row.get('pad_fraction', '-')} "
                  f"(baseline {baseline[name].get('pad_fraction', '-')})")
    if args.fleet is not None:
        with open(args.fleet) as f:
            fleet_report = json.load(f)
        fleet_failures = check_fleet(fleet_report, gate)
        failures += fleet_failures
        print(f"fleet gate: {args.fleet} "
              f"{'FAILED' if fleet_failures else 'ok'}")
    if args.scene is not None:
        with open(args.scene) as f:
            scene_report = json.load(f)
        scene_failures = check_scene(scene_report, gate)
        failures += scene_failures
        print(f"scene gate: {args.scene} "
              f"{'FAILED' if scene_failures else 'ok'}")
    if args.ops is not None:
        with open(args.ops) as f:
            ops_report = json.load(f)
        ops_failures = check_ops(ops_report, gate)
        failures += ops_failures
        print(f"ops gate: {args.ops} "
              f"{'FAILED' if ops_failures else 'ok'}")
    if failures:
        print("\nPERF REGRESSION:")
        for f_ in failures:
            print(f"  FAIL {f_}")
        sys.exit(1)
    print("bench gate passed")


if __name__ == "__main__":
    main()
