"""Render results/dryrun/*.json into the EXPERIMENTS.md roofline tables.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [--dir results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x * 1e6:.1f}µs"
    if x < 0.1:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.3f}s"


def load(dirname: str, variants: bool = False):
    recs = []
    for p in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        parts = os.path.basename(p)[:-5].split("__")
        if (len(parts) > 3) != variants:
            continue
        with open(p) as f:
            r = json.load(f)
        r["_variant"] = "__".join(parts[3:]) if len(parts) > 3 else "base"
        recs.append(r)
    return recs


SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def dryrun_table(recs) -> str:
    rows = ["| arch | shape | mesh | chips | compile | bytes/dev (args) | collectives/group | status |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9),
                                         r["mesh"])):
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"{r.get('chips','-')} | - | - | - | FAIL: "
                        f"{r.get('error','?')[:60]} |")
            continue
        mem = r.get("memory", {})
        args_gb = mem.get("argument_size_in_bytes", 0) / 2**30
        cc = r.get("collective_counts_per_group", {})
        coll = " ".join(f"{k.replace('_count_', '')}:{v}" for k, v in cc.items()
                        if v) or "-"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r.get('compile_s', '-')}s | {args_gb:.2f} GiB | {coll} | OK |")
    return "\n".join(rows)


def roofline_table(recs) -> str:
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "MODEL_FLOPS | useful ratio | what moves the dominant term |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9))):
        if not r.get("ok") or r["mesh"] != "single":
            continue
        t = r["roofline"]
        hint = hint_for(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"{r['dominant'].replace('_s', '')} | {r['model_flops']:.3g} | "
            f"{r['useful_compute_ratio']:.3f} | {hint} |")
    return "\n".join(rows)


def hint_for(r) -> str:
    d = r["dominant"]
    kind = r["kind"]
    if d == "collective_s":
        c = r.get("collectives", {})
        ar = c.get("all-reduce", 0)
        if ar > 0.5 * c.get("total", 1):
            return ("all-reduce bound: MoE dispatch via shard_map all-to-all / "
                    "grad-reduce in bf16" if "moe" in r["arch"] or "jamba" in r["arch"]
                    else "all-reduce bound: reshard grads (reduce-scatter) / overlap")
        return "all-gather bound: cache FSDP gathers across microbatch"
    if d == "memory_s":
        if kind == "decode":
            return "KV/cache streaming bound: quantize cache or shard seq wider"
        return "HBM bound: fuse logits softmax, larger attn chunk, bf16 logits"
    return "compute bound: cut remat recompute, causal-skip attention"


def variant_table(recs) -> str:
    rows = ["| arch | shape | variant | compute | memory | collective | args/dev |",
            "|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], SHAPE_ORDER.get(r["shape"], 9),
                                         r["_variant"])):
        if not r.get("ok"):
            continue
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['_variant']} | "
            f"{fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} | "
            f"{fmt_s(t['collective_s'])} | "
            f"{r['memory']['argument_size_in_bytes'] / 2**30:.2f} GiB |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    ok = [r for r in recs if r.get("ok")]
    fail = [r for r in recs if not r.get("ok")]
    print(f"## Dry-run ({len(ok)} ok, {len(fail)} failed)\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod, per cell)\n")
    print(roofline_table(recs))
    vrecs = load(args.dir, variants=True)
    if vrecs:
        print("\n## Perf variants (§Perf iterations)\n")
        print(variant_table(vrecs))


if __name__ == "__main__":
    main()
