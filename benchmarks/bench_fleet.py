"""Fleet scaling benchmark: router over N worker processes vs one process.

One scenario family, written to ``BENCH_fleet.json``:

  **fleet_vs_single** — the SAME pool of distinct masks (caches disabled
  where they would flatter: the timed masks are never pre-cached) served
  two ways: (a) a single in-process ``YCHGService`` behind its own
  ``ServerThread`` (today's one-process ceiling) and (b) the
  ``repro.fleet`` router fanning over ``--workers`` subprocess workers.
  Both arms are warmed on a DISJOINT warm mask set (same bucket, so the
  ladder rungs compile outside timing, but no timed mask is ever served
  from a cache). The row records throughput for both arms, the ratio,
  and a bit-identity verdict (every field of every result compared
  against the single-process arm).

  **Honesty about cores**: fanning over processes buys nothing a single
  core can't give. The row records ``cores`` (``os.cpu_count()``); the
  ``>= 2x`` acceptance bar is asserted only when ``cores >= 4`` — on
  smaller boxes the measured ratio is recorded with a ``cpu_limited``
  note instead of a fake pass or a guaranteed failure.

  A final **peering leg** (recorded, always asserted) replays the
  smoke's death -> reroute -> restart -> repeat sequence and requires the
  rolled-up ``ychg_cache_peer_hits_total`` > 0: repeat traffic after a
  worker restart must be served from a sibling's cache, not recomputed.

Run:  PYTHONPATH=src python benchmarks/bench_fleet.py [--out BENCH_fleet.json]
"""

from __future__ import annotations

import argparse
import asyncio
import json
import os
import time
from typing import Dict, List

import numpy as np

import jax

from repro.data import modis
from repro.engine import Engine
from repro.fleet import FleetRouter, FleetSupervisor, HashRing, RouterConfig, RouterThread
from repro.fleet.router import routing_key
from repro.frontend import ServerThread, YCHGClient
from repro.service import ServiceConfig, YCHGService

RES = 64
MAX_BATCH = 8


def _masks(n: int, seed0: int) -> List[np.ndarray]:
    return [modis.snowfield(RES, seed=seed0 + i) for i in range(n)]


def _timed_batch(client: YCHGClient, masks) -> tuple:
    t0 = time.perf_counter()
    items = {it.id: it for it in client.analyze_batch(masks)}
    dt = time.perf_counter() - t0
    bad = [i for i, it in items.items() if not it.ok]
    assert not bad, f"batch failures: {bad}"
    return dt, items


def _identical(items: Dict, want: List[Dict[str, np.ndarray]]) -> bool:
    for i, want_res in enumerate(want):
        got = items[i].result
        for field, arr in want_res.items():
            a, b = np.asarray(arr), got[field]
            if not (np.array_equal(a, b) and a.dtype == b.dtype
                    and a.shape == b.shape):
                return False
    return True


def run_fleet_vs_single(n_workers: int, n_requests: int) -> dict:
    timed = _masks(n_requests, seed0=3000)
    warm = _masks(n_requests, seed0=9000)     # disjoint: warms compiles only
    cores = os.cpu_count() or 1

    cfg = ServiceConfig(bucket_sides=(RES,), max_batch=MAX_BATCH,
                        max_delay_ms=2.0)

    # ---- single-process arm (reference results double as the identity bar)
    with YCHGService(Engine(), cfg) as svc, ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        list(client.analyze_batch(warm))
        single_s, single_items = _timed_batch(client, timed)
    want = [single_items[i].result for i in range(n_requests)]

    # ---- fleet arm: router over n_workers subprocess workers
    worker_args = ["--buckets", str(RES), "--max-batch", str(MAX_BATCH),
                   "--max-delay-ms", "2.0", "--cache-entries", "1024"]
    sup = FleetSupervisor(n_workers, worker_args=worker_args)
    peer_hits = 0.0
    try:
        links = sup.start()
        router = FleetRouter(
            links,
            RouterConfig(bucket_sides=(RES,), max_batch=MAX_BATCH,
                         max_delay_ms=2.0, health_interval_s=3600.0),
            supervisor=sup)
        with RouterThread(router) as rt, \
                YCHGClient("127.0.0.1", rt.port) as client:
            client.wait_ready(timeout=180.0)
            list(client.analyze_batch(warm))
            fleet_s, fleet_items = _timed_batch(client, timed)
            bit_identical = _identical(fleet_items, want)

            # ---- peering leg: kill a mask's owner, reroute (survivor
            # caches it), restart the slot, repeat -> sibling-cache hit
            ring = HashRing([l.name for l in links])
            probe = timed[0]
            owner = ring.node_for(routing_key(probe))
            sup._by_name[owner].process.kill()
            got = client.analyze(probe)                 # reroutes
            assert all(
                np.array_equal(np.asarray(want[0][f]), got[f])
                for f in want[0]), "rerouted result not identical"
            asyncio.run_coroutine_threadsafe(
                router.check_workers(), rt._loop).result(timeout=300)
            client.analyze(probe)                       # restarted owner peers
            for line in client.metrics_text().splitlines():
                if line.startswith("ychg_cache_peer_hits_total "):
                    peer_hits = float(line.rsplit(" ", 1)[1])
    finally:
        sup.stop()

    assert bit_identical, "fleet arm not bit-identical to single process"
    assert peer_hits > 0, "repeat traffic after restart never hit a sibling"

    ratio = round((n_requests / fleet_s) / (n_requests / single_s), 2)
    row = {
        "scenario": "fleet_vs_single",
        "n_requests": n_requests,
        "n_workers": n_workers,
        "cores": cores,
        "resolutions": [RES],
        "single_rps": round(n_requests / single_s, 1),
        "fleet_rps": round(n_requests / fleet_s, 1),
        "fleet_throughput_ratio": ratio,
        "bit_identical": bit_identical,
        "peer_hits": peer_hits,
    }
    if cores >= 4:
        assert ratio >= 2.0, (
            f"router over {n_workers} workers on {cores} cores only "
            f"{ratio}x a single process (bar: 2x)")
    else:
        row["note"] = (
            f"cpu_limited: {cores} core(s) — {n_workers} worker processes "
            "time-slice one CPU, so the >= 2x bar is asserted only on "
            ">= 4 cores; ratio recorded as measured")
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--requests", type=int, default=32)
    args = ap.parse_args()
    row = run_fleet_vs_single(args.workers, args.requests)
    print(json.dumps(row), flush=True)
    report = {
        "bench": "fleet_scaling",
        "platform": jax.default_backend(),
        "backend": Engine().resolve_backend(),
        "note": (
            "fleet_vs_single serves one pool of distinct masks through a "
            "single-process front end and through the fleet router over "
            f"{args.workers} subprocess workers (warm masks disjoint from "
            "timed masks; no timed mask pre-cached). Bit-identity and the "
            "sibling-cache (peering) leg are hard-asserted everywhere; the "
            ">= 2x throughput bar is asserted only when cores >= 4, "
            "recorded as measured (cpu_limited) otherwise."
        ),
        "scenarios": [row],
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} (1 scenario)")


if __name__ == "__main__":
    main()
