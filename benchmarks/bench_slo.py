"""Traffic-class SLO benchmark: priorities, deadlines, tenant quotas.

Three scenario rows, written to ``BENCH_slo.json`` and gated in CI by
``check_bench_regression.py --slo`` (the slo-smoke job):

  **traffic_classes** — the tentpole scenario. A baseline leg measures
  interactive-only closed-loop p95 latency on an idle service; the
  overload leg then floods the service with a batch-class backlog
  offered at ``OFFERED_MULTIPLE``x the batch bucket's admission bound
  (plus a standard-class side stream) and re-measures the SAME
  interactive traffic through the congested service. Strict class
  priority must keep the interactive p95 flat — the row records the
  overload/baseline ratio — while the batch flood sheds against its own
  allowance (``batch_sheds > 0``, hard) and the interactive class sheds
  nothing (``interactive_sheds == 0``, hard). The two legs use disjoint
  mask pools and every mask is unique, so the cache never serves a
  timed request.

  **deadline_shed** — requests submitted with a deadline the admission
  estimator can prove unmeetable are shed at the door with a typed
  error and an honest ``Retry-After``. ``deadline_ms=0`` probes are
  already dead on arrival and shed deterministically (the gate's
  ``min_deadline_sheds`` bar); small-positive-deadline probes against
  the live backlog are recorded as measured (they shed only once the
  drain-rate estimator is warm — a cold estimator never sheds).

  **tenant_quota** — per-tenant token buckets: a tenant with a
  starvation-rate quota spends its burst and is then shed with
  ``Retry-After`` equal to the (clamped) time until its next token,
  while a second tenant and un-tenanted traffic on the same service
  admit freely. Pure token algebra: deterministic on any box.

  **Honesty about cores**: the p95 ratio compares two same-box
  measurements, but on a core-starved box both legs are noise-dominated
  — the row records ``cores`` and the ratio bar is asserted by the gate
  only when ``cores >= 4``; smaller boxes carry a ``cpu_limited`` note
  instead of a fake pass. Shed counts and quota algebra are asserted
  everywhere — they are policy, not speed.

Run:  PYTHONPATH=src python benchmarks/bench_slo.py [--out BENCH_slo.json]
"""

from __future__ import annotations

import argparse
import json
import os
import time
from concurrent.futures import Future
from typing import List

import jax

from repro.data import modis
from repro.engine import Engine
from repro.service import (
    DeadlineExceeded,
    Service,
    ServiceConfig,
    ServiceOverloaded,
    TenantQuotaExceeded,
)

INTERACTIVE_RES = 64       # interactive/standard bucket
BATCH_RES = 128            # the flooded batch bucket (its own bound)
MAX_BATCH = 8
BUCKET_BOUND = 32          # per-bucket admission bound
OFFERED_MULTIPLE = 3       # batch flood = 3x its bucket's bound
N_INTERACTIVE = 16
N_STANDARD = 8


def _masks(res: int, n: int, seed0: int) -> List:
    return [modis.snowfield(res, seed=seed0 + i) for i in range(n)]


def _p95_ms(latencies_s: List[float]) -> float:
    xs = sorted(latencies_s)
    return round(xs[int(0.95 * (len(xs) - 1))] * 1e3, 2)


def _closed_loop_ms(svc: Service, masks: List, klass: str) -> List[float]:
    """Submit one at a time, awaiting each result: per-request wall
    latency through admission, queue, dispatch, and device."""
    out = []
    for m in masks:
        t0 = time.perf_counter()
        svc.submit(m, klass=klass).result()
        out.append(time.perf_counter() - t0)
    return out


def run_traffic_classes(svc: Service) -> dict:
    cores = os.cpu_count() or 1
    # warm both buckets' ladder rungs outside all timing: sequential
    # submits compile rung 1; the concurrent burst compiles the larger
    # rungs the overload flood will use
    for m in _masks(INTERACTIVE_RES, 4, seed0=9000):
        svc.submit(m, klass="interactive").result()
    warm_futs = [svc.submit(m, klass="batch")
                 for m in _masks(BATCH_RES, 2 * MAX_BATCH, seed0=9100)]
    for f in warm_futs:
        f.result()

    # ---- baseline leg: interactive alone on an idle service
    base_lat = _closed_loop_ms(
        svc, _masks(INTERACTIVE_RES, N_INTERACTIVE, seed0=3000),
        "interactive")
    p95_baseline = _p95_ms(base_lat)

    # ---- overload leg: flood batch at OFFERED_MULTIPLE x its bound,
    # add a standard-class side stream, re-measure interactive
    offered_batch = OFFERED_MULTIPLE * BUCKET_BOUND
    batch_futs: List[Future] = []
    batch_shed_client = 0
    for m in _masks(BATCH_RES, offered_batch, seed0=4000):
        try:
            batch_futs.append(svc.submit(m, klass="batch"))
        except ServiceOverloaded:
            batch_shed_client += 1
    std_futs = [svc.submit(m, klass="standard")
                for m in _masks(INTERACTIVE_RES, N_STANDARD, seed0=5000)]
    over_lat = _closed_loop_ms(
        svc, _masks(INTERACTIVE_RES, N_INTERACTIVE, seed0=6000),
        "interactive")
    p95_overload = _p95_ms(over_lat)
    for f in batch_futs + std_futs:
        f.result()

    m = svc.metrics()
    shed_by_class = dict(m.shed_by_class)
    batch_sheds = shed_by_class.get("batch", 0)
    interactive_sheds = shed_by_class.get("interactive", 0)
    assert batch_sheds > 0, (
        f"batch flood of {offered_batch} against bound {BUCKET_BOUND} "
        f"shed nothing — admission control is not engaging")
    assert interactive_sheds == 0, (
        f"{interactive_sheds} interactive sheds — the protected class "
        f"was collateral damage of the batch flood")
    assert batch_shed_client == batch_sheds, (
        f"client saw {batch_shed_client} sheds, service counted "
        f"{batch_sheds}")
    ratio = round(p95_overload / p95_baseline, 2) if p95_baseline else None
    row = {
        "scenario": "traffic_classes",
        "cores": cores,
        "classes": ["interactive", "standard", "batch"],
        "offered_multiple": OFFERED_MULTIPLE,
        "bucket_bound": BUCKET_BOUND,
        "offered_batch": offered_batch,
        "n_interactive": N_INTERACTIVE,
        "n_standard": N_STANDARD,
        "interactive_p95_ms_baseline": p95_baseline,
        "interactive_p95_ms_overload": p95_overload,
        "interactive_p95_ratio": ratio,
        "batch_sheds": batch_sheds,
        "interactive_sheds": interactive_sheds,
        "standard_sheds": shed_by_class.get("standard", 0),
    }
    if cores < 4:
        row["note"] = (
            f"cpu_limited: {cores} core(s) — both legs noise-dominated, "
            "so the p95 ratio bar is asserted only on >= 4 cores; ratio "
            "recorded as measured")
    return row


def run_deadline_shed(svc: Service) -> dict:
    """Probe the deadline gate against whatever backlog the overload leg
    left behind. ``deadline_ms=0`` probes shed deterministically (dead
    on arrival); positive-deadline probes shed only when the warm
    estimator predicts a miss, and are recorded as measured."""
    dead_probes, dead_sheds, retry_after = 4, 0, None
    for m in _masks(INTERACTIVE_RES, dead_probes, seed0=7000):
        try:
            svc.submit(m, klass="batch", deadline_ms=0.0).result()
        except DeadlineExceeded as e:
            dead_sheds += 1
            retry_after = e.retry_after_s
    tight_probes, tight_sheds = 4, 0
    for m in _masks(INTERACTIVE_RES, tight_probes, seed0=7100):
        try:
            svc.submit(m, klass="batch", deadline_ms=1.0).result()
        except DeadlineExceeded:
            tight_sheds += 1
    assert dead_sheds == dead_probes, (
        f"only {dead_sheds}/{dead_probes} dead-on-arrival probes shed")
    return {
        "scenario": "deadline_shed",
        "dead_probes": dead_probes,
        "dead_sheds": dead_sheds,
        "retry_after_s": retry_after,
        "tight_deadline_ms": 1.0,
        "tight_probes": tight_probes,
        "tight_sheds_measured": tight_sheds,
        "deadline_sheds_total": svc.metrics().shed_deadline,
    }


def run_tenant_quota(engine: Engine) -> dict:
    """Token-bucket algebra over a real service: deterministic on any
    box (the starved tenant's refill over the bench's lifetime is
    negligible by construction)."""
    cfg = ServiceConfig(bucket_sides=(INTERACTIVE_RES,),
                        max_batch=MAX_BATCH, max_delay_ms=2.0,
                        tenant_rate=0.001, tenant_burst=4)
    offered, retry_after = 10, None
    with Service(engine, cfg) as svc:
        admitted: List[Future] = []
        sheds = 0
        for m in _masks(INTERACTIVE_RES, offered, seed0=8000):
            try:
                admitted.append(svc.submit(m, tenant="acme"))
            except TenantQuotaExceeded as e:
                sheds += 1
                retry_after = e.retry_after_s
        other = [svc.submit(m, tenant="beta")
                 for m in _masks(INTERACTIVE_RES, 4, seed0=8100)]
        free = [svc.submit(m)
                for m in _masks(INTERACTIVE_RES, 4, seed0=8200)]
        for f in admitted + other + free:
            f.result()
        m = svc.metrics()
        shed_by_tenant = dict(m.shed_by_tenant)
    assert sheds == offered - cfg.tenant_burst, (
        f"tenant burst {cfg.tenant_burst} of {offered} offered should "
        f"shed {offered - cfg.tenant_burst}, shed {sheds}")
    assert shed_by_tenant.get("beta", 0) == 0, (
        "the under-quota tenant was shed")
    return {
        "scenario": "tenant_quota",
        "tenant_rate": cfg.tenant_rate,
        "tenant_burst": cfg.tenant_burst,
        "offered": offered,
        "admitted": cfg.tenant_burst,
        "quota_sheds": sheds,
        "other_tenant_sheds": shed_by_tenant.get("beta", 0),
        "retry_after_s": retry_after,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_slo.json")
    args = ap.parse_args()

    engine = Engine()
    cfg = ServiceConfig(
        bucket_sides=(INTERACTIVE_RES, BATCH_RES), max_batch=MAX_BATCH,
        max_delay_ms=2.0, bucket_queue_depth=BUCKET_BOUND,
        overload_policy="shed")
    rows = []
    with Service(engine, cfg) as svc:
        rows.append(run_traffic_classes(svc))
        print(json.dumps(rows[-1]), flush=True)
        rows.append(run_deadline_shed(svc))
        print(json.dumps(rows[-1]), flush=True)
    rows.append(run_tenant_quota(engine))
    print(json.dumps(rows[-1]), flush=True)

    report = {
        "bench": "traffic_slo",
        "platform": jax.default_backend(),
        "backend": engine.resolve_backend(),
        "note": (
            "traffic_classes floods a batch-class bucket at "
            f"{OFFERED_MULTIPLE}x its admission bound and holds the "
            "interactive closed-loop p95 to its idle-service baseline "
            "(ratio asserted by the gate only on >= 4 cores; sheds "
            "asserted everywhere: batch > 0, interactive == 0). "
            "deadline_shed pins dead-on-arrival sheds and records "
            "warm-estimator sheds as measured. tenant_quota is "
            "deterministic token algebra: burst admitted, the rest shed "
            "with a clamped honest Retry-After, other tenants untouched."
        ),
        "scenarios": rows,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(rows)} scenarios)")


if __name__ == "__main__":
    main()
