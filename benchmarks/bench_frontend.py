"""Load generator for the network front end + the fairness policies.

Two scenario families, written to ``BENCH_frontend.json``:

  **wire_vs_inprocess** — the SAME schedule through (a) in-process
  ``YCHGService.submit`` and (b) the loopback HTTP transport (streamed
  batch + sequential closed-loop round trips), so the wire tax is
  measured directly: batch-throughput ratio and per-request added
  latency. The transport must stay a thin edge, not a second service.

  **fair_vs_unfair_skew** — open-loop traffic offered at 3x measured
  capacity, 1-in-6 requests in a minority bucket and the rest flooding a
  hot bucket, through two admission configurations on one schedule:

    unfair  the PR-4 policy: one bucket-blind global ``max_queue_depth``
            + arrival-order flushes (``fair=False``) — the flood owns the
            queue, so the bound sheds minority requests too;
    fair    per-bucket ``bucket_queue_depth`` + deficit-round-robin
            flushes (``fair=True``) — the flood sheds against its own
            allowance only.

  The acceptance bar (asserted here, recorded in the JSON): under the
  fair policy the minority bucket sheds NOTHING and its client-observed
  p95 stays bounded, while the flooded bucket sheds; under the unfair
  policy the minority bucket demonstrably sheds with the flood.

Run:  PYTHONPATH=src python benchmarks/bench_frontend.py [--out BENCH_frontend.json]
"""

from __future__ import annotations

import argparse
import json
import threading
import time
from typing import Dict, List

import numpy as np

import jax

from repro.data import modis
from repro.engine import Engine
from repro.frontend import ServerThread, YCHGClient
from repro.service import ServiceConfig, ServiceOverloaded, YCHGService


def _pace(t0: float, n: int, rate: float) -> None:
    due = t0 + n / rate
    while True:
        remaining = due - time.perf_counter()
        if remaining <= 0:
            return
        time.sleep(min(1e-3, remaining))


def _warm_rungs(engine: Engine, res: int, max_batch: int = 8) -> None:
    """Compile every sub-batch rung's batch + crop shape outside timing."""
    from repro.service import crop_result, sub_batch_ladder

    for b in sub_batch_ladder(max_batch):
        r = engine.analyze_batch(np.zeros((b, res, res), np.uint8))
        crop_result(r, 0, res).block_until_ready()


# ------------------------------------------------------ wire vs in-process


def run_wire_vs_inprocess() -> dict:
    res, n_requests, pool_size = 128, 48, 8
    pool = [modis.snowfield(res, seed=900 + i) for i in range(pool_size)]
    rng = np.random.default_rng(7)
    schedule = rng.choice(pool_size, size=n_requests)
    engine = Engine()
    cfg = ServiceConfig(bucket_sides=(res,), max_batch=8, max_delay_ms=2.0)

    with YCHGService(engine, cfg) as svc:
        svc.analyze(pool[0], timeout=600)           # warm outside timing
        # in-process arm: submit all, await all (the batch twin)
        t0 = time.perf_counter()
        for f in [svc.submit(pool[i]) for i in schedule]:
            f.result(timeout=600)
        inproc_batch_s = time.perf_counter() - t0
        # in-process sequential arm: per-request closed loop
        t0 = time.perf_counter()
        for i in schedule[:16]:
            svc.analyze(pool[i], timeout=600)
        inproc_seq_ms = (time.perf_counter() - t0) / 16 * 1e3

    with YCHGService(engine, cfg) as svc, ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        client.analyze(pool[0])                     # warm (incl. keep-alive)
        t0 = time.perf_counter()
        items = list(client.analyze_batch([pool[i] for i in schedule]))
        wire_batch_s = time.perf_counter() - t0
        assert all(it.ok for it in items), "wire batch had failures"
        t0 = time.perf_counter()
        for i in schedule[:16]:
            client.analyze(pool[i])
        wire_seq_ms = (time.perf_counter() - t0) / 16 * 1e3

    return {
        "scenario": "wire_vs_inprocess",
        "n_requests": n_requests,
        "resolutions": [res],
        "inprocess_rps": round(n_requests / inproc_batch_s, 1),
        "wire_rps": round(n_requests / wire_batch_s, 1),
        "wire_throughput_ratio": round(inproc_batch_s / wire_batch_s, 2),
        "inprocess_seq_ms": round(inproc_seq_ms, 3),
        "wire_seq_ms": round(wire_seq_ms, 3),
        "wire_overhead_ms_per_request": round(wire_seq_ms - inproc_seq_ms, 3),
    }


# ------------------------------------------------------ fair vs unfair skew


def _run_skew_arm(engine: Engine, knobs: dict,
                  requests: List[tuple], rate: float) -> dict:
    """One admission policy under the shared skewed open-loop schedule.

    ``requests`` is [(kind, mask), ...] with every mask DISTINCT — repeat
    masks would coalesce onto in-flight leaders (consuming no queue slot)
    and the admission bounds would never engage.
    """
    base = dict(bucket_sides=(64, 128), max_batch=8, max_delay_ms=2.0,
                cache_entries=0, overload_policy="shed")
    shed = {"minority": 0, "flood": 0}
    latencies: Dict[str, list] = {"minority": [], "flood": []}
    lock = threading.Lock()
    with YCHGService(engine, ServiceConfig(**base, **knobs)) as svc:
        futures = []
        t0 = time.perf_counter()
        for n, (kind, mask) in enumerate(requests):
            _pace(t0, n, rate)
            try:
                fut = svc.submit(mask)
            except ServiceOverloaded:
                shed[kind] += 1
                continue

            # stamp completion in the done callback: awaiting futures in
            # submit order would charge each request for every slower
            # predecessor and corrupt the per-bucket percentiles
            def _stamp(f, kind=kind, t_sub=time.perf_counter()):
                lat = (time.perf_counter() - t_sub) * 1e3
                with lock:
                    latencies[kind].append(lat)

            fut.add_done_callback(_stamp)
            futures.append(fut)
        for fut in futures:
            fut.result(timeout=600)
    out = {}
    for kind in ("minority", "flood"):
        lat = np.asarray(latencies[kind])
        out[f"{kind}_served"] = int(lat.size)
        out[f"{kind}_shed"] = shed[kind]
        out[f"{kind}_p95_ms"] = (round(float(np.percentile(lat, 95)), 3)
                                 if lat.size else None)
    return out


def run_fair_vs_unfair_skew() -> dict:
    n_requests = 120
    # 1 in 6 requests is minority traffic; deterministic interleave; every
    # mask distinct so nothing coalesces and admission truly engages
    requests = [
        ("minority" if n % 6 == 0 else "flood",
         modis.snowfield(64 if n % 6 == 0 else 128, seed=1000 + n))
        for n in range(n_requests)
    ]
    engine = Engine()
    # compile every ladder rung (batch + crop) for both buckets up front
    for res in (64, 128):
        _warm_rungs(engine, res)
    # probe flood-bucket capacity closed-loop on distinct masks, offer 3x
    probe = [modis.snowfield(128, seed=2000 + i) for i in range(24)]
    with YCHGService(engine, ServiceConfig(
            bucket_sides=(64, 128), max_batch=8, max_delay_ms=2.0,
            cache_entries=0)) as svc:
        svc.analyze(probe[0], timeout=600)
        t0 = time.perf_counter()
        for f in [svc.submit(m) for m in probe]:
            f.result(timeout=600)
        capacity_rps = 24 / (time.perf_counter() - t0)
    rate = 3.0 * capacity_rps
    out = {"scenario": "fair_vs_unfair_skew", "n_requests": n_requests,
           "resolutions": [64, 128],
           "traffic": "open-loop 3x capacity, 1-in-6 minority (64), "
                      "rest flood (128)",
           "capacity_rps": round(capacity_rps, 1),
           "offered_rps": round(rate, 1)}
    arms = (
        # PR-4 policy: bucket-blind global bound, arrival-order flushes
        ("unfair", {"max_queue_depth": 16, "fair": False}),
        # this PR: per-bucket bounds + deficit-round-robin flushes
        ("fair", {"bucket_queue_depth": 24, "fair": True}),
    )
    for label, knobs in arms:
        arm = _run_skew_arm(engine, knobs, requests, rate)
        for k, v in arm.items():
            out[f"{label}_{k}"] = v
    # the acceptance bar: fairness isolates the minority bucket completely
    assert out["fair_minority_shed"] == 0, out
    assert out["fair_flood_shed"] > 0, out          # the flood still sheds
    assert out["unfair_minority_shed"] > 0, out     # bucket-blind shed it
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_frontend.json")
    args = ap.parse_args()
    rows = [run_wire_vs_inprocess(), run_fair_vs_unfair_skew()]
    for row in rows:
        print(json.dumps(row), flush=True)
    report = {
        "bench": "frontend_load_sweep",
        "platform": jax.default_backend(),
        "backend": Engine().resolve_backend(),
        "note": (
            "wire_vs_inprocess drives one schedule through in-process "
            "submit and through loopback HTTP (streamed batch + "
            "per-request closed loop) — the wire tax, measured; "
            "fair_vs_unfair_skew offers 3x-capacity open-loop traffic, "
            "1-in-6 minority-bucket, under the PR-4 bucket-blind global "
            "bound with arrival-order flushes vs per-bucket bounds with "
            "deficit-round-robin: fairness must keep minority sheds at "
            "ZERO (and its p95 bounded) while the flood sheds"
        ),
        "scenarios": rows,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(rows)} scenarios)")


if __name__ == "__main__":
    main()
