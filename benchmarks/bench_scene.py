"""Scene-scale streaming benchmark: stitched strips vs the whole scene.

Two scenario families, written to ``BENCH_scene.json``:

  **scene_stitch** — one synthetic granule analysed three ways: (a)
  whole-scene, one ``engine.analyze`` call on the full (H, W) mask (the
  ceiling when the scene *fits*); (b) streamed, ``SceneRunner`` over
  ``tile_h``-row strips in stacks of ``stack_tiles`` with exact seam
  stitching — the only arm that works when the scene does not fit; (c)
  per-tile-naive, one ``engine.analyze`` call per strip (what tiling
  costs without batching). Records Mpx/s for all three, the
  stitched/whole and stitched/naive ratios, and a ``bit_identical``
  verdict comparing every stitched field against the whole-scene arm —
  the number that makes the speed numbers mean anything.

  **checkpoint_overhead** — the same manifest run as a ``BulkJob`` twice:
  checkpointing every stack (the paranoid setting) vs only at granule
  boundaries. Records the elapsed ratio as
  ``checkpoint_overhead_fraction`` — the price of kill-anywhere
  resumability at its most aggressive — plus a ``resume_bit_identical``
  verdict from an interrupt-and-resume pass compared byte-for-byte
  against the uninterrupted output files.

  Both gated ratios are *same-box relative* (stitched vs naive, per-stack
  checkpointing vs none), so they hold on any machine; ``cores`` is
  recorded for context, and a recording made under pathological
  conditions can carry a ``cpu_limited`` note the gate honours instead of
  its ratio bars (``check_bench_regression.py --scene``). The
  ``bit_identical`` / ``resume_bit_identical`` verdicts have no escape.

Run:  PYTHONPATH=src python benchmarks/bench_scene.py [--out BENCH_scene.json]
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time
from typing import List

import numpy as np

import jax

from repro.data import scenes
from repro.engine import Engine
from repro.scene import (
    BulkJob,
    BulkJobConfig,
    GranuleReader,
    SceneRunner,
    synthetic_manifest,
)


def _best_of(n: int, fn) -> float:
    """Best wall time of n calls — rewards steady state, tolerates noise."""
    return min(_timed(fn) for _ in range(n))


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _identical(got: dict, want: dict) -> bool:
    for field, arr in want.items():
        a, b = np.asarray(arr), np.asarray(got[field])
        if not (np.array_equal(a, b) and a.dtype == b.dtype
                and a.shape == b.shape):
            return False
    return True


def run_scene_stitch(height: int, width: int, tile_h: int,
                     stack_tiles: int, repeats: int) -> dict:
    engine = Engine()
    mask = scenes.scene(height, width, seed=42, cell=64)
    reader = GranuleReader.from_array(mask, tile_h, granule_id="bench")
    runner = SceneRunner(engine, stack_tiles=stack_tiles)
    px = height * width

    def whole():
        # to_host() so the timing includes materialising the result, like
        # the stitched/naive arms do (asynchronous dispatch would
        # otherwise flatter this arm enormously)
        return engine.analyze(mask).to_host()

    def streamed():
        return runner.analyze_scene(reader)

    def naive():
        # one device call per strip, stitched the same exact way
        state = None
        from repro.scene import SceneState
        state = SceneState.fresh(reader.width)
        for t in range(reader.n_tiles):
            tile = reader.read_tile(t)
            res = engine.analyze(tile)
            runner.update(state, tile[None], np.asarray(res.runs))
        return runner.finalize(reader, state)

    whole(), streamed(), naive()          # warmup: compile all three shapes
    t_whole = _best_of(repeats, whole)
    t_stream = _best_of(repeats, streamed)
    t_naive = _best_of(repeats, naive)
    bit_identical = _identical(streamed().to_host(), whole())
    return {
        "scenario": "scene_stitch",
        "height": height,
        "width": width,
        "tile_h": tile_h,
        "stack_tiles": stack_tiles,
        "n_tiles": reader.n_tiles,
        "cores": os.cpu_count() or 1,
        "whole_scene_mpx_s": round(px / t_whole / 1e6, 1),
        "stitched_mpx_s": round(px / t_stream / 1e6, 1),
        "per_tile_naive_mpx_s": round(px / t_naive / 1e6, 1),
        "stitched_vs_whole_ratio": round(t_whole / t_stream, 3),
        "stitched_vs_naive_ratio": round(t_naive / t_stream, 3),
        "bit_identical": bool(bit_identical),
    }


def run_checkpoint_overhead(height: int, width: int, tile_h: int,
                            stack_tiles: int, n_granules: int) -> dict:
    engine = Engine()
    manifest = synthetic_manifest(n_granules, height, width, seed=7,
                                  cell=64)
    px = n_granules * height * width

    def run_job(tmp: str, tag: str, every: int, **kw) -> "tuple":
        job = BulkJob(engine, manifest, BulkJobConfig(
            out_dir=os.path.join(tmp, tag, "out"),
            ckpt_dir=os.path.join(tmp, tag, "ckpt"),
            tile_h=tile_h, stack_tiles=stack_tiles,
            checkpoint_every=every))
        return job, job.run(**kw)

    with tempfile.TemporaryDirectory() as tmp:
        run_job(tmp, "warm", 10**9)       # warmup: compile the stack shape
        _, r_none = run_job(tmp, "none", 10**9)   # boundary ckpts only
        _, r_every = run_job(tmp, "every", 1)     # ckpt per stack
        overhead = (r_every.elapsed_s - r_none.elapsed_s) / r_none.elapsed_s

        # resume verdict: interrupt the per-stack job mid-manifest,
        # resume, and compare output bytes against the "none" arm
        kill_job, first = run_job(tmp, "kill", 1,
                                  max_stacks=max(1, r_every.stacks_done // 2))
        _, second = run_job(tmp, "kill", 1)
        resume_ok = (first.status == "interrupted" and second.completed
                     and second.resumes == 1)
        if resume_ok:
            for spec in manifest:
                a = os.path.join(tmp, "none", "out",
                                 f"{spec.granule_id}.ychg")
                b = kill_job.output_path(spec)
                with open(a, "rb") as fa, open(b, "rb") as fb:
                    if fa.read() != fb.read():
                        resume_ok = False
                        break
    return {
        "scenario": "checkpoint_overhead",
        "n_granules": n_granules,
        "height": height,
        "width": width,
        "tile_h": tile_h,
        "stack_tiles": stack_tiles,
        "cores": os.cpu_count() or 1,
        "no_ckpt_mpx_s": round(px / r_none.elapsed_s / 1e6, 1),
        "ckpt_every_stack_mpx_s": round(px / r_every.elapsed_s / 1e6, 1),
        "checkpoint_overhead_fraction": round(max(0.0, overhead), 3),
        "resume_bit_identical": bool(resume_ok),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_scene.json")
    ap.add_argument("--height", type=int, default=8192)
    ap.add_argument("--width", type=int, default=2048)
    ap.add_argument("--tile-h", type=int, default=512)
    ap.add_argument("--stack", type=int, default=4)
    ap.add_argument("--granules", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()

    scenarios: List[dict] = []
    print(f"scene_stitch: {args.height}x{args.width}, tile_h {args.tile_h}, "
          f"stacks of {args.stack} [{jax.default_backend()}]", flush=True)
    row = run_scene_stitch(args.height, args.width, args.tile_h,
                           args.stack, args.repeats)
    scenarios.append(row)
    print(f"  whole {row['whole_scene_mpx_s']} Mpx/s, stitched "
          f"{row['stitched_mpx_s']} Mpx/s "
          f"({row['stitched_vs_whole_ratio']}x whole, "
          f"{row['stitched_vs_naive_ratio']}x naive), naive "
          f"{row['per_tile_naive_mpx_s']} Mpx/s, "
          f"bit_identical={row['bit_identical']}", flush=True)

    print(f"checkpoint_overhead: {args.granules} granules of "
          f"{args.height}x{args.width}", flush=True)
    row = run_checkpoint_overhead(args.height, args.width, args.tile_h,
                                  args.stack, args.granules)
    scenarios.append(row)
    print(f"  no-ckpt {row['no_ckpt_mpx_s']} Mpx/s, per-stack ckpt "
          f"{row['ckpt_every_stack_mpx_s']} Mpx/s (overhead "
          f"{row['checkpoint_overhead_fraction']:.1%}), "
          f"resume_bit_identical={row['resume_bit_identical']}", flush=True)

    report = {
        "bench": "scene_streaming",
        "platform": jax.default_backend(),
        "backend": "auto",
        "note": (
            "scene_stitch analyses one synthetic granule whole, streamed "
            "(SceneRunner strips + exact seam stitching), and "
            "per-tile-naive; bit_identical compares every stitched field "
            "against the whole-scene call. checkpoint_overhead runs the "
            "same manifest checkpointing every stack vs boundaries only, "
            "and proves interrupt->resume writes byte-identical outputs. "
            "Gated ratios are same-box relative; bit-identity verdicts "
            "have no escape hatch."
        ),
        "scenarios": scenarios,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
