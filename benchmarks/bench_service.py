"""Synthetic load generator + sweep for the yCHG ROI service.

Each scenario builds a mask pool (`data.modis.snowfield`/`striped`), draws a
request schedule over it (unique traffic, zipf-ish repeated traffic, mixed
resolutions, optionally paced to an open-loop arrival rate), then drives the
SAME schedule through two paths:

  naive    one blocking ``engine.analyze(mask)`` per request, in order —
           the pre-service serving strategy (what launch/serve.py used to
           approximate with one hand-built batch);
  service  ``YCHGService.submit`` per request, futures awaited at the end —
           micro-batching + bucket padding + result cache + overlap.

Both paths are warmed first (compile time is a separate, known cost — see
``launch/serve.py``'s cold/warm split), so the comparison is steady-state.
Per scenario we record naive/service throughput, speedup, p50/p95 latency,
cache hit rate, Mpx/s, and the compiled-shape count, and write the table to
``BENCH_service.json`` for later PRs to track.

Run:  PYTHONPATH=src python benchmarks/bench_service.py [--out BENCH_service.json]

``--quick`` swaps in a seconds-not-minutes scenario set (same shapes of
traffic, smaller pools/schedules) shared by the CI ``bench-gate`` job and
local smoke runs: the committed ``BENCH_service.json`` carries the quick
baselines under ``"quick"`` plus the gate's tolerances under ``"gate"``,
and ``benchmarks/check_bench_regression.py`` fails CI when a fresh quick
run regresses past them.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import List, Optional, Sequence

import numpy as np

import jax

from repro.data import modis
from repro.engine import Engine
from repro.service import (
    ServiceConfig,
    ServiceOverloaded,
    YCHGService,
    sub_batch_ladder,
)


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    resolutions: Sequence[int]     # pool mask sides (mixed-res traffic)
    pool_size: int                 # distinct masks in the pool
    n_requests: int
    repeat_alpha: Optional[float]  # zipf-ish skew; None = all-unique schedule
    rate: Optional[float] = None   # open-loop arrivals/s; None = closed-loop
    seed: int = 0


SCENARIOS = (
    # the acceptance scenario: repeated-mask traffic, closed loop
    Scenario("repeat_small", (128,), pool_size=8, n_requests=160,
             repeat_alpha=1.2),
    # worst case for the cache: every request distinct
    Scenario("unique_small", (128,), pool_size=160, n_requests=160,
             repeat_alpha=None),
    # mixed resolutions exercise the bucket ladder + striped masks the
    # hyperedge-count invariance (paper knob (b)) inside the pool
    Scenario("mixed_res", (64, 128, 256), pool_size=24, n_requests=120,
             repeat_alpha=1.0),
    # paced open-loop traffic: latency under a sustainable arrival rate
    Scenario("paced_repeat", (128,), pool_size=8, n_requests=100,
             repeat_alpha=1.2, rate=200.0),
)

# the --quick set: same traffic shapes, schedules small enough for CI
# (seconds, warmup included) — these are what the bench-gate compares
QUICK_SCENARIOS = (
    Scenario("repeat_small", (128,), pool_size=6, n_requests=48,
             repeat_alpha=1.2),
    Scenario("unique_small", (128,), pool_size=48, n_requests=48,
             repeat_alpha=None),
    Scenario("mixed_res", (64, 128), pool_size=12, n_requests=36,
             repeat_alpha=1.0),
)


def build_pool(sc: Scenario) -> List[np.ndarray]:
    rng = np.random.default_rng(sc.seed)
    pool = []
    for i in range(sc.pool_size):
        res = sc.resolutions[i % len(sc.resolutions)]
        if i % 3 == 2:  # striped masks pin an exact hyperedge count
            pool.append(modis.striped(res, int(rng.integers(10, 200))))
        else:
            pool.append(modis.snowfield(res, seed=sc.seed * 1000 + i))
    return pool


def build_schedule(sc: Scenario, rng: np.random.Generator) -> np.ndarray:
    if sc.repeat_alpha is None:
        assert sc.pool_size >= sc.n_requests
        return rng.permutation(sc.n_requests)
    weights = 1.0 / np.arange(1, sc.pool_size + 1) ** sc.repeat_alpha
    return rng.choice(sc.pool_size, size=sc.n_requests, p=weights / weights.sum())


def run_naive(engine: Engine, pool, schedule, rate) -> float:
    """Per-request blocking engine.analyze over the schedule; returns rps."""
    t0 = time.perf_counter()
    for n, i in enumerate(schedule):
        if rate is not None:
            _pace(t0, n, rate)
        engine.analyze(pool[i]).block_until_ready()
    return len(schedule) / (time.perf_counter() - t0)


def run_service(svc: YCHGService, pool, schedule, rate) -> float:
    t0 = time.perf_counter()
    futures = []
    for n, i in enumerate(schedule):
        if rate is not None:
            _pace(t0, n, rate)
        futures.append(svc.submit(pool[i]))
    for f in futures:
        f.result(timeout=600)
    return len(schedule) / (time.perf_counter() - t0)


def _pace(t0: float, n: int, rate: float) -> None:
    due = t0 + n / rate
    while True:
        remaining = due - time.perf_counter()
        if remaining <= 0:
            return
        time.sleep(min(1e-3, remaining))


def _warm_rungs(engine: Engine, res: int, max_batch: int = 8) -> None:
    """Compile every sub-batch ladder rung's batch computation AND the
    service's per-request crop fan-out for it, outside any timed region."""
    from repro.service import crop_result

    for b in sub_batch_ladder(max_batch):
        r = engine.analyze_batch(np.zeros((b, res, res), np.uint8))
        crop_result(r, 0, res).block_until_ready()


def run_scenario(sc: Scenario) -> dict:
    pool = build_pool(sc)
    schedule = build_schedule(sc, np.random.default_rng(sc.seed + 1))
    sides = tuple(sorted(set(sc.resolutions)))
    max_batch = 8
    engine = Engine()
    svc = YCHGService(engine, ServiceConfig(bucket_sides=sides,
                                            max_batch=max_batch,
                                            max_delay_ms=2.0))
    with svc:
        # warm both paths: compile each distinct shape once, outside timing.
        # The service now dispatches (b, side, side) for every sub-batch
        # ladder rung b — and fans out through a (b, side)-shaped crop —
        # so warm each rung's batch AND crop, not just the full batch.
        for res in sides:
            warm = pool[next(i for i, m in enumerate(pool)
                             if m.shape[0] == res)]
            engine.analyze(warm).block_until_ready()
            svc.submit(warm).result(timeout=600)
            _warm_rungs(engine, res, max_batch)
        naive_rps = run_naive(engine, pool, schedule, sc.rate)
        service_rps = run_service(svc, pool, schedule, sc.rate)
        m = svc.metrics()
    row = {
        "scenario": sc.name,
        "n_requests": sc.n_requests,
        "resolutions": list(sides),
        "traffic": "unique" if sc.repeat_alpha is None
        else f"zipf(a={sc.repeat_alpha})",
        "rate_rps": sc.rate,
        "naive_rps": round(naive_rps, 1),
        "service_rps": round(service_rps, 1),
        "speedup": round(service_rps / naive_rps, 2),
        "p50_latency_ms": round(m.p50_latency_ms, 3),
        "p95_latency_ms": round(m.p95_latency_ms, 3),
        "cache_hit_rate": round(m.hit_rate, 3),
        "coalesced": m.coalesced,
        "mpx_per_s": round(m.mpx_per_s, 2),
        "compiled_shapes": m.n_compiled_shapes,
        "shape_budget": len(sides) * len(sub_batch_ladder(max_batch)),
        "pad_fraction": round(m.pad_fraction, 3),
    }
    # acceptance bar: bucket ladder x sub-batch ladder bounds the shapes
    assert m.n_compiled_shapes <= len(sides) * len(sub_batch_ladder(max_batch)), row
    return row


def run_low_occupancy(pool_size: int = 24) -> dict:
    """Closed-loop B=1 traffic (submit one, await it, submit the next):
    every flush has occupancy 1, the worst case for pad-to-max_batch. The
    SAME schedule runs under sub-bucket padding and under the old
    pad-to-max policy; sub-buckets must dispatch ~max_batch x fewer pixels
    (pad_fraction) and be no slower end to end."""
    res, max_batch = 128, 8
    pool = [modis.snowfield(res, seed=500 + i) for i in range(pool_size)]
    out = {"scenario": "low_occupancy", "n_requests": len(pool),
           "resolutions": [res], "traffic": "closed-loop B=1",
           "max_batch": max_batch}
    for label, sub in (("sub_buckets", True), ("pad_to_max", False)):
        cfg = ServiceConfig(bucket_sides=(res,), max_batch=max_batch,
                            max_delay_ms=2.0, cache_entries=0,
                            sub_batches=sub)
        with YCHGService(Engine(), cfg) as svc:
            svc.analyze(pool[0], timeout=600)   # warm: compile outside timing
            t0 = time.perf_counter()
            for m in pool:
                svc.analyze(m, timeout=600)
            dt = time.perf_counter() - t0
            met = svc.metrics()
        out[f"{label}_rps"] = round(len(pool) / dt, 1)
        out[f"{label}_pad_fraction"] = round(met.pad_fraction, 3)
        out[f"{label}_p95_latency_ms"] = round(met.p95_latency_ms, 3)
    out["speedup_sub_vs_padmax"] = round(
        out["sub_buckets_rps"] / out["pad_to_max_rps"], 2)
    # the acceptance bar: strictly less pad compute, no slower end to end
    # (5% wall-clock tolerance: at this size the delay window dominates
    # both arms, so "no slower" means within run-to-run noise)
    assert out["sub_buckets_pad_fraction"] < out["pad_to_max_pad_fraction"], out
    assert out["speedup_sub_vs_padmax"] >= 0.95, out
    return out


def run_overload() -> dict:
    """Open-loop traffic offered well past capacity. Unbounded queue: every
    request is admitted and p95 balloons with the backlog. Bounded queue
    with overload_policy="shed": excess submits fail fast with
    ServiceOverloaded, and the p95 of what IS served stays flat."""
    res, n_requests = 128, 120
    pool = [modis.snowfield(res, seed=700 + i) for i in range(n_requests)]
    base = dict(bucket_sides=(res,), max_batch=8, max_delay_ms=2.0,
                cache_entries=0)
    # compile every ladder rung (batch + crop) once, outside every
    # measurement below
    _warm_rungs(Engine(), res)
    # probe steady-state capacity, then offer a multiple of it
    with YCHGService(Engine(), ServiceConfig(**base)) as svc:
        svc.analyze(pool[0], timeout=600)
        t0 = time.perf_counter()
        for f in [svc.submit(m) for m in pool[:40]]:
            f.result(timeout=600)
        capacity_rps = 40 / (time.perf_counter() - t0)
    rate = 3.0 * capacity_rps
    out = {"scenario": "overload", "n_requests": n_requests,
           "resolutions": [res], "traffic": "open-loop 3x capacity",
           "capacity_rps": round(capacity_rps, 1),
           "offered_rps": round(rate, 1)}
    for label, knobs in (
        ("unbounded", {}),
        ("bounded_shed", {"max_queue_depth": 16, "overload_policy": "shed"}),
    ):
        shed = 0
        with YCHGService(Engine(),
                         ServiceConfig(**base, **knobs)) as svc:
            svc.analyze(pool[0], timeout=600)
            futures = []
            t0 = time.perf_counter()
            for n, m in enumerate(pool):
                _pace(t0, n, rate)
                try:
                    futures.append(svc.submit(m))
                except ServiceOverloaded:
                    shed += 1
            for f in futures:
                f.result(timeout=600)
            met = svc.metrics()
        out[f"{label}_p95_latency_ms"] = round(met.p95_latency_ms, 3)
        out[f"{label}_served"] = len(futures)
        if knobs:
            out[f"{label}_shed"] = shed
            assert shed > 0 and shed == met.shed, out   # admission worked
    # the acceptance bar: a bounded queue keeps tail latency flat under
    # the same offered load, at the price of shedding the excess
    assert (out["bounded_shed_p95_latency_ms"]
            <= out["unbounded_p95_latency_ms"]), out
    return out


EXTRA_SCENARIOS = {
    "low_occupancy": run_low_occupancy,
    "overload": run_overload,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_service.json")
    ap.add_argument("--scenario", default=None,
                    help="run a single scenario by name")
    ap.add_argument("--quick", action="store_true",
                    help="the small scenario set the CI bench-gate runs "
                         "(seconds, not minutes); writes mode='quick'")
    args = ap.parse_args()
    scenarios = QUICK_SCENARIOS if args.quick else SCENARIOS
    extras = (
        {"low_occupancy": lambda: run_low_occupancy(pool_size=10)}
        if args.quick else EXTRA_SCENARIOS
    )
    rows = []
    for sc in scenarios:
        if args.scenario and sc.name != args.scenario:
            continue
        row = run_scenario(sc)
        rows.append(row)
        print(json.dumps(row), flush=True)
    for name, runner in extras.items():
        if args.scenario and name != args.scenario:
            continue
        row = runner()
        rows.append(row)
        print(json.dumps(row), flush=True)
    report = {
        "bench": "service_load_sweep",
        "mode": "quick" if args.quick else "full",
        "platform": jax.default_backend(),
        "backend": Engine().resolve_backend(),
        "note": (
            "steady-state (both paths warmed); naive = blocking per-request "
            "engine.analyze on the same schedule; latency percentiles are "
            "service submit->ready times (compute misses only — cache hits "
            "are excluded from the window); low_occupancy compares sub-"
            "bucket padding vs pad-to-max_batch on one schedule; overload "
            "offers 3x capacity open-loop, unbounded vs bounded+shed"
        ),
        "scenarios": rows,
    }
    # re-recording over an existing baseline must not destroy the CI
    # bench-gate's contract: a full re-run carries the committed "quick"
    # baselines and "gate" tolerances forward, and a quick re-run aimed at
    # the baseline file refreshes ONLY its "quick" section (never clobbers
    # the full table). Point --out at a fresh path for a standalone report.
    try:
        with open(args.out) as f:
            existing = json.load(f)
    except (OSError, ValueError):
        existing = None
    if existing is not None:
        if args.quick and existing.get("mode") != "quick":
            existing["quick"] = {
                "note": existing.get("quick", {}).get(
                    "note", "baselines for the CI bench-gate"),
                "scenarios": rows,
            }
            report = existing
        elif not args.quick:
            for section in ("quick", "gate"):
                if section in existing:
                    report[section] = existing[section]
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(rows)} scenarios)")


if __name__ == "__main__":
    main()
