"""Synthetic load generator + sweep for the yCHG ROI service.

Each scenario builds a mask pool (`data.modis.snowfield`/`striped`), draws a
request schedule over it (unique traffic, zipf-ish repeated traffic, mixed
resolutions, optionally paced to an open-loop arrival rate), then drives the
SAME schedule through two paths:

  naive    one blocking ``engine.analyze(mask)`` per request, in order —
           the pre-service serving strategy (what launch/serve.py used to
           approximate with one hand-built batch);
  service  ``YCHGService.submit`` per request, futures awaited at the end —
           micro-batching + bucket padding + result cache + overlap.

Both paths are warmed first (compile time is a separate, known cost — see
``launch/serve.py``'s cold/warm split), so the comparison is steady-state.
Per scenario we record naive/service throughput, speedup, p50/p95 latency,
cache hit rate, Mpx/s, and the compiled-shape count, and write the table to
``BENCH_service.json`` for later PRs to track.

Run:  PYTHONPATH=src python benchmarks/bench_service.py [--out BENCH_service.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import List, Optional, Sequence

import numpy as np

import jax

from repro.data import modis
from repro.engine import YCHGEngine
from repro.service import ServiceConfig, YCHGService


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    resolutions: Sequence[int]     # pool mask sides (mixed-res traffic)
    pool_size: int                 # distinct masks in the pool
    n_requests: int
    repeat_alpha: Optional[float]  # zipf-ish skew; None = all-unique schedule
    rate: Optional[float] = None   # open-loop arrivals/s; None = closed-loop
    seed: int = 0


SCENARIOS = (
    # the acceptance scenario: repeated-mask traffic, closed loop
    Scenario("repeat_small", (128,), pool_size=8, n_requests=160,
             repeat_alpha=1.2),
    # worst case for the cache: every request distinct
    Scenario("unique_small", (128,), pool_size=160, n_requests=160,
             repeat_alpha=None),
    # mixed resolutions exercise the bucket ladder + striped masks the
    # hyperedge-count invariance (paper knob (b)) inside the pool
    Scenario("mixed_res", (64, 128, 256), pool_size=24, n_requests=120,
             repeat_alpha=1.0),
    # paced open-loop traffic: latency under a sustainable arrival rate
    Scenario("paced_repeat", (128,), pool_size=8, n_requests=100,
             repeat_alpha=1.2, rate=200.0),
)


def build_pool(sc: Scenario) -> List[np.ndarray]:
    rng = np.random.default_rng(sc.seed)
    pool = []
    for i in range(sc.pool_size):
        res = sc.resolutions[i % len(sc.resolutions)]
        if i % 3 == 2:  # striped masks pin an exact hyperedge count
            pool.append(modis.striped(res, int(rng.integers(10, 200))))
        else:
            pool.append(modis.snowfield(res, seed=sc.seed * 1000 + i))
    return pool


def build_schedule(sc: Scenario, rng: np.random.Generator) -> np.ndarray:
    if sc.repeat_alpha is None:
        assert sc.pool_size >= sc.n_requests
        return rng.permutation(sc.n_requests)
    weights = 1.0 / np.arange(1, sc.pool_size + 1) ** sc.repeat_alpha
    return rng.choice(sc.pool_size, size=sc.n_requests, p=weights / weights.sum())


def run_naive(engine: YCHGEngine, pool, schedule, rate) -> float:
    """Per-request blocking engine.analyze over the schedule; returns rps."""
    t0 = time.perf_counter()
    for n, i in enumerate(schedule):
        if rate is not None:
            _pace(t0, n, rate)
        engine.analyze(pool[i]).block_until_ready()
    return len(schedule) / (time.perf_counter() - t0)


def run_service(svc: YCHGService, pool, schedule, rate) -> float:
    t0 = time.perf_counter()
    futures = []
    for n, i in enumerate(schedule):
        if rate is not None:
            _pace(t0, n, rate)
        futures.append(svc.submit(pool[i]))
    for f in futures:
        f.result(timeout=600)
    return len(schedule) / (time.perf_counter() - t0)


def _pace(t0: float, n: int, rate: float) -> None:
    due = t0 + n / rate
    while True:
        remaining = due - time.perf_counter()
        if remaining <= 0:
            return
        time.sleep(min(1e-3, remaining))


def run_scenario(sc: Scenario) -> dict:
    pool = build_pool(sc)
    schedule = build_schedule(sc, np.random.default_rng(sc.seed + 1))
    sides = tuple(sorted(set(sc.resolutions)))
    engine = YCHGEngine()
    svc = YCHGService(engine, ServiceConfig(bucket_sides=sides, max_batch=8,
                                            max_delay_ms=2.0))
    with svc:
        # warm both paths: compile each distinct shape once, outside timing
        for res in sides:
            warm = pool[next(i for i, m in enumerate(pool)
                             if m.shape[0] == res)]
            engine.analyze(warm).block_until_ready()
            svc.submit(warm).result(timeout=600)
        naive_rps = run_naive(engine, pool, schedule, sc.rate)
        service_rps = run_service(svc, pool, schedule, sc.rate)
        m = svc.metrics()
    row = {
        "scenario": sc.name,
        "n_requests": sc.n_requests,
        "resolutions": list(sides),
        "traffic": "unique" if sc.repeat_alpha is None
        else f"zipf(a={sc.repeat_alpha})",
        "rate_rps": sc.rate,
        "naive_rps": round(naive_rps, 1),
        "service_rps": round(service_rps, 1),
        "speedup": round(service_rps / naive_rps, 2),
        "p50_latency_ms": round(m.p50_latency_ms, 3),
        "p95_latency_ms": round(m.p95_latency_ms, 3),
        "cache_hit_rate": round(m.hit_rate, 3),
        "coalesced": m.coalesced,
        "mpx_per_s": round(m.mpx_per_s, 2),
        "compiled_shapes": m.n_compiled_shapes,
        "bucket_budget": len(sides),
        "pad_fraction": round(m.pad_fraction, 3),
    }
    assert m.n_compiled_shapes <= len(sides), row  # acceptance bar
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_service.json")
    ap.add_argument("--scenario", default=None,
                    help="run a single scenario by name")
    args = ap.parse_args()
    rows = []
    for sc in SCENARIOS:
        if args.scenario and sc.name != args.scenario:
            continue
        row = run_scenario(sc)
        rows.append(row)
        print(json.dumps(row), flush=True)
    report = {
        "bench": "service_load_sweep",
        "platform": jax.default_backend(),
        "backend": YCHGEngine().resolve_backend(),
        "note": (
            "steady-state (both paths warmed); naive = blocking per-request "
            "engine.analyze on the same schedule; latency percentiles are "
            "service submit->ready times"
        ),
        "scenarios": rows,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out} ({len(rows)} scenarios)")


if __name__ == "__main__":
    main()
