"""`FleetRouter` — one HTTP front end over N worker processes.

The router owns no engine: it consistent-hashes each request's
process-stable serialized cache key onto a worker slot
(:mod:`repro.fleet.hashring`) and forwards the client's *original encoded
mask payload* through the worker's framed RPC untouched — no re-encode on
either hop, which is what keeps the router path trivially bit-identical
to in-process ``YCHGService.submit`` (the fleet-smoke CI leg holds it to
byte equality). Same mask -> same worker, so the fleet coalesces and
caches exactly like one big process.

Admission reuses the service's own DRR :class:`~repro.service.scheduler.
Scheduler` verbatim — per-``(side, dtype)`` bucket bounds, block/shed
policy, deficit-round-robin fairness — with "dispatch" meaning "schedule
the forward coroutines on the router loop" instead of "run a kernel", so
one hot resolution floods its own allowance while minority traffic keeps
flowing, one layer above where the same policy already protects each
worker.

Failure handling is deterministic: a dead worker's keys fail over to the
next node on the ring walk (always the same survivor), the health loop
notices and — when a :class:`FleetSupervisor` is attached — restarts the
worker under its old slot name, so it resumes its old keyspace with an
empty cache and the peered-cache probe refills it from the survivor.

``GET /metrics`` rolls every worker's Prometheus page plus the router's
own counters into one page: worker ``*_total`` series are summed
(labelled series summed per label set) and each worker contributes a
``ychg_fleet_worker_up`` gauge.
"""

from __future__ import annotations

import asyncio
import dataclasses
import http.client
import json
import math
import subprocess
import sys
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.engine.ops import op_names
from repro.fleet.hashring import HashRing
from repro.fleet.worker import parse_ready_line
from repro.frontend import protocol
from repro.frontend.client import AsyncRPCClient, FrontendError
from repro.frontend.server import (
    CLASS_HEADER,
    DEADLINE_HEADER,
    TENANT_HEADER,
    TRACE_HEADER,
    _chunk,
    _DrainRate,
    _head,
    _parse_head,
    _respond,
    _respond_json,
)
from repro.obs import (
    NULL_TRACE,
    PromBuilder,
    base_family,
    maybe_trace,
    parse_prom_text,
    recorder,
)
from repro.obs.histogram import HistogramSnapshot
from repro.service.batching import pick_bucket_side
from repro.service.cache import make_key, serialize_key
from repro.service.scheduler import (
    Scheduler,
    SchedulerConfig,
    ServiceOverloaded,
)


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Router policy knobs.

    bucket_sides/max_batch/max_delay_ms/queue depths/overload_policy feed
    the router-side DRR admission scheduler (same semantics as
    ``ServiceConfig``); ``max_delay_ms`` defaults to 0 because batching-
    for-the-device is the workers' job — the router's scheduler exists for
    admission and fairness, not latency trading. ``inflight_slices``
    bounds outstanding forwarded slices; ``forward_timeout_s`` is one
    forward's whole budget (generous: a worker's first flush compiles);
    ``health_interval_s`` paces the liveness loop; ``replicas`` is the
    ring's virtual-node count.
    """

    bucket_sides: Tuple[int, ...] = (64, 128, 256, 512, 1024)
    max_batch: int = 8
    max_delay_ms: float = 0.0
    inflight_slices: int = 16
    max_queue_depth: Optional[int] = None
    bucket_queue_depth: Optional[int] = None
    overload_policy: str = "block"
    forward_timeout_s: float = 300.0
    health_interval_s: float = 1.0
    replicas: int = 64
    # traffic classes + tenant quotas (docs/traffic.md): the router is
    # the fleet's admission edge, so quota and deadline sheds happen HERE,
    # before any worker sees a byte of the request
    classes: Tuple[str, ...] = ("interactive", "standard", "batch")
    default_class: str = "standard"
    tenant_rate: float = 0.0
    tenant_burst: float = 0.0

    def scheduler_config(self) -> SchedulerConfig:
        return SchedulerConfig(
            max_batch=self.max_batch,
            max_delay_ms=self.max_delay_ms,
            inflight_jobs=self.inflight_slices,
            max_queue_depth=self.max_queue_depth,
            bucket_queue_depth=self.bucket_queue_depth,
            overload_policy=self.overload_policy,
            sub_batches=True,
            fair=True,
            classes=self.classes,
            default_class=self.default_class,
            tenant_rate=self.tenant_rate,
            tenant_burst=self.tenant_burst,
        )


@dataclasses.dataclass
class WorkerLink:
    """One worker slot: a STABLE name plus wherever it currently listens.

    The name ("w0", "w1", ...) is the ring identity; host/ports may change
    across restarts without moving any keys."""

    name: str
    host: str
    rpc_port: int
    http_port: int
    process: Optional[subprocess.Popen] = None
    up: bool = True


@dataclasses.dataclass
class _RouterRequest:
    """One admitted request riding the scheduler: the original encoded
    mask payload (forwarded untouched), its routing key, and the future
    the HTTP handler awaits for the worker's response frame."""

    payload: Dict[str, Any]
    skey: bytes
    bucket: Tuple[str, int, str]
    t_submit: float
    future: Future
    op_key: str = "ychg"
    stages: Optional[List[str]] = None
    served_by: Optional[str] = None
    trace: Any = NULL_TRACE   # the HTTP handler's trace; spans join it
    # traffic-shaping fields the scheduler reads at admission; never part
    # of skey/bucket/payload, so a classed forward stays bit-identical
    klass: Optional[str] = None
    deadline_ms: Optional[float] = None
    tenant: Optional[str] = None


def routing_key(mask: np.ndarray, op: str = "ychg") -> bytes:
    """The placement key for a mask: the serialized cache key with the
    policy components pinned to fleet constants. All workers run one
    policy, so backend/config would be the same bytes in every key —
    placement only ever depends on (content, shape, dtype, op), exactly
    the components :func:`serialize_key` renders process-stably. The op
    qualifies the key so the same mask under two ops lands wherever its
    cache entry would live (entries are namespaced per op)."""
    return serialize_key(
        make_key(np.ascontiguousarray(mask), "fleet", None, op=op))


class FleetRouter:
    """Route requests over worker links; serve one HTTP front end."""

    def __init__(self, links: Sequence[WorkerLink],
                 config: RouterConfig = RouterConfig(), *,
                 host: str = "127.0.0.1", port: int = 0,
                 supervisor: Optional["FleetSupervisor"] = None):
        if not links:
            raise ValueError("FleetRouter needs at least one worker link")
        self.config = config
        self.host = host
        self._want_port = port
        self._links: Dict[str, WorkerLink] = {l.name: l for l in links}
        self._ring = HashRing([l.name for l in links], config.replicas)
        self._supervisor = supervisor
        self._clients: Dict[str, AsyncRPCClient] = {}
        self._restarting: set = set()
        self._pool = ThreadPoolExecutor(
            max_workers=32, thread_name_prefix="ychg-router")
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._http_server: Optional[asyncio.AbstractServer] = None
        self._health_task: Optional[asyncio.Task] = None
        # loop-thread-only counters (every mutation runs on the loop)
        self.routed_total = 0
        self.rerouted_total = 0
        self.unroutable_total = 0
        self.completed_total = 0
        # completion-rate estimator feeding the router's own 429
        # Retry-After (same rolling-window class the frontend uses);
        # observed and read on the loop thread only
        self._drain = _DrainRate()
        self._scheduler = Scheduler(
            config.scheduler_config(),
            dispatch=self._dispatch,
            complete=self._complete,
            fail=self._fail,
        )

    # ----------------------------------------------------------- lifecycle

    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._http_server = await asyncio.start_server(
            self._handle_http, self.host, self._want_port)
        await self.broadcast_peers()
        self._health_task = asyncio.ensure_future(self._health_loop())

    @property
    def port(self) -> int:
        assert self._http_server is not None, "router not started"
        return self._http_server.sockets[0].getsockname()[1]

    async def aclose(self) -> None:
        """Drain-on-shutdown: stop accepting, let admitted forwards
        finish, then drop worker connections."""
        if self._health_task is not None:
            self._health_task.cancel()
        if self._http_server is not None:
            self._http_server.close()
            await self._http_server.wait_closed()
        # scheduler.close drains: every admitted forward completes or fails
        await asyncio.get_running_loop().run_in_executor(
            self._pool, self._scheduler.close)
        for client in list(self._clients.values()):
            try:
                await client.aclose()
            except (ConnectionError, OSError):
                pass
        self._clients.clear()
        self._pool.shutdown(wait=False)

    # -------------------------------------------------- scheduler callbacks

    def _dispatch(self, bucket, requests: List[_RouterRequest],
                  batch_size: int) -> List[Future]:
        """"Dispatch" a slice = start its forwards on the router loop;
        the list of concurrent futures is the job handle."""
        assert self._loop is not None, "router not started"
        return [asyncio.run_coroutine_threadsafe(self._forward(r), self._loop)
                for r in requests]

    def _complete(self, handle: List[Future],
                  requests: List[_RouterRequest]) -> None:
        """Retire a slice: block (scheduler thread) until each forward
        lands, then fan frames/errors out to the handlers' futures."""
        deadline = time.monotonic() + self.config.forward_timeout_s
        for fut, req in zip(handle, requests):
            try:
                frame = fut.result(
                    timeout=max(0.1, deadline - time.monotonic()))
            except Exception as e:
                if req.future.set_running_or_notify_cancel():
                    req.future.set_exception(e)
                continue
            if req.future.set_running_or_notify_cancel():
                req.future.set_result(frame)

    def _fail(self, requests: List[_RouterRequest], exc: Exception) -> None:
        for req in requests:
            if req.future.set_running_or_notify_cancel():
                req.future.set_exception(exc)

    # ------------------------------------------------------------ forwarding

    def _alive(self) -> List[str]:
        return [name for name, l in self._links.items() if l.up]

    async def _client(self, name: str) -> AsyncRPCClient:
        client = self._clients.get(name)
        if client is None:
            link = self._links[name]
            client = AsyncRPCClient(link.host, link.rpc_port)
            await client.connect()
            self._clients[name] = client
        return client

    def _drop_client(self, name: str) -> None:
        client = self._clients.pop(name, None)
        if client is not None and client._writer is not None:
            client._writer.close()

    async def _forward(self, req: _RouterRequest) -> Dict[str, Any]:
        """Forward one request to its ring owner, walking the preference
        order past downed/failing workers. A worker that ANSWERS (even
        with an error status) ends the walk — only transport failures
        reroute, so a deterministic 4xx/5xx never retries elsewhere."""
        t0 = time.monotonic()
        call_frame: Dict[str, Any] = {"op": "analyze", "mask": req.payload}
        if req.stages is not None:
            call_frame["op"] = "pipeline"
            call_frame["stages"] = req.stages
        elif req.op_key != "ychg":
            call_frame["opname"] = req.op_key
        if req.trace.enabled:
            # the RPC frame field mirroring the HTTP X-YCHG-Trace header:
            # the worker's spans join this router-side trace id
            call_frame["trace"] = req.trace.trace_id
        # the class rides to the worker so its own scheduler honours the
        # priority; deadline/tenant do NOT — both were already enforced at
        # this edge, and re-charging a tenant token per hop would double-
        # bill the quota
        if req.klass is not None:
            call_frame["klass"] = req.klass
        last_exc: Optional[Exception] = None
        first = True
        for name in self._ring.preference(req.skey):
            link = self._links[name]
            if not link.up:
                first = False
                continue
            try:
                client = await self._client(name)
                frame = await asyncio.wait_for(
                    client.call(call_frame),
                    timeout=self.config.forward_timeout_s)
            except Exception as e:
                last_exc = e
                self._mark_down(name)
                first = False
                continue
            self.routed_total += 1
            if not first:
                self.rerouted_total += 1
            req.served_by = name
            req.trace.add("router.forward", t0, time.monotonic(),
                          worker=name, rerouted=not first)
            return frame
        self.unroutable_total += 1
        req.trace.add("router.forward", t0, time.monotonic(),
                      outcome="unroutable")
        raise FrontendError(
            f"no live worker could serve this request "
            f"(last error: {last_exc})", status=503)

    def _mark_down(self, name: str) -> None:
        link = self._links[name]
        if link.up:
            link.up = False
        self._drop_client(name)

    # ---------------------------------------------------- health + restarts

    async def _health_loop(self) -> None:
        while True:
            await asyncio.sleep(self.config.health_interval_s)
            try:
                await self.check_workers()
            except asyncio.CancelledError:
                raise
            except Exception:
                pass   # the health loop must outlive any one bad cycle

    async def check_workers(self) -> Dict[str, bool]:
        """One liveness pass: ping every link's RPC health; mark, and
        (with a supervisor) restart, the dead ones."""
        status: Dict[str, bool] = {}
        for name, link in list(self._links.items()):
            alive = False
            if not (link.process is not None
                    and link.process.poll() is not None):
                try:
                    client = await self._client(name)
                    await asyncio.wait_for(client.health(), timeout=5.0)
                    alive = True
                except Exception:
                    alive = False
            if alive:
                link.up = True
            else:
                self._mark_down(name)
                if self._supervisor is not None:
                    await self._restart(name)
                    alive = self._links[name].up
            status[name] = alive
        return status

    async def _restart(self, name: str) -> None:
        """Respawn one worker slot (same name -> same keyspace) off-loop,
        then reconnect and re-broadcast the peer set."""
        if name in self._restarting:
            return
        self._restarting.add(name)
        try:
            loop = asyncio.get_running_loop()
            await loop.run_in_executor(
                self._pool, self._supervisor.restart, name)
            self._drop_client(name)
            self._links[name].up = True
            await self.broadcast_peers()
        except Exception:
            self._links[name].up = False
        finally:
            self._restarting.discard(name)

    async def broadcast_peers(self) -> None:
        """Tell every worker where its siblings' RPC ports are (each
        worker's peer set excludes itself)."""
        for name, link in self._links.items():
            if not link.up:
                continue
            peers = [[l.host, l.rpc_port]
                     for n, l in self._links.items() if n != name]
            try:
                client = await self._client(name)
                await asyncio.wait_for(
                    client.call({"op": "set_peers", "peers": peers}),
                    timeout=5.0)
            except Exception:
                self._mark_down(name)

    # ------------------------------------------------------------- HTTP side

    async def _handle_http(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                try:
                    head = await reader.readuntil(b"\r\n\r\n")
                except asyncio.IncompleteReadError:
                    break
                method, target, headers = _parse_head(head)
                try:
                    n = int(headers.get("content-length", "0") or "0")
                except ValueError:
                    await _respond_json(writer, 400, {
                        "error": "malformed Content-Length"}, False)
                    break
                if n > protocol.MAX_FRAME_BYTES or n < 0:
                    await _respond_json(writer, 413, {
                        "error": f"body of {n} bytes exceeds "
                                 f"{protocol.MAX_FRAME_BYTES}"}, False)
                    break
                body = await reader.readexactly(n) if n else b""
                keep = headers.get("connection", "").lower() != "close"
                keep = await self._route(method, target, body, writer, keep,
                                         headers)
                if not keep:
                    break
        except (ConnectionError, asyncio.LimitOverrunError,
                asyncio.IncompleteReadError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _route(self, method: str, target: str, body: bytes,
                     writer: asyncio.StreamWriter, keep: bool,
                     headers: Optional[Dict[str, str]] = None) -> bool:
        h = headers or {}
        trace_id = h.get(TRACE_HEADER) or None
        try:
            # decoded inside the try: a malformed class/deadline/tenant is
            # a 400 at the fleet edge, same as at the single-process edge
            traffic = protocol.decode_traffic(
                klass=h.get(CLASS_HEADER), deadline_ms=h.get(DEADLINE_HEADER),
                tenant=h.get(TENANT_HEADER))
            if method == "GET" and target == "/healthz":
                await _respond_json(writer, 200, {
                    "status": "ok",
                    "workers": {n: l.up for n, l in self._links.items()},
                    "queue_depth": self._scheduler.backlog()}, keep)
            elif method == "GET" and target == "/metrics":
                page = await self._rollup_metrics()
                await _respond(writer, 200, page.encode(),
                               "text/plain; version=0.0.4", keep)
            elif method == "GET" and target == "/debug/traces":
                # router-side flight recorder only; worker rings are
                # served by each worker's own /debug/traces
                await _respond(writer, 200,
                               recorder().to_chrome_json().encode(),
                               "application/json", keep)
            elif method == "POST" and target == "/v1/analyze":
                # historical alias for /v1/ychg
                await self._http_analyze(body, writer, keep, trace_id,
                                         traffic=traffic)
            elif method == "POST" and target == "/v1/analyze_batch":
                await self._http_analyze_batch(body, writer, trace_id,
                                               traffic=traffic)
                keep = False
            elif method == "POST" and target == "/v1/pipeline":
                await self._http_pipeline(body, writer, keep, trace_id,
                                          traffic=traffic)
            elif method == "POST" and target.startswith("/v1/"):
                opname = target[len("/v1/"):]
                if opname in op_names():
                    await self._http_analyze(body, writer, keep, trace_id,
                                             op=opname, traffic=traffic)
                else:
                    await _respond_json(writer, 404, {
                        "error": f"unknown op {opname!r}",
                        "ops": list(op_names())}, keep)
            else:
                await _respond_json(writer, 404, {
                    "error": f"no route for {method} {target}"}, keep)
        except protocol.ProtocolError as e:
            await _respond_json(writer, 400, {"error": str(e)}, keep)
        except (ValueError, KeyError, json.JSONDecodeError) as e:
            await _respond_json(writer, 400, {"error": f"bad request: {e}"},
                                keep)
        except ConnectionError:
            raise
        except Exception as e:
            await _respond_json(writer, 500, {"error": str(e)}, keep)
        return keep

    async def _submit(self, item: Dict[str, Any],
                      trace: Any = None, op: Optional[str] = None,
                      stages: Optional[List[str]] = None,
                      traffic: Optional[Dict[str, Any]] = None,
                      ) -> Dict[str, Any]:
        """Admit one encoded mask through the DRR scheduler and await the
        worker's response frame. decode_array validates the payload and
        yields shape/dtype for the bucket + routing key; the DECODED mask
        goes no further — the worker gets the client's original bytes.
        ``traffic`` (klass/deadline_ms/tenant) rides the request into the
        scheduler, where quota and deadline admission run BEFORE any
        worker is touched."""
        tr = trace if trace is not None else NULL_TRACE
        traffic = traffic or {}
        mask = protocol.decode_array(item["mask"])
        side = pick_bucket_side(mask.shape, self.config.bucket_sides)
        op_key = "+".join(stages) if stages else (op or "ychg")
        req = _RouterRequest(
            payload=item["mask"], skey=routing_key(mask, op_key),
            bucket=(op_key, side, str(mask.dtype)),
            t_submit=time.monotonic(), future=Future(),
            op_key=op_key, stages=stages, trace=tr,
            klass=traffic.get("klass"),
            deadline_ms=traffic.get("deadline_ms"),
            tenant=traffic.get("tenant"))
        loop = asyncio.get_running_loop()
        # submit on the executor: a "block" park must not stall the loop
        t_gate = time.monotonic()
        await loop.run_in_executor(
            self._pool, self._scheduler.submit, req)
        tr.add("router.admission", t_gate, time.monotonic())
        frame = await asyncio.wrap_future(req.future)
        self.completed_total += 1
        self._drain.observe(self.completed_total)
        return frame

    def _retry_hint_s(self) -> float:
        """Measured Retry-After for a router-side shed: the observed
        completion rate over the current backlog (1.0 s only while cold —
        no completions observed yet)."""
        self._drain.observe(self.completed_total)
        return self._drain.retry_after_s(self._scheduler.backlog())

    def _shed_response(self, e: ServiceOverloaded) -> Dict[str, Any]:
        """The 429 body for a router-side shed. Quota/deadline sheds carry
        their own exact Retry-After on the exception; a plain overload
        shed falls back to the drain-rate estimate. ``kind`` names the
        check that tripped, same contract as the single-process edge."""
        retry = getattr(e, "retry_after_s", None)
        if retry is None:
            retry = self._retry_hint_s()
        kind = {"DeadlineExceeded": "deadline",
                "TenantQuotaExceeded": "quota"}.get(
                    type(e).__name__, "overload")
        return {"error": str(e), "status": 429, "kind": kind,
                "retry_after_s": round(retry, 3)}

    def _frame_to_response(self, frame: Dict[str, Any],
                           rid: Any) -> Tuple[int, Dict[str, Any]]:
        """A worker response frame -> (status, body), ids rewritten to the
        client's (the frame's id is the worker-connection-local RPC id)."""
        if "result" in frame:
            return 200, {"id": rid, "result": frame["result"]}
        out = {k: v for k, v in frame.items() if k != "id"}
        out["id"] = rid
        out.setdefault("error", "worker error")
        return int(frame.get("status", 500)), out

    async def _http_analyze(self, body: bytes, writer: asyncio.StreamWriter,
                            keep: bool,
                            trace_id: Optional[str] = None,
                            op: Optional[str] = None,
                            stages: Optional[List[str]] = None,
                            traffic: Optional[Dict[str, Any]] = None) -> None:
        tr = maybe_trace(trace_id, process="router")
        try:
            payload = json.loads(body)
            rid = payload.get("id")
            try:
                frame = await self._submit(payload, tr, op=op, stages=stages,
                                           traffic=traffic)
            except ServiceOverloaded as e:
                out = self._shed_response(e)
                retry = out["retry_after_s"]
                await _respond_json(
                    writer, 429, out, keep,
                    extra=[("Retry-After", str(max(1, math.ceil(retry))))])
                return
            except FrontendError as e:
                await _respond_json(writer, e.status, {
                    "error": str(e), "status": e.status}, keep)
                return
            status, out = self._frame_to_response(frame, rid)
            extra = None
            if status == 429 and out.get("retry_after_s") is not None:
                extra = [("Retry-After",
                          str(max(1,
                                  math.ceil(float(out["retry_after_s"])))))]
            await _respond_json(writer, status, out, keep, extra=extra)
        finally:
            tr.finish()

    async def _http_pipeline(self, body: bytes, writer: asyncio.StreamWriter,
                             keep: bool,
                             trace_id: Optional[str] = None,
                             traffic: Optional[Dict[str, Any]] = None) -> None:
        """``POST /v1/pipeline`` — validate the stage list here (cheap,
        deterministic), then forward as a pipeline RPC frame to the mask's
        ring owner; the worker runs the compound request device-resident."""
        payload = json.loads(body)
        stages = payload.get("stages")
        if (not isinstance(stages, list) or not stages
                or not all(isinstance(s, str) for s in stages)):
            raise protocol.ProtocolError(
                "'stages' must be a non-empty list of op names")
        await self._http_analyze(body, writer, keep, trace_id,
                                 stages=[str(s) for s in stages],
                                 traffic=traffic)

    async def _http_analyze_batch(self, body: bytes,
                                  writer: asyncio.StreamWriter,
                                  trace_id: Optional[str] = None,
                                  traffic: Optional[Dict[str, Any]] = None,
                                  ) -> None:
        """Chunked NDJSON in COMPLETION order, same contract as the
        single-process front end."""
        tr = maybe_trace(trace_id, process="router")
        payload = json.loads(body)
        items = payload["masks"]
        if not isinstance(items, list):
            raise protocol.ProtocolError("'masks' must be a list")

        async def run_one(i: int, item: Dict[str, Any]) -> Dict[str, Any]:
            rid = item.get("id", i)
            try:
                frame = await self._submit({"mask": item}, tr,
                                           traffic=traffic)
            except ServiceOverloaded as e:
                return dict(self._shed_response(e), id=rid)
            except protocol.ProtocolError as e:
                return {"id": rid, "error": str(e), "status": 400}
            except FrontendError as e:
                return {"id": rid, "error": str(e), "status": e.status}
            except Exception as e:
                return {"id": rid, "error": str(e), "status": 500}
            status, out = self._frame_to_response(frame, rid)
            return out

        writer.write(_head(200, "application/x-ndjson", keep=False,
                           chunked=True))
        tasks = [asyncio.ensure_future(run_one(i, it))
                 for i, it in enumerate(items)]
        try:
            for fut in asyncio.as_completed(tasks):
                writer.write(_chunk(protocol.dumps_line(await fut)))
                await writer.drain()
            writer.write(b"0\r\n\r\n")
            await writer.drain()
        finally:
            for t in tasks:
                t.cancel()
            tr.finish()

    # -------------------------------------------------------- metrics rollup

    def _fetch_worker_metrics(self, link: WorkerLink) -> Optional[str]:
        try:
            conn = http.client.HTTPConnection(
                link.host, link.http_port, timeout=5.0)
            try:
                conn.request("GET", "/metrics")
                resp = conn.getresponse()
                if resp.status != 200:
                    return None
                return resp.read().decode()
            finally:
                conn.close()
        except (ConnectionError, OSError, http.client.HTTPException):
            return None

    async def _rollup_metrics(self) -> str:
        """One Prometheus page for the whole fleet: worker ``*_total``
        counters AND histogram families summed per label set (exact,
        because every process shares the fixed bucket boundaries of
        :mod:`repro.obs.histogram` — ``_bucket``/``_sum``/``_count`` are
        all plain summable counters), per-worker up gauges, router
        counters."""
        loop = asyncio.get_running_loop()
        pages: Dict[str, Optional[str]] = {}
        for name, link in self._links.items():
            pages[name] = (await loop.run_in_executor(
                self._pool, self._fetch_worker_metrics, link)
                if link.up else None)
        totals: Dict[Tuple[str, Tuple], float] = {}
        order: List[Tuple[str, Tuple]] = []
        types: Dict[str, str] = {}
        for text in pages.values():
            if text is None:
                continue
            try:
                page = parse_prom_text(text)
            except ValueError:
                continue   # one malformed worker must not kill the page
            types.update(page.types)
            for s in page.samples:
                fam = base_family(s.name)
                is_hist = page.types.get(fam) == "histogram"
                if not (s.name.endswith("_total") or is_hist):
                    continue
                key = (s.name, s.labels)
                if key not in totals:
                    order.append(key)
                    totals[key] = 0.0
                totals[key] += s.value
        # group summed series by family (first-seen order) so TYPE lines
        # come out once per family, with histogram families declared as
        # histograms rather than counters
        fam_order: List[str] = []
        fam_series: Dict[str, List[Tuple[str, Tuple]]] = {}
        for name, labels in order:
            fam = base_family(name)
            if types.get(fam) != "histogram":
                fam = name
            if fam not in fam_series:
                fam_order.append(fam)
                fam_series[fam] = []
            fam_series[fam].append((name, labels))
        b = PromBuilder()
        b.raw("# HELP ychg_* fleet rollup: worker *_total and histogram "
              "series summed across workers + router-side ychg_fleet_* "
              "series")
        for fam in fam_order:
            b.header(fam,
                     "histogram" if types.get(fam) == "histogram"
                     else "counter")
            for name, labels in fam_series[fam]:
                b.sample(name, labels, totals[(name, labels)])
        b.header("ychg_fleet_worker_up", "gauge",
                 "1 when the worker answered the last metrics scrape")
        for name, link in self._links.items():
            b.sample("ychg_fleet_worker_up", (("worker", name),),
                     1 if link.up and pages.get(name) is not None else 0)
        b.counter("ychg_fleet_routed_total", self.routed_total,
                  "requests forwarded to a worker")
        b.counter("ychg_fleet_rerouted_total", self.rerouted_total,
                  "forwards that failed over past their ring owner")
        b.counter("ychg_fleet_unroutable_total", self.unroutable_total,
                  "requests no live worker could serve")
        b.counter("ychg_fleet_completed_total", self.completed_total,
                  "requests answered through the router")
        b.counter("ychg_fleet_shed_deadline_total",
                  self._scheduler.shed_deadline,
                  "requests shed at the router edge: deadline unmeetable")
        b.counter("ychg_fleet_shed_quota_total", self._scheduler.shed_quota,
                  "requests shed at the router edge: tenant over quota")
        shed_by_class = self._scheduler.shed_by_class
        if shed_by_class:
            b.header("ychg_fleet_shed_class_total", "counter",
                     "router-edge sheds by traffic class")
            for klass, n in sorted(shed_by_class.items()):
                b.sample("ychg_fleet_shed_class_total",
                         (("class", klass),), n)
        shed_by_tenant = self._scheduler.shed_by_tenant
        if shed_by_tenant:
            b.header("ychg_fleet_shed_tenant_total", "counter",
                     "router-edge sheds by tenant")
            for tenant, n in sorted(shed_by_tenant.items()):
                b.sample("ychg_fleet_shed_tenant_total",
                         (("tenant", tenant),), n)
        b.gauge("ychg_fleet_queue_depth", self._scheduler.backlog(),
                "router-side admitted-but-unforwarded requests")
        b.gauge("ychg_fleet_drain_rate_rps", round(self._drain.rate(), 3),
                "observed router completion rate feeding Retry-After")
        return b.render()


# ------------------------------------------------------------- supervision


class FleetSupervisor:
    """Spawn and respawn worker processes under stable slot names.

    Workers bind ephemeral ports and hand them back through the one-line
    ``WORKER READY`` handshake on stdout; a restart keeps the slot name
    (ring placement) and updates the link's ports in place, so the
    router's tables never go stale."""

    def __init__(self, n: int, *, host: str = "127.0.0.1",
                 worker_args: Sequence[str] = (),
                 start_timeout_s: float = 180.0):
        if n < 1:
            raise ValueError(f"fleet size must be >= 1, got {n}")
        self.host = host
        self.worker_args = list(worker_args)
        self.start_timeout_s = start_timeout_s
        self.links: List[WorkerLink] = [
            WorkerLink(name=f"w{i}", host=host, rpc_port=0, http_port=0,
                       up=False)
            for i in range(n)]
        self._by_name = {l.name: l for l in self.links}

    def start(self) -> List[WorkerLink]:
        for link in self.links:
            self._spawn(link)
        return self.links

    def _spawn(self, link: WorkerLink) -> None:
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.fleet.worker",
             "--host", self.host, "--port", "0", "--rpc-port", "0",
             *self.worker_args],
            stdout=subprocess.PIPE, stderr=subprocess.DEVNULL, text=True)
        deadline = time.monotonic() + self.start_timeout_s
        ports = None
        assert proc.stdout is not None
        while time.monotonic() < deadline:
            line = proc.stdout.readline()
            if not line:
                break   # worker died before handshaking
            ports = parse_ready_line(line)
            if ports is not None:
                break
        if ports is None:
            proc.kill()
            proc.wait(timeout=10)
            raise RuntimeError(
                f"worker {link.name} never printed its READY handshake")
        link.rpc_port, link.http_port = ports
        link.process = proc
        link.up = True

    def restart(self, name: str) -> WorkerLink:
        """Kill (if needed) and respawn one slot; blocks through the new
        worker's handshake. Safe to call from an executor thread."""
        link = self._by_name[name]
        self._stop_one(link)
        self._spawn(link)
        return link

    def _stop_one(self, link: WorkerLink, timeout: float = 10.0) -> None:
        proc = link.process
        link.up = False
        if proc is None or proc.poll() is not None:
            return
        proc.terminate()
        try:
            proc.wait(timeout=timeout)
        except subprocess.TimeoutExpired:
            proc.kill()
            proc.wait(timeout=timeout)

    def stop(self) -> None:
        for link in self.links:
            self._stop_one(link)


# -------------------------------------------------------- sync entry point


class RouterThread:
    """A `FleetRouter` on its own event-loop thread, for sync callers
    (mirrors ``repro.frontend.server.ServerThread``)."""

    def __init__(self, router: FleetRouter, *, start_timeout: float = 60.0):
        self._router = router
        self._ready = threading.Event()
        self._stop: Optional[asyncio.Event] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._exc: Optional[BaseException] = None
        self.port: Optional[int] = None
        self._thread = threading.Thread(
            target=lambda: asyncio.run(self._main()),
            name="ychg-fleet-router", daemon=True)
        self._thread.start()
        if not self._ready.wait(start_timeout):
            raise RuntimeError("fleet router failed to start in time")
        if self._exc is not None:
            raise self._exc

    async def _main(self) -> None:
        try:
            await self._router.start()
            self.port = self._router.port
            self._loop = asyncio.get_running_loop()
            self._stop = asyncio.Event()
        except BaseException as e:
            self._exc = e
            self._ready.set()
            return
        self._ready.set()
        await self._stop.wait()
        await self._router.aclose()

    def close(self, timeout: float = 60.0) -> None:
        if self._loop is not None and self._stop is not None:
            self._loop.call_soon_threadsafe(self._stop.set)
        self._thread.join(timeout)

    def __enter__(self) -> "RouterThread":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
