"""`PeeredResultCache` — a ResultCache that asks its siblings before
computing.

Fleet workers each hold a private LRU; the router's consistent hashing
makes those caches *mostly* disjoint, but a worker restart (empty cache,
same keyspace) or a failover window (keys served by the wrong worker)
leaves entries stranded on a sibling. On a local miss this cache probes
each configured peer's RPC ``cache_probe`` verb — a pure lookup on the
far side, never a compute — and adopts the first hit, so a repeat mask
after a restart costs one loopback round trip instead of a kernel run.

The probe is deliberately cheap and fail-soft: a fresh blocking socket
per probe (no connection state to manage across worker restarts), a short
timeout, and ANY transport or decode failure is just a miss — peering
must never make a worker less available than not peering. The reply
carries the stored entry layout ((1, W)/(1,) arrays, ``batched=False``),
which is re-hosted onto this process's device via ``jnp.asarray`` so the
adopted entry is indistinguishable from one this worker computed —
``to_host()`` of either is byte-identical (pinned in tests/test_fleet.py).
"""

from __future__ import annotations

import json
import socket
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.engine.ops import get_op, split_pipeline_key
from repro.frontend import protocol
from repro.service.cache import CacheKey, ResultCache, serialize_key

# one probe's whole budget (connect + request + reply): siblings are
# loopback neighbours, so anything slower than this is effectively down
# and compute is the better bet
DEFAULT_PROBE_TIMEOUT_S = 0.25


def _recv_exactly(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed mid-frame")
        buf += chunk
    return buf


def probe_peer(host: str, port: int, skey: bytes, *,
               timeout: float = DEFAULT_PROBE_TIMEOUT_S,
               opname: str = "ychg") -> Optional[Dict[str, Any]]:
    """One blocking ``cache_probe`` round trip; the decoded hit frame, or
    None on miss/any failure. ``opname`` tells the sibling which op's
    field set to encode the stored entry with (the key already carries it
    — this just saves the far side reverse-engineering the bytes)."""
    try:
        with socket.create_connection((host, port), timeout=timeout) as sock:
            sock.sendall(protocol.pack_frame(
                {"op": "cache_probe", "key": skey.hex(), "id": 0,
                 "opname": opname}))
            head = _recv_exactly(sock, 4)
            payload = _recv_exactly(sock, protocol.unpack_frame_header(head))
    except (ConnectionError, OSError, protocol.ProtocolError):
        return None
    try:
        frame = json.loads(payload)
    except ValueError:
        return None
    return frame if frame.get("hit") else None


class PeeredResultCache(ResultCache):
    """A ResultCache whose misses consult sibling workers over RPC."""

    def __init__(self, capacity: int = 1024, *,
                 probe_timeout_s: float = DEFAULT_PROBE_TIMEOUT_S):
        # serialized index always on: siblings address us by serialized
        # key through the server's cache_probe verb
        super().__init__(capacity, index_serialized=True)
        self.probe_timeout_s = probe_timeout_s
        self._peers: Tuple[Tuple[str, int], ...] = ()
        self._peers_lock = threading.Lock()

    def set_peers(self, peers: Sequence[Tuple[str, int]]) -> None:
        """Replace the sibling set ((host, rpc_port) pairs). Called at
        fleet bring-up and re-broadcast after any worker restart."""
        with self._peers_lock:
            self._peers = tuple((str(h), int(p)) for h, p in peers)

    @property
    def peers(self) -> Tuple[Tuple[str, int], ...]:
        with self._peers_lock:
            return self._peers

    def peer_probe(self, key: CacheKey) -> Optional[Any]:
        """Ask each sibling in turn; reconstruct the first hit as a
        device-resident stored-layout result. Any failure = miss."""
        peers = self.peers
        if not peers:
            return None
        skey = serialize_key(key)
        op_key = key[6]
        result_type = get_op(split_pipeline_key(op_key)[-1]).result_type
        for host, port in peers:
            frame = probe_peer(host, port, skey,
                               timeout=self.probe_timeout_s, opname=op_key)
            if frame is None:
                continue
            try:
                fields = {
                    f: jnp.asarray(protocol.decode_array(frame["result"][f]))
                    for f in protocol.result_fields(op_key)}
                result = result_type(**fields, batched=False)
            except (KeyError, TypeError, ValueError, protocol.ProtocolError):
                continue   # a garbled reply is a miss, not an outage
            self.peer_hits += 1
            return result
        self.peer_misses += 1
        return None
