"""One fleet worker process: ``python -m repro.fleet.worker``.

A worker is exactly today's stack — ``Engine`` (unmeshed; serialized
cache keys need process-stable components) behind ``Service`` behind
``FrontendServer`` — plus a :class:`~repro.fleet.peering.PeeredResultCache`
so local misses consult siblings before computing. The supervisor spawns
workers with ephemeral ports (0) and parses the one-line handshake this
process prints once both listeners are bound::

    WORKER READY rpc=<port> http=<port>

SIGTERM (and SIGINT) drain the service before exit, so an orderly fleet
shutdown never abandons admitted requests.
"""

from __future__ import annotations

import argparse
import os
import signal
import threading


READY_PREFIX = "WORKER READY"


def ready_line(rpc_port: int, http_port: int) -> str:
    return f"{READY_PREFIX} rpc={rpc_port} http={http_port}"


def parse_ready_line(line: str):
    """(rpc_port, http_port) out of a handshake line, or None."""
    line = line.strip()
    if not line.startswith(READY_PREFIX):
        return None
    try:
        kv = dict(part.split("=", 1)
                  for part in line[len(READY_PREFIX):].split())
        return int(kv["rpc"]), int(kv["http"])
    except (KeyError, ValueError):
        return None


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.fleet.worker",
        description="one yCHG fleet worker (service + HTTP + RPC)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="HTTP port (0 = ephemeral)")
    ap.add_argument("--rpc-port", type=int, default=0,
                    help="framed TCP RPC port (0 = ephemeral)")
    ap.add_argument("--buckets", default="64,128",
                    help="comma-separated bucket sides")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-delay-ms", type=float, default=2.0)
    ap.add_argument("--cache-entries", type=int, default=1024)
    ap.add_argument("--max-queue-depth", type=int, default=None)
    ap.add_argument("--bucket-queue-depth", type=int, default=None)
    ap.add_argument("--policy", default="block", choices=["block", "shed"])
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="share a persistent JAX compilation cache (a "
                         "restarted worker reloads its bucket ladder's "
                         "compiles from disk instead of recompiling)")
    ap.add_argument("--trace-dump", default=None, metavar="PATH",
                    help="dump this worker's flight recorder as Chrome-trace "
                         "JSON to PATH.<pid> on shutdown")
    return ap


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    if args.compile_cache:
        from repro.launch.compilecache import enable_compile_cache

        enable_compile_cache(args.compile_cache)

    from repro import obs
    from repro.engine import Engine
    from repro.fleet.peering import PeeredResultCache
    from repro.frontend import ServerThread
    from repro.service import Service, ServiceConfig

    if args.trace_dump:
        # per-process suffix: every worker of a supervisor shares the flag
        obs.configure(dump_path=f"{args.trace_dump}.{os.getpid()}")

    config = ServiceConfig(
        bucket_sides=tuple(int(b) for b in args.buckets.split(",")),
        max_batch=args.max_batch,
        max_delay_ms=args.max_delay_ms,
        cache_entries=args.cache_entries,
        max_queue_depth=args.max_queue_depth,
        bucket_queue_depth=args.bucket_queue_depth,
        overload_policy=args.policy,
    )
    cache = PeeredResultCache(args.cache_entries)
    stop = threading.Event()
    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, lambda *_: stop.set())

    with Service(Engine(), config, cache=cache) as svc:
        with ServerThread(svc, host=args.host, port=args.port,
                          rpc_port=args.rpc_port) as srv:
            print(ready_line(srv.rpc_port, srv.port), flush=True)
            stop.wait()
            obs.auto_dump("worker-shutdown")
            # context exits drain: ServerThread stops accepting, then
            # service.close() finishes every admitted request


if __name__ == "__main__":
    main()
