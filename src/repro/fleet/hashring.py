"""Consistent-hash ring over worker slot names.

The router places each request by its serialized cache key
(:func:`repro.service.cache.serialize_key`) so identical masks always hit
the same worker — that worker's local cache and in-flight coalescing then
do fleet-wide what they already do per process. Two properties matter:

  * **stability** — points are blake2b digests of ``"{node}#{i}"``, no
    ``hash()``, no randomness: the same node names produce the same ring
    in every process and every run, and a restarted worker that keeps its
    slot name ("w1") keeps its keyspace;
  * **minimal movement** — with ``replicas`` virtual nodes per worker,
    removing one worker redistributes only its own arc among the
    survivors; everyone else's placement is untouched.

``node_for(key, up=...)`` walks clockwise past downed nodes, so failover
is deterministic too: a key's requests always fail over to the same
survivor, keeping the cache-locality story intact even mid-outage.
"""

from __future__ import annotations

import bisect
import hashlib
from typing import Dict, List, Optional, Sequence


def _point(label: str) -> int:
    """A 64-bit ring position from a stable byte rendering of the label."""
    return int.from_bytes(
        hashlib.blake2b(label.encode(), digest_size=8).digest(), "big")


class HashRing:
    """Immutable consistent-hash ring over a fixed set of node names."""

    def __init__(self, nodes: Sequence[str], replicas: int = 64):
        if not nodes:
            raise ValueError("HashRing needs at least one node")
        if len(set(nodes)) != len(nodes):
            raise ValueError(f"duplicate node names in {list(nodes)}")
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.nodes = tuple(nodes)
        self.replicas = replicas
        points: Dict[int, str] = {}
        for node in nodes:
            for i in range(replicas):
                points[_point(f"{node}#{i}")] = node
        self._points = sorted(points)
        self._owner = [points[p] for p in self._points]

    def preference(self, key: bytes) -> List[str]:
        """All nodes in failover order for ``key``: the owner first, then
        each distinct node as the clockwise walk reaches it."""
        start = bisect.bisect_right(
            self._points,
            int.from_bytes(
                hashlib.blake2b(key, digest_size=8).digest(), "big"))
        seen: List[str] = []
        n = len(self._points)
        for i in range(n):
            node = self._owner[(start + i) % n]
            if node not in seen:
                seen.append(node)
                if len(seen) == len(self.nodes):
                    break
        return seen

    def node_for(self, key: bytes,
                 up: Optional[Sequence[str]] = None) -> Optional[str]:
        """The owning node for ``key``, skipping nodes not in ``up``
        (None = all up). None when every candidate is down."""
        alive = set(self.nodes if up is None else up)
        for node in self.preference(key):
            if node in alive:
                return node
        return None
