"""`repro.fleet` — multi-process serving: router, workers, peered cache.

The fleet stacks three pieces on the existing single-process service:

  * :mod:`repro.fleet.hashring` — consistent hashing over *stable worker
    slot names* ("w0", "w1", ...) keyed by the process-stable serialized
    cache key, so identical masks land on the same worker (and coalesce
    fleet-wide) and placement survives worker restarts;
  * :mod:`repro.fleet.peering` — ``PeeredResultCache``: on a local miss a
    worker probes its siblings' caches over RPC before paying compute;
  * :mod:`repro.fleet.router` — ``FleetRouter``: one HTTP front end
    fanning requests over N worker processes through the length-prefixed
    RPC, with DRR admission (the same ``Scheduler`` machinery the service
    uses), health checks, restart-on-death, and a rolled-up /metrics page.

``launch/serve.py --fleet N`` wires them together.
"""

from repro.fleet.hashring import HashRing
from repro.fleet.peering import PeeredResultCache
from repro.fleet.router import (
    FleetRouter,
    FleetSupervisor,
    RouterConfig,
    RouterThread,
    WorkerLink,
)

__all__ = [
    "FleetRouter",
    "FleetSupervisor",
    "HashRing",
    "PeeredResultCache",
    "RouterConfig",
    "RouterThread",
    "WorkerLink",
]
