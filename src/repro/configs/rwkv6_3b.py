"""rwkv6-3b "Finch" [ssm] — attention-free, data-dependent decay.
[arXiv:2404.05892; hf:RWKV/rwkv-6-world-3b]
32L d_model=2560 d_ff=8960 vocab=65536; head dim 64 -> 40 heads.
"""

from repro.configs.base import LayerSpec, ModelConfig, register


@register("rwkv6-3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        num_layers=32,
        d_model=2560,
        num_heads=40,       # d_model / rwkv_head_dim
        num_kv_heads=40,
        head_dim=64,
        d_ff=8960,
        vocab_size=65_536,
        layer_pattern=(LayerSpec("rwkv", "rwkv_ffn"),),
        rwkv_head_dim=64,
        rwkv_decay_lora=64,
        rwkv_mix_lora=32,
        norm_type="layernorm",
        pos_embed="none",
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=8, head_dim=8,
        d_ff=128, vocab_size=256, rwkv_head_dim=8, rwkv_decay_lora=8,
        rwkv_mix_lora=4, ssm_chunk=4,
        param_dtype="float32", activation_dtype="float32", remat="none",
    )
