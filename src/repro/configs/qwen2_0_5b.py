"""qwen2-0.5b [dense] — GQA with QKV bias, tied embeddings.
[arXiv:2407.10671; hf:Qwen/Qwen2-0.5B]
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936, head_dim=64.
"""

from repro.configs.base import ModelConfig, register


@register("qwen2-0.5b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b",
        family="dense",
        num_layers=24,
        d_model=896,
        num_heads=14,
        num_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab_size=151_936,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, param_dtype="float32",
        activation_dtype="float32", remat="none", attn_chunk=64,
    )
