"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE every
other layer (16 experts, top-2). [arXiv:2403.19887; hf:ai21labs/Jamba-v0.1]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536.

Period-8 pattern (attention at index 4, MoE on odd indices) — matches the
paper's jamba block: 8 layers, 1 attention, MoE applied every 2 layers.
"""

from repro.configs.base import LayerSpec, ModelConfig, register


def _pattern():
    out = []
    for i in range(8):
        mixer = "attn" if i == 4 else "mamba"
        channel = "moe" if i % 2 == 1 else "mlp"
        out.append(LayerSpec(mixer, channel))
    return tuple(out)


@register("jamba-v0.1-52b")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14_336,
        vocab_size=65_536,
        layer_pattern=_pattern(),
        num_experts=16,
        experts_per_token=2,
        ssm_state_dim=16,
        ssm_conv_dim=4,
        ssm_expand=2,
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, num_experts=4, experts_per_token=2,
        moe_capacity_factor=4.0, ssm_chunk=4,
        param_dtype="float32", activation_dtype="float32", remat="none",
        attn_chunk=64,
    )
