"""command-r-35b [dense] — GQA, no bias, parallel attn+mlp block, LayerNorm,
tied embeddings. [hf:CohereForAI/c4ai-command-r-v01; unverified]
40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
"""

from repro.configs.base import ModelConfig, register


@register("command-r-35b")
def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b",
        family="dense",
        num_layers=40,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=22_528,
        vocab_size=256_000,
        parallel_block=True,
        norm_type="layernorm",
        tie_embeddings=True,
        rope_theta=8_000_000.0,
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=160, vocab_size=256, param_dtype="float32",
        activation_dtype="float32", remat="none", attn_chunk=64,
    )
