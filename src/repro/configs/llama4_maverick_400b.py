"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert,
MoE interleaved every other layer, early-fusion multimodal.
[hf:meta-llama/Llama-4-Maverick-17B-128E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.

Early fusion = image tokens share the decoder stream; the vision frontend is
a stub (precomputed patch embeddings as a prefix), same contract as llava.
"""

from repro.configs.base import LayerSpec, ModelConfig, register


@register("llama4-maverick-400b-a17b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202_048,
        # dense layer / MoE layer interleave (interleave_moe_layer_step=2)
        layer_pattern=(LayerSpec("attn", "mlp"), LayerSpec("attn", "moe")),
        num_experts=128,
        experts_per_token=1,   # top-1 sigmoid gate + always-on shared expert
        frontend="vision",
        frontend_tokens=1024,
        rope_theta=500_000.0,
        param_dtype="bfloat16",
        # 800 GB bf16 weights need FSDP even at inference on a 256-chip pod
        # (model-axis-only sharding = 50 GB/chip); production decode for this
        # arch wants a bigger mesh or int8 weights — see EXPERIMENTS.md §Perf.
        decode_rule_overrides={"embed": "data"},
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=256, num_experts=4, experts_per_token=1,
        moe_capacity_factor=4.0, frontend_tokens=8,
        param_dtype="float32", activation_dtype="float32", remat="none",
        attn_chunk=64,
    )
