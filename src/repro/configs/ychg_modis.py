"""The paper's own workload: yCHG over MODIS-like scenes.

Knobs mirror the poster's experiments: resolution series up to the
21000x21000 scene (knob a) and hyperedge series 147 -> 4,124,319 (knob b).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class YCHGWorkloadConfig:
    name: str = "ychg-modis"
    resolutions: Tuple[int, ...] = (250, 500, 1000, 2000, 4000, 8000, 12000, 21000)
    hyperedge_series: Tuple[int, ...] = (
        147, 1_000, 10_000, 100_000, 1_000_000, 4_124_319
    )
    hyperedge_resolution: int = 8192   # fixed resolution for knob (b)
    batch: int = 8                     # tiles per device batch in the pipeline
    block_w: int = 128                 # Pallas lane tile
    block_h: int = 2048                # streamed kernel row tile
    backends: Tuple[str, ...] = ("scalar", "serial", "jax", "pallas")


def config() -> YCHGWorkloadConfig:
    return YCHGWorkloadConfig()
