"""The paper's own workload: yCHG over MODIS-like scenes.

Knobs mirror the poster's experiments: resolution series up to the
21000x21000 scene (knob a) and hyperedge series 147 -> 4,124,319 (knob b).
The ``engine`` section is the canonical way this workload constructs its
yCHG computation: ``Engine(config().engine.to_engine_config())``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class EngineSection:
    """Mirror of ``repro.engine.YCHGConfig`` inside the workload config.

    Kept as plain data (no repro.engine import at config-definition time)
    so configs stay importable in tooling that never runs the algorithm.
    """

    backend: str = "auto"              # registry-resolved per platform
    block_w: int = 128                 # Pallas lane tile
    block_h: int = 2048                # streamed kernel row tile
    dtype: Optional[str] = None        # cast masks on ingest (None = as-is)
    mesh_axis: str = "data"            # batch axis when a mesh is attached
    interpret: Optional[bool] = None   # None = interpret off-TPU
    stream_vmem_budget: int = 4 * 1024 * 1024

    def to_engine_config(self, **overrides: Any):
        """Materialise as a ``repro.engine.YCHGConfig`` (with overrides)."""
        from repro.engine import YCHGConfig

        kw = dataclasses.asdict(self)
        kw.update(overrides)
        return YCHGConfig(**kw)


@dataclasses.dataclass(frozen=True)
class YCHGWorkloadConfig:
    name: str = "ychg-modis"
    resolutions: Tuple[int, ...] = (250, 500, 1000, 2000, 4000, 8000, 12000, 21000)
    hyperedge_series: Tuple[int, ...] = (
        147, 1_000, 10_000, 100_000, 1_000_000, 4_124_319
    )
    hyperedge_resolution: int = 8192   # fixed resolution for knob (b)
    batch: int = 8                     # tiles per device batch in the pipeline
    engine: EngineSection = EngineSection()
    backends: Tuple[str, ...] = ("scalar", "serial", "jax", "pallas", "fused")

    # legacy flat tile knobs, kept as views of the engine section
    @property
    def block_w(self) -> int:
        return self.engine.block_w

    @property
    def block_h(self) -> int:
        return self.engine.block_h


def config() -> YCHGWorkloadConfig:
    return YCHGWorkloadConfig()
