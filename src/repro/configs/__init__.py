from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
    LayerSpec,
    ModelConfig,
    ShapeConfig,
    get_config,
    list_archs,
    shapes_for,
)

__all__ = [
    "ALL_SHAPES",
    "DECODE_32K",
    "LONG_500K",
    "PREFILL_32K",
    "TRAIN_4K",
    "LayerSpec",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "list_archs",
    "shapes_for",
]
