"""qwen3-4b [dense] — GQA + qk_norm, no bias. [hf:Qwen/Qwen3-4B]
36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936, head_dim=128.
"""

from repro.configs.base import ModelConfig, register


@register("qwen3-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        family="dense",
        num_layers=36,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151_936,
        qk_norm=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, param_dtype="float32",
        activation_dtype="float32", remat="none", attn_chunk=64,
    )
