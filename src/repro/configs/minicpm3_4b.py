"""minicpm3-4b [dense] — MLA (multi-head latent attention).
[hf:openbmb/MiniCPM3-4B]
62L d_model=2560 40H d_ff=6400 vocab=73448; MLA: q_lora=768, kv_lora=256,
qk_nope=64, qk_rope=32, v_head=64. The decode path uses the absorbed-matmul
formulation against the compressed (kv_lora + rope) cache.
"""

from repro.configs.base import LayerSpec, ModelConfig, register


@register("minicpm3-4b")
def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        num_layers=62,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,
        head_dim=96,  # qk_nope + qk_rope
        d_ff=6400,
        vocab_size=73_448,
        layer_pattern=(LayerSpec("mla", "mlp"),),
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_rope_dim=32,
        qk_nope_dim=64,
        v_head_dim=64,
        tie_embeddings=True,
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=24,
        d_ff=128, vocab_size=256, q_lora_rank=32, kv_lora_rank=16,
        qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16,
        param_dtype="float32", activation_dtype="float32", remat="none",
        attn_chunk=64,
    )
