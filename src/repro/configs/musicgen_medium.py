"""musicgen-medium [audio] — decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf:facebook/musicgen-medium]
48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048, GELU MLP, LayerNorm,
sinusoidal positions.

The EnCodec frontend is a STUB per the brief: input_specs() provides
precomputed frame embeddings for the audio-prompt prefix; the text-
conditioning cross-attention of the original is out of scope (the backbone
cells are the assigned LM shapes). Codebook interleaving (delay pattern) is
a data-layout concern handled upstream of the model.
"""

from repro.configs.base import ModelConfig, register


@register("musicgen-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        d_ff=6144,
        vocab_size=2048,
        norm_type="layernorm",
        mlp_act="gelu",
        pos_embed="sinusoidal",
        frontend="audio",
        frontend_tokens=512,
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, frontend_tokens=8,
        param_dtype="float32", activation_dtype="float32", remat="none",
        attn_chunk=64,
    )
