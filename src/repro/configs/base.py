"""Config dataclasses + registry for the assigned architectures.

A model is assembled from a repeating ``layer_pattern`` of (token-mixer,
channel-mixer) pairs — scan-over-layer-groups keeps the HLO O(period) in
depth:

  mixers:   "attn" (GQA/MHA, optional qk_norm/bias), "mla", "mamba", "rwkv"
  channels: "mlp" (swiglu/gelu), "moe", "rwkv_ffn"

Shapes (assigned): each cell names a step kind —
  train_4k / prefill_32k lower train_step / prefill_step;
  decode_32k / long_500k lower serve_step (1 new token, KV cache of seq_len).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str          # "attn" | "mla" | "mamba" | "rwkv"
    channel: str        # "mlp" | "moe" | "rwkv_ffn"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | hybrid | ssm | vlm | moe | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    layer_pattern: Tuple[LayerSpec, ...] = (LayerSpec("attn", "mlp"),)

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    parallel_block: bool = False     # command-r style parallel attn+mlp
    pos_embed: str = "rope"          # "rope" | "sinusoidal" | "none"
    rope_theta: float = 10_000.0

    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    qk_nope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_impl: str = "dispatch"       # "dispatch" (sort+scatter) | "alltoall" (shard_map)

    # SSM (mamba)
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0             # 0 -> ceil(d_model / 16)
    ssm_chunk: int = 16              # within-chunk associative scan length

    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32

    # misc
    norm_type: str = "rmsnorm"       # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-5
    mlp_act: str = "swiglu"          # "swiglu" | "gelu"
    tie_embeddings: bool = False
    frontend: str = "none"           # "none" | "vision" | "audio"
    frontend_tokens: int = 0         # prefix positions fed by the (stub) frontend
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    remat: str = "full"              # "none" | "dots" | "full"
    attn_chunk: int = 1024           # flash-style q/kv chunk for train/prefill
    scan_layers: bool = True         # False: Python loop over groups (cost probes)
    # arch-specific rule overrides applied to decode cells (e.g. llama4's
    # 800 GB of bf16 experts exceed 16 chips x 16 GB without FSDP)
    decode_rule_overrides: Dict[str, Optional[object]] = dataclasses.field(
        default_factory=dict)
    weight_quant: str = "none"       # "none" | "int8" (serve path, §Perf)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.ssm_dt_rank == 0:
            object.__setattr__(self, "ssm_dt_rank", -(-self.d_model // 16))
        assert self.num_layers % len(self.layer_pattern) == 0, (
            f"{self.name}: num_layers {self.num_layers} not divisible by "
            f"pattern period {len(self.layer_pattern)}"
        )

    @property
    def num_groups(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def is_attention_free(self) -> bool:
        return all(s.mixer in ("mamba", "rwkv") for s in self.layer_pattern)

    @property
    def has_full_attention(self) -> bool:
        return any(s.mixer in ("attn", "mla") for s in self.layer_pattern)

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                 # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int
    # logical->mesh rule overrides for this cell, e.g. {"act_kv_seq": "data"}
    rule_overrides: Dict[str, Optional[object]] = dataclasses.field(default_factory=dict)


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig(
    "long_500k", "decode", 524_288, 1,
    # batch of 1 cannot shard over data; shard the (huge) cache seq over
    # BOTH axes (512k / 256 chips = 2k rows per chip).
    rule_overrides={"act_batch": None, "act_kv_seq": ("data", "model")},
)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


# ---------------------------------------------------------------------------
# registry

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    import repro.configs.archs  # noqa: F401  (populates the registry)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    import repro.configs.archs  # noqa: F401

    return sorted(_REGISTRY)


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """The assigned shape cells that apply to this arch.

    long_500k needs sub-quadratic attention: it runs for SSM/hybrid archs and
    is skipped (recorded) for pure full-attention archs. All assigned archs
    are decoder-style, so decode shapes apply to all.
    """
    out = []
    for s in ALL_SHAPES:
        if s.name == "long_500k" and not (
            cfg.is_attention_free or cfg.family == "hybrid"
        ):
            continue
        out.append(s)
    return tuple(out)
