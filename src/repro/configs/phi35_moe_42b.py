"""phi3.5-moe-42b-a6.6b [moe] — 16 experts, top-2, every layer MoE.
[hf:microsoft/Phi-3.5-MoE-instruct]
32L d_model=4096 32H (GQA kv=8) d_ff=6400 (per expert) vocab=32064.
"""

from repro.configs.base import LayerSpec, ModelConfig, register


@register("phi3.5-moe-42b-a6.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab_size=32_064,
        layer_pattern=(LayerSpec("attn", "moe"),),
        num_experts=16,
        experts_per_token=2,
        norm_type="layernorm",
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=256, num_experts=4, experts_per_token=2,
        moe_capacity_factor=4.0,
        param_dtype="float32", activation_dtype="float32", remat="none",
        attn_chunk=64,
    )
