"""llava-next-34b [vlm] — anyres tiling over a 34B text backbone.
[hf:llava-hf/llava-v1.6-34b-hf; unverified]
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

The vision frontend is a STUB per the brief: input_specs() provides
precomputed patch embeddings (B, frontend_tokens, d_model) which replace the
first positions of the token stream. anyres tile *selection* is where the
paper's technique plugs in: repro.data.pipeline.anyres_select ranks candidate
crops by yCHG hyperedge density (see DESIGN.md §3).
"""

from repro.configs.base import ModelConfig, register


@register("llava-next-34b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        family="vlm",
        num_layers=60,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=20_480,
        vocab_size=64_000,
        frontend="vision",
        frontend_tokens=2880,  # 5 anyres tiles x 576 patches
        rope_theta=5_000_000.0,
        param_dtype="bfloat16",
    )


def smoke() -> ModelConfig:
    return config().scaled(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=128, vocab_size=256, frontend_tokens=8,
        param_dtype="float32", activation_dtype="float32", remat="none",
        attn_chunk=64,
    )
