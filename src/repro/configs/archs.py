"""Imports every architecture module so the registry is populated."""

from repro.configs import (  # noqa: F401
    command_r_35b,
    jamba_v01_52b,
    llama4_maverick_400b,
    llava_next_34b,
    minicpm3_4b,
    musicgen_medium,
    phi35_moe_42b,
    qwen2_0_5b,
    qwen3_4b,
    rwkv6_3b,
)

SMOKE = {
    "qwen2-0.5b": qwen2_0_5b.smoke,
    "command-r-35b": command_r_35b.smoke,
    "minicpm3-4b": minicpm3_4b.smoke,
    "qwen3-4b": qwen3_4b.smoke,
    "jamba-v0.1-52b": jamba_v01_52b.smoke,
    "rwkv6-3b": rwkv6_3b.smoke,
    "llava-next-34b": llava_next_34b.smoke,
    "phi3.5-moe-42b-a6.6b": phi35_moe_42b.smoke,
    "llama4-maverick-400b-a17b": llama4_maverick_400b.smoke,
    "musicgen-medium": musicgen_medium.smoke,
}


def smoke_config(name: str):
    return SMOKE[name]()
