"""yCHG-JAX: a multi-pod JAX framework built around the data-parallel
yConvex Hypergraph algorithm (Jha, Agarwal, Kanna — ICS'13).

Public surface:
  repro.core       — the paper's contribution (column cut-vertex scan + transitions)
  repro.kernels    — Pallas TPU kernels for the scan (+ jnp oracles)
  repro.models     — assigned LM architectures (dense/GQA/MLA/MoE/SSM/RWKV/hybrid)
  repro.configs    — one config per assigned architecture (+ the paper's workload)
  repro.service    — batching/caching ROI request service over repro.engine
  repro.launch     — production mesh, multi-pod dry-run, train/serve drivers
"""

__version__ = "0.1.0"
