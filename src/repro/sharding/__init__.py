from repro.sharding.logical import (
    TRAIN_RULES,
    DECODE_RULES,
    make_rules,
    spec_for,
    param_shardings,
    tree_shardings,
)
from repro.sharding.ychg import (
    BATCH_AXIS,
    batch_sharded_analyze,
    make_batch_mesh,
    pad_batch,
)

__all__ = [
    "TRAIN_RULES",
    "DECODE_RULES",
    "make_rules",
    "spec_for",
    "param_shardings",
    "tree_shardings",
    "BATCH_AXIS",
    "batch_sharded_analyze",
    "make_batch_mesh",
    "pad_batch",
]
