from repro.sharding.logical import (
    TRAIN_RULES,
    DECODE_RULES,
    make_rules,
    spec_for,
    param_shardings,
    tree_shardings,
)

__all__ = [
    "TRAIN_RULES",
    "DECODE_RULES",
    "make_rules",
    "spec_for",
    "param_shardings",
    "tree_shardings",
]
