"""Logical-axis -> mesh-axis sharding rules (MaxText-style, with fallback).

Every param/activation/cache leaf carries a tuple of logical axis names
(see models/). A *rule table* maps logical names to mesh axis names (or
tuples for multi-axis sharding, or None). ``spec_for`` resolves a leaf to a
PartitionSpec against a concrete mesh with production guard rails:

  * mesh axes absent from the mesh are ignored (the same table works for
    the (data, model) single-pod mesh and the (pod, data, model) one);
  * a dim not divisible by its mesh-axis product *falls back* by dropping
    trailing axes until divisible (never crash on e.g. 14 heads vs 16-way
    TP — replicate instead, the dry-run records what actually sharded);
  * a mesh axis never appears twice in one spec (first dim wins).

Rule tables:
  TRAIN_RULES  — TP over "model" + FSDP ("embed" params over "data") +
                 batch DP over ("pod", "data").
  DECODE_RULES — TP over "model", batch over ("pod", "data"), no FSDP
                 (weights stay resident), cache seq replicated by default;
                 long-context cells override act_kv_seq -> "data" (sequence-
                 parallel KV) and act_batch -> None via ShapeConfig.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec

Rule = Union[None, str, Tuple[str, ...]]

TRAIN_RULES: Dict[str, Rule] = {
    # params
    "vocab": "model",
    # FSDP axis (ZeRO-3-style 2-D weight sharding); spans pods when present
    # (400B-param optimizer state does not fit one pod's worth of chips)
    "embed": ("pod", "data"),
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "heads_flat": "model",
    "embed_out": "model",
    "experts": "model",
    "q_lora": None,
    "kv_lora": None,
    "head": None,
    "layers": None,
    # activations
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_embed": None,
    "act_mlp": "model",
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_kv_seq": None,
    "act_vocab": "model",
}

DECODE_RULES: Dict[str, Rule] = {
    **TRAIN_RULES,
    "embed": None,            # no FSDP at inference
    # KV cache sharded along SEQUENCE over the TP axis (flash-decode style):
    # every assigned arch has kv_heads < 16, so head-sharding alone would
    # fall back to replication and a 32k cache would not fit HBM (the llama4
    # decode_32k cell measured 99.6 GiB/device under head-sharding fallback).
    # Softmax over the sharded axis lowers to a max/sum all-reduce pair.
    "act_kv_seq": "model",
    "act_kv_heads": None,
}


def make_rules(kind: str, overrides: Optional[Dict[str, Rule]] = None
               ) -> Dict[str, Rule]:
    base = TRAIN_RULES if kind in ("train", "prefill") else DECODE_RULES
    rules = dict(base)
    if overrides:
        rules.update(overrides)
    return rules


def _norm(rule: Rule, mesh) -> Tuple[str, ...]:
    if rule is None:
        return ()
    axes = (rule,) if isinstance(rule, str) else tuple(rule)
    return tuple(a for a in axes if a in mesh.shape)


def spec_for(
    logical_axes: Sequence[Optional[str]],
    rules: Dict[str, Rule],
    mesh,
    shape: Sequence[int],
) -> PartitionSpec:
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, logical_axes):
        axes = () if name is None else _norm(rules.get(name), mesh)
        axes = tuple(a for a in axes if a not in used)
        # divisibility fallback: drop trailing axes until the dim divides
        while axes:
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if dim % prod == 0:
                break
            axes = axes[:-1]
        used.update(axes)
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(axes)
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def tree_shardings(axes_tree, rules: Dict[str, Rule], mesh, shape_tree):
    """Map (logical-axes tree, shape tree) -> NamedSharding tree."""

    def one(axes, leaf):
        return NamedSharding(mesh, spec_for(axes, rules, mesh, leaf.shape))

    return jax.tree_util.tree_map(
        one, axes_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x
        ),
    )


def param_shardings(cfg, rules, mesh):
    from repro.models import abstract_params, param_logical_axes

    return tree_shardings(param_logical_axes(cfg), rules, mesh, abstract_params(cfg))
