"""Batch sharding for yCHG scene stacks: shard_map over the fused kernel.

The MODIS deployment scenario processes stacks of (H, W) scene tiles. The
fused kernel already batches a whole stack into one launch; this module
splits the batch across a 1-D device mesh so every device runs one fused
launch on its shard — per-column planes and per-image totals are already
per-image, so no cross-device collective is needed (out_specs keep the
batch axis sharded and JAX reassembles the global arrays).

Single-host CPU containers see a 1-device mesh and degrade to the plain
fused call; a TPU pod slice shards B ways for free. Ragged batches are
padded with blank images (zero runs, zero hyperedges) to a multiple of the
mesh size and sliced back, so callers never have to align their stacks.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.ychg import YCHGSummary
from repro.kernels import ops as kops

Array = jax.Array

BATCH_AXIS = "data"


def make_batch_mesh(axis_name: str = BATCH_AXIS, devices: Optional[Sequence] = None
                    ) -> Mesh:
    """1-D mesh over all local devices (or an explicit device list)."""
    devices = list(jax.devices()) if devices is None else list(devices)
    return Mesh(np.asarray(devices), (axis_name,))


def pad_batch(imgs: Array, multiple: int) -> tuple[Array, int]:
    """Pad the leading batch dim with blank images to a multiple; returns
    (padded stack, original batch size). Blank images contribute zero runs
    and zero hyperedges, so the padding is inert end to end."""
    b = imgs.shape[0]
    pad = -b % multiple
    if pad:
        imgs = jnp.pad(imgs, ((0, pad),) + ((0, 0),) * (imgs.ndim - 1))
    return imgs, b


def batch_sharded_analyze(
    imgs: Array,
    *,
    mesh: Optional[Mesh] = None,
    axis_name: str = BATCH_AXIS,
    block_w: int = 128,
    block_h: int = 2048,
    interpret: bool | None = None,
) -> YCHGSummary:
    """(B, H, W) stack -> YCHGSummary, batch-sharded over the mesh.

    Bit-identical to ``core.ychg.analyze`` on the same stack: each device
    runs ``kernels.ops.analyze_fused`` on its B/n shard (one fused kernel
    launch per device), and results are reassembled along the batch axis.
    """
    if imgs.ndim != 3:
        raise ValueError(f"expected (B, H, W) stack, got {imgs.shape}")
    mesh = make_batch_mesh(axis_name) if mesh is None else mesh
    x, b = pad_batch(imgs, mesh.shape[axis_name])

    def local(xs: Array):
        s = kops.analyze_fused(
            xs, block_w=block_w, block_h=block_h, interpret=interpret
        )
        return (s.runs, s.cut_vertices, s.transitions, s.births, s.deaths,
                s.n_hyperedges, s.n_transitions)

    spec = P(axis_name)
    runs, cuts, trans, births, deaths, nh, nt = shard_map(
        local, mesh=mesh, in_specs=spec, out_specs=spec, check_rep=False
    )(x)
    return YCHGSummary(
        runs=runs[:b],
        cut_vertices=cuts[:b],
        transitions=trans[:b],
        births=births[:b],
        deaths=deaths[:b],
        n_hyperedges=nh[:b],
        n_transitions=nt[:b],
    )
