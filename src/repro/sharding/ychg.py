"""Batch-mesh helpers for yCHG scene stacks + the deprecated shard_map shim.

The shard_map path now lives inside the engine: it is simply the fused
backend with a mesh attached (``Engine(cfg, mesh=mesh)`` — see
``repro.engine.engine.Engine._run_meshed``). The engine pads ragged
batches with blank images (zero runs, zero hyperedges — inert end to end)
to a multiple of the mesh size and strips the pad internally, so callers
never see padded-length results.

This module keeps the mesh/padding utilities (``make_batch_mesh``,
``pad_batch``) and ``batch_sharded_analyze`` as a DEPRECATED shim that
delegates to the engine. Single-host CPU containers see a 1-device mesh
and degrade to the plain fused call; a TPU pod slice shards B ways for
free.
"""

from __future__ import annotations

import warnings
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.ychg import YCHGSummary

Array = jax.Array

BATCH_AXIS = "data"


def make_batch_mesh(axis_name: str = BATCH_AXIS, devices: Optional[Sequence] = None
                    ) -> Mesh:
    """1-D mesh over all local devices (or an explicit device list)."""
    devices = list(jax.devices()) if devices is None else list(devices)
    return Mesh(np.asarray(devices), (axis_name,))


def pad_batch(imgs: Array, multiple: int) -> tuple[Array, int]:
    """Pad the leading batch dim with blank images to a multiple; returns
    (padded stack, original batch size). Blank images contribute zero runs
    and zero hyperedges, so the padding is inert end to end."""
    b = imgs.shape[0]
    pad = -b % multiple
    if pad:
        imgs = jnp.pad(imgs, ((0, pad),) + ((0, 0),) * (imgs.ndim - 1))
    return imgs, b


def batch_sharded_analyze(
    imgs: Array,
    *,
    mesh: Optional[Mesh] = None,
    axis_name: str = BATCH_AXIS,
    block_w: int = 128,
    block_h: int = 2048,
    interpret: bool | None = None,
) -> YCHGSummary:
    """DEPRECATED: use ``Engine(cfg, mesh=mesh).analyze_batch(imgs)``.

    (B, H, W) stack -> YCHGSummary, batch-sharded over the mesh; bit-identical
    to ``core.ychg.analyze`` on the same stack. Kept as a thin shim over the
    engine's mesh path for old callers.
    """
    warnings.warn(
        "repro.sharding.batch_sharded_analyze is deprecated; use "
        "repro.engine.Engine(YCHGConfig(backend='fused'), mesh=mesh)"
        ".analyze_batch(imgs)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.engine import Engine, YCHGConfig

    engine = Engine(
        YCHGConfig(backend="fused", block_w=block_w, block_h=block_h,
                   mesh_axis=axis_name, interpret=interpret),
        mesh=make_batch_mesh(axis_name) if mesh is None else mesh,
    )
    return engine.analyze_batch(imgs).to_summary()
