"""Scene-level yCHG results and their deterministic on-disk form.

A :class:`SceneResult` carries the same seven fields ``YCHGResult.to_host()``
produces for a single mask — per-column arrays of width W plus the two
scalar reductions — computed for a whole granule, however it was tiled.

The serialisation is a custom header+raw-bytes layout rather than
``np.savez`` because **byte-identity is the contract**: a bulk job killed
mid-scene and resumed must write files byte-identical to an uninterrupted
run, and zip archives embed member timestamps that would break that for
free. Here the bytes are a pure function of the content: a fixed magic, a
sorted-key JSON header (shapes, dtypes, scene metadata), then each field's
C-order buffer in a fixed field order. Writes go to a temp file in the
same directory and ``os.replace`` into place, so readers never observe a
half-written result and a kill mid-write leaves only a ``.tmp`` file.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict

import numpy as np

_MAGIC = b"YCHGSCENE1\n"
# field order is part of the format — never reorder
FIELDS = ("runs", "cut_vertices", "transitions", "births", "deaths",
          "n_hyperedges", "n_transitions")


@dataclasses.dataclass(frozen=True)
class SceneResult:
    """Whole-granule yCHG output on the host, plus how it was produced."""

    granule_id: str
    height: int
    width: int
    tile_h: int
    n_tiles: int
    runs: np.ndarray           # (W,) int32
    cut_vertices: np.ndarray   # (W,) int32
    transitions: np.ndarray    # (W,) bool
    births: np.ndarray         # (W,) int32
    deaths: np.ndarray         # (W,) int32
    n_hyperedges: np.ndarray   # ()   int32
    n_transitions: np.ndarray  # ()   int32

    def to_host(self) -> Dict[str, np.ndarray]:
        """The ``YCHGResult.to_host()``-shaped dict for parity checks."""
        return {f: getattr(self, f) for f in FIELDS}

    def to_bytes(self) -> bytes:
        header = {
            "granule_id": self.granule_id,
            "height": self.height,
            "width": self.width,
            "tile_h": self.tile_h,
            "n_tiles": self.n_tiles,
            "fields": {
                f: {"shape": list(getattr(self, f).shape),
                    "dtype": str(getattr(self, f).dtype)}
                for f in FIELDS
            },
        }
        head = json.dumps(header, sort_keys=True,
                          separators=(",", ":")).encode()
        parts = [_MAGIC, len(head).to_bytes(8, "little"), head]
        for f in FIELDS:
            parts.append(np.ascontiguousarray(getattr(self, f)).tobytes())
        return b"".join(parts)

    @classmethod
    def from_bytes(cls, blob: bytes) -> "SceneResult":
        if blob[: len(_MAGIC)] != _MAGIC:
            raise ValueError("not a scene result file (bad magic)")
        off = len(_MAGIC)
        head_len = int.from_bytes(blob[off: off + 8], "little")
        off += 8
        header = json.loads(blob[off: off + head_len])
        off += head_len
        arrays = {}
        for f in FIELDS:
            meta = header["fields"][f]
            dt = np.dtype(meta["dtype"])
            shape = tuple(meta["shape"])
            n = dt.itemsize * int(np.prod(shape, dtype=np.int64)) \
                if shape else dt.itemsize
            arrays[f] = np.frombuffer(
                blob[off: off + n], dtype=dt).reshape(shape).copy()
            off += n
        if off != len(blob):
            raise ValueError(
                f"scene result file has {len(blob) - off} trailing bytes")
        return cls(granule_id=header["granule_id"], height=header["height"],
                   width=header["width"], tile_h=header["tile_h"],
                   n_tiles=header["n_tiles"], **arrays)


def write_scene_result(path: str, result: SceneResult) -> str:
    """Atomic write (temp + rename); returns ``path``."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(result.to_bytes())
    os.replace(tmp, path)
    return path


def read_scene_result(path: str) -> SceneResult:
    with open(path, "rb") as f:
        return SceneResult.from_bytes(f.read())
