"""Granule sources and the tile-row windowing reader.

A *granule* is one arbitrarily large (H, W) binary scene — a whole MODIS
snow-cover grid, not a service-sized mask. :class:`GranuleReader` windows
it into **overlap-free full-width tile rows** (horizontal strips of
``tile_h`` rows; the last strip is zero-padded at the bottom so every tile
the engine sees has the same static shape — pad rows below a column add no
rising edge, so they are inert to yCHG). Strips deliberately do NOT
overlap: the run that crosses a strip boundary is reconciled exactly by
the seam correction in :mod:`repro.scene.runner`, the same carry-row idea
the streamed Pallas kernel applies between its H-tiles, lifted to scene
scale.

Two backing stores, one read API:

  * ``kind="synthetic"`` — :func:`repro.data.scenes.scene_rows`, a pure
    function of (seed, row window): nothing is ever materialised beyond
    the strip being read, so a synthetic granule can be any size;
  * ``kind="memmap"`` — a ``.npy`` file opened with ``mmap_mode="r"``:
    the OS pages in only the rows a strip touches.

``GranuleSpec`` is a frozen, JSON-serialisable description, so a bulk-job
manifest is just a list of specs (``manifest_to_json`` / ``manifest_from_json``).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.data import scenes


@dataclasses.dataclass(frozen=True)
class GranuleSpec:
    """One granule of a bulk-job manifest (frozen, JSON round-trippable)."""

    granule_id: str
    height: int
    width: int
    kind: str = "synthetic"          # "synthetic" | "memmap"
    path: Optional[str] = None       # .npy path for kind="memmap"
    seed: int = 0                    # synthetic content knobs
    cell: int = 64
    coverage: float = 0.45
    dtype: str = "uint8"

    def __post_init__(self):
        if self.height < 1 or self.width < 1:
            raise ValueError(
                f"granule {self.granule_id!r}: size {self.height}x"
                f"{self.width} must be >= 1x1")
        if self.kind not in ("synthetic", "memmap"):
            raise ValueError(f"unknown granule kind {self.kind!r}")
        if self.kind == "memmap" and not self.path:
            raise ValueError(
                f"granule {self.granule_id!r}: kind='memmap' needs a path")

    @property
    def pixels(self) -> int:
        return self.height * self.width


def manifest_to_json(manifest: Sequence[GranuleSpec]) -> str:
    return json.dumps([dataclasses.asdict(s) for s in manifest], indent=2)


def manifest_from_json(text: str) -> List[GranuleSpec]:
    return [GranuleSpec(**obj) for obj in json.loads(text)]


def synthetic_manifest(n_granules: int, height: int, width: int, *,
                       seed: int = 0, cell: int = 64,
                       coverage: float = 0.45) -> List[GranuleSpec]:
    """N same-sized synthetic granules with distinct content seeds."""
    return [
        GranuleSpec(granule_id=f"granule_{seed + i:04d}", height=height,
                    width=width, seed=seed + i, cell=cell, coverage=coverage)
        for i in range(n_granules)
    ]


class GranuleReader:
    """Windows one granule into (tile_h, W) strips, read on demand.

    ``read_stack(t0, n)`` returns strips ``[t0, t0+n)`` as one
    ``(n, tile_h, W)`` host array ready for ``engine.analyze_batch`` —
    the scene runner's unit of device work. Only the requested rows are
    touched, whatever the granule's total size.
    """

    def __init__(self, source: Any, tile_h: int, *,
                 granule_id: str = "granule"):
        if tile_h < 1:
            raise ValueError(f"tile_h must be >= 1, got {tile_h}")
        self._source = source
        self.tile_h = tile_h
        self.granule_id = granule_id
        self.height, self.width = source.shape if hasattr(source, "shape") \
            else (source.height, source.width)
        if self.height < 1 or self.width < 1:
            raise ValueError(
                f"scene must be >= 1x1, got {self.height}x{self.width}")
        self.n_tiles = -(-self.height // tile_h)

    # ------------------------------------------------------------- builders

    @classmethod
    def from_array(cls, arr: np.ndarray, tile_h: int, *,
                   granule_id: str = "granule") -> "GranuleReader":
        if arr.ndim != 2:
            raise ValueError(f"expected an (H, W) scene, got {arr.shape}")
        return cls(arr, tile_h, granule_id=granule_id)

    @classmethod
    def from_npy(cls, path: str, tile_h: int, *,
                 granule_id: Optional[str] = None) -> "GranuleReader":
        """Memory-mapped .npy scene: strips page in on read, never whole."""
        arr = np.load(path, mmap_mode="r")
        if arr.ndim != 2:
            raise ValueError(f"{path}: expected an (H, W) scene, "
                             f"got {arr.shape}")
        return cls(arr, tile_h, granule_id=granule_id or path)

    @classmethod
    def open(cls, spec: GranuleSpec, tile_h: int) -> "GranuleReader":
        if spec.kind == "memmap":
            reader = cls.from_npy(spec.path, tile_h,
                                  granule_id=spec.granule_id)
            if (reader.height, reader.width) != (spec.height, spec.width):
                raise ValueError(
                    f"granule {spec.granule_id!r}: {spec.path} is "
                    f"{reader.height}x{reader.width}, manifest says "
                    f"{spec.height}x{spec.width}")
            return reader
        return cls(_SyntheticSource(spec), tile_h,
                   granule_id=spec.granule_id)

    # -------------------------------------------------------------- reading

    def tile_rows(self, t: int) -> Tuple[int, int]:
        """Real scene rows [row0, row1) covered by strip ``t``."""
        if not 0 <= t < self.n_tiles:
            raise IndexError(f"tile {t} out of range [0, {self.n_tiles})")
        row0 = t * self.tile_h
        return row0, min(row0 + self.tile_h, self.height)

    def read_tile(self, t: int) -> np.ndarray:
        """Strip ``t`` as a (tile_h, W) array (last strip zero-padded)."""
        row0, row1 = self.tile_rows(t)
        rows = np.asarray(self._read_rows(row0, row1))
        if row1 - row0 == self.tile_h:
            return rows
        out = np.zeros((self.tile_h, self.width), rows.dtype)
        out[: row1 - row0] = rows
        return out

    def read_stack(self, t0: int, n: int) -> np.ndarray:
        """Strips [t0, t0+n) as one contiguous (n, tile_h, W) stack."""
        if n < 1 or t0 < 0 or t0 + n > self.n_tiles:
            raise IndexError(
                f"stack [{t0}, {t0 + n}) out of range [0, {self.n_tiles})")
        row0 = t0 * self.tile_h
        row1 = min(row0 + n * self.tile_h, self.height)
        rows = np.asarray(self._read_rows(row0, row1))
        stack = np.zeros((n, self.tile_h, self.width), rows.dtype)
        flat = stack.reshape(n * self.tile_h, self.width)
        flat[: row1 - row0] = rows
        return stack

    def _read_rows(self, row0: int, row1: int) -> np.ndarray:
        if hasattr(self._source, "read_rows"):
            return self._source.read_rows(row0, row1)
        return self._source[row0:row1]


class _SyntheticSource:
    """Row-window view over :func:`repro.data.scenes.scene_rows`."""

    def __init__(self, spec: GranuleSpec):
        self.spec = spec
        self.height = spec.height
        self.width = spec.width

    def read_rows(self, row0: int, row1: int) -> np.ndarray:
        s = self.spec
        return scenes.scene_rows(
            s.height, s.width, row0, row1, seed=s.seed, cell=s.cell,
            coverage=s.coverage, dtype=np.dtype(s.dtype))
