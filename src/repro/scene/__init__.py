"""repro.scene — granule-scale streaming analysis and resumable bulk jobs.

The offline/bulk counterpart of :mod:`repro.service`: where the service
micro-batches many small independent masks, this package takes scenes too
large for one device call, windows them into overlap-free tile rows
(:class:`GranuleReader`), streams tile stacks through a
:class:`repro.engine.Engine` (mesh-aware, double-buffered), and
stitches per-tile outputs into a whole-scene result **bit-identical** to
analysing the unsplit scene (:class:`SceneRunner`). :class:`BulkJob` runs
a manifest of granules as a resumable batch job: progress is checkpointed
via :class:`repro.checkpoint.Checkpointer`, and a job killed mid-scene
resumes from the last completed tile row with byte-identical output.
"""

from repro.scene.bulk import BulkJob, BulkJobConfig, BulkJobReport
from repro.scene.granule import (
    GranuleReader,
    GranuleSpec,
    manifest_from_json,
    manifest_to_json,
    synthetic_manifest,
)
from repro.scene.result import (
    SceneResult,
    read_scene_result,
    write_scene_result,
)
from repro.scene.runner import (
    SceneProgress,
    SceneProgressSnapshot,
    SceneRunner,
    SceneState,
    seam_joins,
    stitch_tile_runs,
)

__all__ = [
    "BulkJob",
    "BulkJobConfig",
    "BulkJobReport",
    "GranuleReader",
    "GranuleSpec",
    "SceneProgress",
    "SceneProgressSnapshot",
    "SceneResult",
    "SceneRunner",
    "SceneState",
    "manifest_from_json",
    "manifest_to_json",
    "read_scene_result",
    "seam_joins",
    "stitch_tile_runs",
    "synthetic_manifest",
    "write_scene_result",
]
