"""SceneRunner — tile-stack streaming analysis with exact seam stitching.

Why tiling is exact here (the stitch invariant the tests pin): yCHG step 1
is a per-column count of rising edges down the scene. Split the scene into
full-width strips and count each strip independently, and every run that
*crosses* a strip boundary is counted twice — once by the strip that ends
it and once by the strip that starts it, because the lower strip sees its
first row with no predecessor. The overcount at each seam is exactly

    seam[j] = (bottom row of upper strip)[j] nonzero
              AND (top row of lower strip)[j] nonzero

so ``scene_runs = sum(strip_runs) - sum(seams)`` reproduces the
whole-scene count **bit for bit** (pure int32 arithmetic, no tolerance).
This is the streamed Pallas kernel's carry-row recurrence lifted from
VMEM tiles to host-scale strips; step 2 (births/deaths/transitions) is
then computed once from the stitched run vector with the same
``core.ychg`` formulas the engine backends are held bit-identical to, so
the full seven-field result equals a single whole-scene ``engine.analyze``
call — dtypes included.

The runner streams (stack_tiles, tile_h, W) stacks through
``engine.analyze_stream`` (strip ingest overlaps device compute); when the
engine carries a mesh, each stack is shard_mapped across its devices —
``Engine._run_meshed`` already pads ragged stacks, so the runner does
not care. Inside each strip, tall tiles past the VMEM budget take the
kernel's own streamed carry-row variant via the engine's existing
heuristic. State between stacks is three small host arrays
(:class:`SceneState`), which is what makes bulk jobs checkpointable: a
resumed job restores the state and continues from the next tile row.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Iterator, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import ychg
from repro.engine import Engine
from repro.obs import maybe_trace
from repro.scene.granule import GranuleReader
from repro.scene.result import SceneResult

DEFAULT_STACK_TILES = 4


# --------------------------------------------------------------- progress


@dataclasses.dataclass(frozen=True)
class SceneProgressSnapshot:
    """Point-in-time view of a scene/bulk job (immutable)."""

    tiles_done: int = 0
    tiles_total: int = 0
    granules_done: int = 0
    granules_total: int = 0
    resumes: int = 0
    stitch_time_s: float = 0.0


class SceneProgress:
    """Thread-safe progress sink shared by runner, bulk job, and metrics.

    Attach to a :class:`repro.service.YCHGService` via
    ``service.attach_scene_progress(progress)`` and the counters surface
    in ``ServiceMetrics`` and on the frontend ``/metrics`` page.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._snap = SceneProgressSnapshot()

    def set_totals(self, *, tiles: int, granules: int) -> None:
        with self._lock:
            self._snap = dataclasses.replace(
                self._snap, tiles_total=tiles, granules_total=granules)

    def note_tiles(self, n: int) -> None:
        with self._lock:
            self._snap = dataclasses.replace(
                self._snap, tiles_done=self._snap.tiles_done + n)

    def note_granule_done(self) -> None:
        with self._lock:
            self._snap = dataclasses.replace(
                self._snap, granules_done=self._snap.granules_done + 1)

    def note_resume(self) -> None:
        with self._lock:
            self._snap = dataclasses.replace(
                self._snap, resumes=self._snap.resumes + 1)

    def note_stitch(self, dt_s: float) -> None:
        with self._lock:
            self._snap = dataclasses.replace(
                self._snap, stitch_time_s=self._snap.stitch_time_s + dt_s)

    def snapshot(self) -> SceneProgressSnapshot:
        with self._lock:
            return self._snap


# ------------------------------------------------------------------ state


@dataclasses.dataclass
class SceneState:
    """Resumable per-granule accumulator: everything a restart needs.

    ``runs`` is the seam-corrected per-column run count over tiles
    ``[0, next_tile)``; ``prev_bottom`` is the binarised last real row of
    the most recent strip (the carry row for the next seam). All three
    are plain host arrays, so the state round-trips through
    :class:`repro.checkpoint.Checkpointer` as a pytree.
    """

    next_tile: int
    runs: np.ndarray         # (W,) int32
    prev_bottom: np.ndarray  # (W,) uint8 (0/1)

    @classmethod
    def fresh(cls, width: int) -> "SceneState":
        return cls(next_tile=0, runs=np.zeros(width, np.int32),
                   prev_bottom=np.zeros(width, np.uint8))


def seam_joins(bottom_row: np.ndarray, top_row: np.ndarray) -> np.ndarray:
    """(W,) int32 count of runs continuing across one strip boundary."""
    return ((np.asarray(bottom_row) != 0)
            & (np.asarray(top_row) != 0)).astype(np.int32)


def stitch_tile_runs(tile_runs: Sequence[np.ndarray],
                     tiles: Sequence[np.ndarray]) -> np.ndarray:
    """Stitch per-strip run counts analysed *independently* (no carry).

    ``tile_runs[i]`` must be the (W,) step-1 output for strip ``tiles[i]``
    — e.g. per-tile results replayed through the HTTP front end — and the
    strips must be consecutive and overlap-free. Returns the whole-scene
    (W,) int32 run vector, bit-identical to analysing the unsplit scene.
    """
    if len(tile_runs) != len(tiles):
        raise ValueError(f"{len(tile_runs)} run vectors for "
                         f"{len(tiles)} tiles")
    total = np.zeros_like(np.asarray(tile_runs[0], np.int32))
    prev_bottom: Optional[np.ndarray] = None
    for runs, tile in zip(tile_runs, tiles):
        tile = np.asarray(tile)
        total += np.asarray(runs, np.int32)
        if prev_bottom is not None:
            total -= seam_joins(prev_bottom, tile[0])
        prev_bottom = tile[-1]
    return total


# ----------------------------------------------------------------- runner


class SceneRunner:
    """Streams one granule's tile stacks through an engine and stitches.

    The engine is used as-is: its backend policy, tile sizes, and optional
    mesh all apply per stack. ``stack_tiles`` strips batch into one
    ``(stack_tiles, tile_h, W)`` device computation.
    """

    def __init__(self, engine: Optional[Engine] = None, *,
                 stack_tiles: int = DEFAULT_STACK_TILES):
        if stack_tiles < 1:
            raise ValueError(f"stack_tiles must be >= 1, got {stack_tiles}")
        self.engine = engine if engine is not None else Engine()
        self.stack_tiles = stack_tiles

    # -- incremental API (what BulkJob drives) ------------------------------

    def update(self, state: SceneState, stack: np.ndarray,
               runs_b: np.ndarray) -> SceneState:
        """Fold one analysed stack into the accumulator (in place).

        ``stack`` is the (b, tile_h, W) host strips; ``runs_b`` the
        matching (b, W) step-1 output. Seam corrections use the strips'
        own boundary rows, so the math is exact whatever ``b`` was.
        """
        stack = np.asarray(stack)
        runs_b = np.asarray(runs_b)
        b = stack.shape[0]
        tops = stack[:, 0, :] != 0
        bottoms = stack[:, -1, :] != 0
        prevs = np.concatenate(
            [(state.prev_bottom != 0)[None], bottoms[:-1]], axis=0)
        seams = tops & prevs
        state.runs += (runs_b.sum(axis=0, dtype=np.int32)
                       - seams.sum(axis=0, dtype=np.int32))
        state.prev_bottom = bottoms[-1].astype(np.uint8)
        state.next_tile += b
        return state

    def finalize(self, reader: GranuleReader, state: SceneState,
                 progress: Optional[SceneProgress] = None) -> SceneResult:
        """Stitched runs -> the full seven-field scene result.

        Step 2 runs once over the stitched (W,) vector with the exact
        ``core.ychg`` formulas (dtypes included), so the output equals a
        single whole-scene ``engine.analyze`` call bit for bit.
        """
        if state.next_tile != reader.n_tiles:
            raise ValueError(
                f"granule {reader.granule_id!r}: finalize at tile "
                f"{state.next_tile} of {reader.n_tiles}")
        t0 = time.perf_counter()
        runs = jnp.asarray(state.runs)
        t = ychg.hyperedge_transitions(runs)
        result = SceneResult(
            granule_id=reader.granule_id,
            height=reader.height,
            width=reader.width,
            tile_h=reader.tile_h,
            n_tiles=reader.n_tiles,
            runs=np.asarray(runs),
            cut_vertices=np.asarray(2 * runs),
            transitions=np.asarray(t["transitions"]),
            births=np.asarray(t["births"]),
            deaths=np.asarray(t["deaths"]),
            n_hyperedges=np.asarray(jnp.sum(t["births"], axis=-1)),
            n_transitions=np.asarray(
                jnp.sum(t["transitions"], axis=-1, dtype=jnp.int32)),
        )
        if progress is not None:
            progress.note_stitch(time.perf_counter() - t0)
        return result

    # -- one-call streaming API ---------------------------------------------

    def analyze_scene(self, reader: GranuleReader, *,
                      progress: Optional[SceneProgress] = None,
                      state: Optional[SceneState] = None,
                      trace=None) -> SceneResult:
        """Stream the whole granule (from ``state`` if given) and stitch.

        Stacks flow through ``engine.analyze_stream``, so strip reading
        and host->device transfer of stack n+1 overlap the device compute
        of stack n — the service's double-buffering discipline applied to
        the offline path. When tracing is on, each stack leaves
        ``scene.read`` / ``scene.compute`` (stream wait, which overlaps
        the *next* read by design) / ``scene.stitch`` spans plus one
        ``scene.finalize`` span on the trace.
        """
        tr = trace if trace is not None else maybe_trace(process="scene")
        own = trace is None
        state = state if state is not None else SceneState.fresh(reader.width)
        pending: "collections.deque[np.ndarray]" = collections.deque()

        def stacks() -> Iterator[np.ndarray]:
            t = state.next_tile
            while t < reader.n_tiles:
                n = min(self.stack_tiles, reader.n_tiles - t)
                r0 = time.monotonic()
                s = reader.read_stack(t, n)
                tr.add("scene.read", r0, time.monotonic(),
                       granule=reader.granule_id, tile=t, tiles=n)
                pending.append(s)
                yield s
                t += n

        try:
            t_wait = time.monotonic()
            for res in self.engine.analyze_stream(stacks()):
                t_got = time.monotonic()
                stack = pending.popleft()
                tr.add("scene.compute", t_wait, t_got,
                       granule=reader.granule_id, tiles=stack.shape[0])
                s0 = time.monotonic()
                self.update(state, stack, np.asarray(res.runs))
                s1 = time.monotonic()
                tr.add("scene.stitch", s0, s1, granule=reader.granule_id)
                if progress is not None:
                    progress.note_stitch(s1 - s0)
                    progress.note_tiles(stack.shape[0])
                t_wait = time.monotonic()
            f0 = time.monotonic()
            result = self.finalize(reader, state, progress)
            tr.add("scene.finalize", f0, time.monotonic(),
                   granule=reader.granule_id)
            return result
        finally:
            if own:
                tr.finish()
