"""BulkJob — a manifest of granules as one resumable, checkpointed batch.

The job walks its manifest in order, streaming each granule's tile stacks
through a :class:`SceneRunner` and writing one deterministic result file
per granule (``<out_dir>/<granule_id>.ychg``, atomic temp+rename). Its
whole restartable state is tiny — which granule, which tile row, the
stitched run accumulator, and the carry row — and is checkpointed through
:class:`repro.checkpoint.Checkpointer` every ``checkpoint_every`` stacks
and at every granule boundary.

Resume contract (asserted by tests/test_scene.py and the scene-smoke CI
job): kill the job at any point — SIGTERM between stacks, or a hard kill
that corrupts the newest checkpoint (the Checkpointer falls back to the
newest *valid* one) — restart it with the same manifest and directories,
and the bytes written to ``out_dir`` are identical to an uninterrupted
run. That holds because (a) tile content is a pure function of the
granule spec (synthetic) or the backing file (memmap), (b) the engine is
deterministic, (c) the stitch is exact integer arithmetic whose partial
sums are exactly what the checkpoint stores, and (d) the result encoding
is content-determined (no timestamps). Work after the last checkpoint is
simply recomputed — at most ``checkpoint_every`` stacks.

Checkpoint steps are ``granule_index * 10**9 + next_tile``: monotone over
the whole job, and human-readable in the checkpoint directory.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.checkpoint import Checkpointer
from repro.engine import Engine
from repro.obs import NULL_TRACE, maybe_trace
from repro.scene.granule import GranuleReader, GranuleSpec
from repro.scene.result import write_scene_result
from repro.scene.runner import (
    DEFAULT_STACK_TILES,
    SceneProgress,
    SceneRunner,
    SceneState,
)

_GRANULE_STRIDE = 10**9  # tiles per granule bound encoded into step numbers

# restore() template: dtypes matter (values are cast onto these), shapes
# are taken from the checkpoint itself
_STATE_LIKE = {
    "granule": np.zeros((), np.int64),
    "next_tile": np.zeros((), np.int64),
    "runs": np.zeros(1, np.int32),
    "prev_bottom": np.zeros(1, np.uint8),
    "resumes": np.zeros((), np.int64),
}


@dataclasses.dataclass(frozen=True)
class BulkJobConfig:
    out_dir: str
    ckpt_dir: str
    tile_h: int = 256
    stack_tiles: int = DEFAULT_STACK_TILES
    checkpoint_every: int = 4      # stacks between mid-granule checkpoints
    keep: int = 3                  # Checkpointer GC depth


@dataclasses.dataclass(frozen=True)
class BulkJobReport:
    """What one ``run()`` call did (counts are for this run only)."""

    status: str                    # "completed" | "interrupted"
    granules_done: int
    tiles_done: int
    stacks_done: int
    resumes: int                   # cumulative across the job's lifetime
    written: List[str]             # result files written this run
    elapsed_s: float

    @property
    def completed(self) -> bool:
        return self.status == "completed"


class BulkJob:
    """Run a granule manifest to completion, resumably."""

    def __init__(self, engine: Optional[Engine],
                 manifest: Sequence[GranuleSpec], config: BulkJobConfig, *,
                 progress: Optional[SceneProgress] = None):
        if not manifest:
            raise ValueError("empty granule manifest")
        ids = [s.granule_id for s in manifest]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate granule_id in manifest: {ids}")
        self.manifest = list(manifest)
        self.config = config
        self.runner = SceneRunner(engine, stack_tiles=config.stack_tiles)
        self.progress = progress
        self._ckpt = Checkpointer(config.ckpt_dir, keep=config.keep)
        os.makedirs(config.out_dir, exist_ok=True)

    def output_path(self, spec: GranuleSpec) -> str:
        return os.path.join(self.config.out_dir, f"{spec.granule_id}.ychg")

    # ------------------------------------------------------------ checkpoint

    def _save(self, granule_idx: int, state: SceneState, resumes: int) -> None:
        tree = {
            "granule": np.int64(granule_idx),
            "next_tile": np.int64(state.next_tile),
            "runs": state.runs,
            "prev_bottom": state.prev_bottom,
            "resumes": np.int64(resumes),
        }
        self._ckpt.save(granule_idx * _GRANULE_STRIDE + state.next_tile, tree)

    def _restore(self) -> Optional[tuple[int, SceneState, int]]:
        """(granule index, state, prior resume count) from the newest
        valid checkpoint, or None for a cold start. Corrupt checkpoints
        are skipped (with a warning) by ``Checkpointer.latest_step``."""
        step = self._ckpt.latest_step()
        if step is None:
            return None
        tree = self._ckpt.restore(step, like=_STATE_LIKE)
        gi = int(np.asarray(tree["granule"]))
        state = SceneState(
            next_tile=int(np.asarray(tree["next_tile"])),
            runs=np.asarray(tree["runs"], np.int32).copy(),
            prev_bottom=np.asarray(tree["prev_bottom"], np.uint8).copy(),
        )
        if gi < len(self.manifest):
            spec = self.manifest[gi]
            if state.runs.shape != (spec.width,):
                raise ValueError(
                    f"checkpoint step {step} has width "
                    f"{state.runs.shape[0]} but manifest granule "
                    f"{spec.granule_id!r} is {spec.width} wide — was the "
                    f"manifest changed under a live checkpoint directory?")
        return gi, state, int(np.asarray(tree["resumes"]))

    # ------------------------------------------------------------------ run

    def run(self, *, max_stacks: Optional[int] = None,
            should_stop: Optional[Callable[[], bool]] = None
            ) -> BulkJobReport:
        """Process until done, stopped, or out of budget.

        ``should_stop`` is polled between stacks (wire a SIGTERM handler
        to it); ``max_stacks`` bounds this run's device work (tests use it
        to stop deterministically mid-granule). Either exit checkpoints
        the current state first, so the next ``run()`` resumes from the
        last completed tile row.
        """
        t_start = time.perf_counter()
        cfg = self.config
        start_gi, state, resumes = 0, None, 0
        restored = self._restore()
        if restored is not None:
            start_gi, state, resumes = restored
            resumes += 1
            if self.progress is not None:
                self.progress.note_resume()
        if self.progress is not None:
            self.progress.set_totals(
                tiles=sum(-(-s.height // cfg.tile_h) for s in self.manifest),
                granules=len(self.manifest))

        stacks_done = tiles_done = granules_done = 0
        written: List[str] = []
        tr = NULL_TRACE  # current granule's trace (one trace per granule)

        def save_ckpt(gi: int, st: SceneState) -> None:
            c0 = time.monotonic()
            self._save(gi, st, resumes)
            tr.add("scene.checkpoint", c0, time.monotonic(),
                   granule=gi, tile=st.next_tile)

        def interrupted(gi: int, st: SceneState) -> BulkJobReport:
            save_ckpt(gi, st)
            tr.finish()
            return BulkJobReport(
                status="interrupted", granules_done=granules_done,
                tiles_done=tiles_done, stacks_done=stacks_done,
                resumes=resumes, written=written,
                elapsed_s=time.perf_counter() - t_start)

        for gi in range(start_gi, len(self.manifest)):
            spec = self.manifest[gi]
            reader = GranuleReader.open(spec, cfg.tile_h)
            if state is None:
                state = SceneState.fresh(reader.width)
            tr = maybe_trace(process="scene")
            since_ckpt = 0
            while state.next_tile < reader.n_tiles:
                if should_stop is not None and should_stop():
                    return interrupted(gi, state)
                if max_stacks is not None and stacks_done >= max_stacks:
                    return interrupted(gi, state)
                n = min(cfg.stack_tiles, reader.n_tiles - state.next_tile)
                r0 = time.monotonic()
                stack = reader.read_stack(state.next_tile, n)
                r1 = time.monotonic()
                tr.add("scene.read", r0, r1, granule=spec.granule_id,
                       tile=state.next_tile, tiles=n)
                res = self.runner.engine.analyze_batch(stack)
                runs = np.asarray(res.runs)
                c1 = time.monotonic()
                tr.add("scene.compute", r1, c1, granule=spec.granule_id,
                       tiles=n)
                self.runner.update(state, stack, runs)
                tr.add("scene.stitch", c1, time.monotonic(),
                       granule=spec.granule_id)
                stacks_done += 1
                tiles_done += n
                since_ckpt += 1
                if self.progress is not None:
                    self.progress.note_tiles(n)
                if since_ckpt >= cfg.checkpoint_every:
                    save_ckpt(gi, state)
                    since_ckpt = 0
            w0 = time.monotonic()
            result = self.runner.finalize(reader, state, self.progress)
            written.append(write_scene_result(self.output_path(spec), result))
            tr.add("scene.write", w0, time.monotonic(),
                   granule=spec.granule_id)
            granules_done += 1
            if self.progress is not None:
                self.progress.note_granule_done()
            # granule boundary checkpoint: a restart resumes *after* the
            # write above (rewriting it would be byte-identical anyway,
            # but this skips the recompute)
            state = (SceneState.fresh(self.manifest[gi + 1].width)
                     if gi + 1 < len(self.manifest) else None)
            save_ckpt(gi + 1,
                      state if state is not None else SceneState.fresh(1))
            tr.finish()
        return BulkJobReport(
            status="completed", granules_done=granules_done,
            tiles_done=tiles_done, stacks_done=stacks_done, resumes=resumes,
            written=written, elapsed_s=time.perf_counter() - t_start)
