"""Fixed-boundary log-spaced histograms for stage/latency timing.

Boundaries are FIXED (module constants, never derived from traffic): two
processes observing with the same bounds produce bucket vectors that sum
exactly, which is what lets the fleet router roll worker histograms up by
plain addition and still serve a correct Prometheus histogram. That
exactness is the whole reason these are not t-digests or windowed deques.

``quantile`` deliberately returns the **upper edge of the bucket** holding
the nearest-rank sample, with no intra-bucket interpolation: one sample
must report p50 == p95 (both ranks land in the same bucket), and a
quantile must never under-report below an observed sample's bucket. The
(lower, upper) edges are exposed via ``quantile_bounds`` so tests can
assert the true empirical percentile is bracketed.

``observe`` is lock-free: one list-index increment and one float add,
GIL-atomic enough for metrics (same discipline as the engine registry's
call counters — best-effort observability, not billing). Snapshots are
immutable and mergeable.
"""

from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Tuple

# Request latencies and coarse stage times: 10 us .. 60 s, log-ish spacing
# (1/2.5/5 per decade). The +Inf bucket is implicit.
DEFAULT_LATENCY_BOUNDS: Tuple[float, ...] = (
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4,
    1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)

# Engine dispatch cost (the synchronous spec.run call): sub-us resolution
# at the bottom because the dispatch budget is ~5 us/call.
DISPATCH_BOUNDS: Tuple[float, ...] = (
    1e-6, 2.5e-6, 5e-6, 1e-5, 2.5e-5, 5e-5,
    1e-4, 2.5e-4, 1e-3, 1e-2, 0.1, 1.0,
)


@dataclasses.dataclass(frozen=True)
class HistogramSnapshot:
    """Immutable point-in-time histogram: len(counts) == len(bounds) + 1
    (the trailing bucket is the implicit +Inf overflow)."""

    bounds: Tuple[float, ...]
    counts: Tuple[int, ...]
    sum: float
    count: int

    def cumulative(self) -> Tuple[int, ...]:
        total, out = 0, []
        for c in self.counts:
            total += c
            out.append(total)
        return tuple(out)

    def quantile_bounds(self, q: float) -> Tuple[float, float]:
        """(lower, upper) edges of the bucket holding the nearest-rank
        sample for quantile ``q``; (0.0, 0.0) when empty. The overflow
        bucket reports (top bound, top bound) — finite on purpose, so a
        gauge fed from it never renders +Inf."""
        if self.count == 0:
            return 0.0, 0.0
        rank = min(self.count, max(1, math.ceil(q * self.count)))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = (self.bounds[i] if i < len(self.bounds)
                      else self.bounds[-1])
                return lo, hi
        return 0.0, self.bounds[-1]

    def quantile(self, q: float) -> float:
        """Nearest-rank quantile, reported as its bucket's upper edge."""
        return self.quantile_bounds(q)[1]

    def merge(self, other: "HistogramSnapshot") -> "HistogramSnapshot":
        if self.bounds != other.bounds:
            raise ValueError(
                f"cannot merge histograms with different bounds: "
                f"{self.bounds} vs {other.bounds}")
        return HistogramSnapshot(
            bounds=self.bounds,
            counts=tuple(a + b for a, b in zip(self.counts, other.counts)),
            sum=self.sum + other.sum,
            count=self.count + other.count,
        )


def empty_snapshot(
        bounds: Tuple[float, ...] = DEFAULT_LATENCY_BOUNDS
        ) -> HistogramSnapshot:
    return HistogramSnapshot(bounds=tuple(bounds),
                             counts=(0,) * (len(bounds) + 1),
                             sum=0.0, count=0)


class Histogram:
    """Mutable fixed-boundary histogram; ``observe`` is lock-free."""

    __slots__ = ("bounds", "_counts", "_sum", "_count")

    def __init__(self, bounds: Tuple[float, ...] = DEFAULT_LATENCY_BOUNDS):
        if not bounds or list(bounds) != sorted(set(bounds)):
            raise ValueError(
                f"bounds must be a non-empty ascending ladder, got {bounds}")
        self.bounds = tuple(float(b) for b in bounds)
        self._counts = [0] * (len(self.bounds) + 1)
        self._sum = 0.0
        self._count = 0

    def observe(self, value: float) -> None:
        # bisect_left gives the first bound >= value, i.e. the Prometheus
        # le-inclusive bucket; values past the top land in the overflow
        self._counts[bisect.bisect_left(self.bounds, value)] += 1
        self._sum += value
        self._count += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def total(self) -> float:
        return self._sum

    def snapshot(self) -> HistogramSnapshot:
        return HistogramSnapshot(bounds=self.bounds,
                                 counts=tuple(self._counts),
                                 sum=self._sum, count=self._count)
