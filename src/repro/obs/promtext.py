"""Prometheus text-exposition building, escaping, and parsing.

One escaping helper shared by the frontend's ``_render_metrics`` and the
fleet rollup (worker and bucket names were previously interpolated raw
into ``{worker="..."}``), one builder that emits ``# HELP``/``# TYPE``
exactly once per family, and one parser strict enough for tests and for
the router's rollup to consume worker pages without regex guesswork.

The exposition-format rules implemented here (escaping, le-ordering,
histogram series naming) follow the Prometheus text format v0.0.4.
"""

from __future__ import annotations

import math
import re
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.obs.histogram import HistogramSnapshot

LabelPairs = Tuple[Tuple[str, str], ...]


def escape_label_value(value: str) -> str:
    """Escape a label value per the exposition format: backslash first
    (so later escapes aren't double-escaped), then quote, then newline."""
    return (str(value)
            .replace("\\", "\\\\")
            .replace('"', '\\"')
            .replace("\n", "\\n"))


def unescape_label_value(value: str) -> str:
    out, i = [], 0
    while i < len(value):
        ch = value[i]
        if ch == "\\" and i + 1 < len(value):
            nxt = value[i + 1]
            out.append({"\\": "\\", '"': '"', "n": "\n"}.get(nxt, nxt))
            i += 2
        else:
            out.append(ch)
            i += 1
    return "".join(out)


def format_labels(labels: Sequence[Tuple[str, str]]) -> str:
    """``{a="x",b="y"}`` with escaped values; empty string for no labels."""
    if not labels:
        return ""
    inner = ",".join(f'{k}="{escape_label_value(v)}"' for k, v in labels)
    return "{" + inner + "}"


def format_value(value) -> str:
    """Integral floats render as ints (``3`` not ``3.0``) so counter lines
    stay byte-compatible with the hand-built format the tests pin."""
    if isinstance(value, bool):
        return str(int(value))
    if isinstance(value, int):
        return str(value)
    f = float(value)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def format_le(bound: float) -> str:
    """Bucket thresholds rendered stably: 0.005 not 5e-03, ints bare."""
    if math.isinf(bound):
        return "+Inf"
    if bound == int(bound):
        return str(int(bound))
    return format(bound, ".12g")


class PromBuilder:
    """Accumulates families; ``render`` emits HELP/TYPE once per family."""

    def __init__(self):
        self._lines: List[str] = []

    def raw(self, line: str) -> None:
        self._lines.append(line)

    def header(self, name: str, kind: str, help_text: str = "") -> None:
        if help_text:
            self._lines.append(f"# HELP {name} {help_text}")
        self._lines.append(f"# TYPE {name} {kind}")

    def sample(self, name: str, labels: Sequence[Tuple[str, str]],
               value) -> None:
        self._lines.append(
            f"{name}{format_labels(labels)} {format_value(value)}")

    def counter(self, name: str, value, help_text: str = "",
                labels: Sequence[Tuple[str, str]] = ()) -> None:
        self.header(name, "counter", help_text)
        self.sample(name, labels, value)

    def gauge(self, name: str, value, help_text: str = "",
              labels: Sequence[Tuple[str, str]] = ()) -> None:
        self.header(name, "gauge", help_text)
        self.sample(name, labels, value)

    def histogram(self, name: str,
                  series: Sequence[Tuple[LabelPairs, HistogramSnapshot]],
                  help_text: str = "") -> None:
        """Emit one histogram family: per label set, cumulative ``_bucket``
        lines (le last, ``+Inf`` included), then ``_sum`` and ``_count``."""
        if not series:
            return
        self.header(name, "histogram", help_text)
        for labels, snap in series:
            cum = snap.cumulative()
            for i, c in enumerate(cum):
                le = (format_le(snap.bounds[i]) if i < len(snap.bounds)
                      else "+Inf")
                self.sample(f"{name}_bucket",
                            tuple(labels) + (("le", le),), c)
            self.sample(f"{name}_sum", labels, snap.sum)
            self.sample(f"{name}_count", labels, snap.count)

    def render(self) -> str:
        return "\n".join(self._lines) + "\n"


_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})?\s+(\S+)\s*$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


class PromSample:
    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: LabelPairs, value: float):
        self.name = name
        self.labels = labels
        self.value = value

    def __repr__(self):
        return f"PromSample({self.name!r}, {self.labels!r}, {self.value!r})"


class PromPage:
    """Parsed exposition page: samples in order + TYPE/HELP per family."""

    def __init__(self, samples: List[PromSample], types: Dict[str, str],
                 helps: Dict[str, str]):
        self.samples = samples
        self.types = types
        self.helps = helps

    def get(self, name: str,
            labels: Optional[LabelPairs] = None) -> Optional[float]:
        for s in self.samples:
            if s.name == name and (labels is None or s.labels == labels):
                return s.value
        return None

    def series(self, name: str) -> List[PromSample]:
        return [s for s in self.samples if s.name == name]


def _parse_labels(body: str) -> LabelPairs:
    pairs, pos = [], 0
    while pos < len(body):
        m = _LABEL_RE.match(body, pos)
        if not m:
            raise ValueError(f"malformed label body at {body[pos:]!r}")
        pairs.append((m.group(1), unescape_label_value(m.group(2))))
        pos = m.end()
        if pos < len(body):
            if body[pos] != ",":
                raise ValueError(f"expected ',' in label body {body!r}")
            pos += 1
    return tuple(pairs)


def _parse_value(text: str) -> float:
    if text == "+Inf":
        return math.inf
    if text == "-Inf":
        return -math.inf
    if text == "NaN":
        return math.nan
    return float(text)


def parse_prom_text(text: str) -> PromPage:
    """Parse an exposition page; raises ValueError on any malformed line
    (the test suite uses this as the 'every series parses' assertion)."""
    samples: List[PromSample] = []
    types: Dict[str, str] = {}
    helps: Dict[str, str] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            parts = line.split(None, 3)
            if len(parts) < 4:
                raise ValueError(f"line {lineno}: malformed TYPE: {line!r}")
            types[parts[2]] = parts[3]
            continue
        if line.startswith("# HELP "):
            parts = line.split(None, 3)
            if len(parts) < 3:
                raise ValueError(f"line {lineno}: malformed HELP: {line!r}")
            helps[parts[2]] = parts[3] if len(parts) > 3 else ""
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {lineno}: malformed sample: {line!r}")
        name, label_body, value_text = m.groups()
        labels = _parse_labels(label_body) if label_body else ()
        try:
            value = _parse_value(value_text)
        except ValueError:
            raise ValueError(
                f"line {lineno}: malformed value {value_text!r}") from None
        samples.append(PromSample(name, labels, value))
    return PromPage(samples, types, helps)


def base_family(name: str) -> str:
    """Histogram series name -> family name (strip _bucket/_sum/_count)."""
    for suffix in ("_bucket", "_sum", "_count"):
        if name.endswith(suffix):
            return name[: -len(suffix)]
    return name
