"""repro.obs — tracing, fixed-boundary histograms, Prometheus text tools.

The observability layer the service/fleet/scene tiers share. Import-light
on purpose (stdlib only): ``repro.engine`` and ``repro.service`` both use
it, so it must sit below every other repro package in the import graph.
"""

from repro.obs.histogram import (
    DEFAULT_LATENCY_BOUNDS,
    DISPATCH_BOUNDS,
    Histogram,
    HistogramSnapshot,
    empty_snapshot,
)
from repro.obs.promtext import (
    PromBuilder,
    PromPage,
    PromSample,
    base_family,
    escape_label_value,
    format_le,
    format_value,
    parse_prom_text,
    unescape_label_value,
)
from repro.obs.trace import (
    NULL_TRACE,
    FlightRecorder,
    Span,
    Trace,
    auto_dump,
    configure,
    maybe_trace,
    mono_to_wall_us,
    new_trace_id,
    recorder,
    tracing_enabled,
)

__all__ = [
    "DEFAULT_LATENCY_BOUNDS",
    "DISPATCH_BOUNDS",
    "Histogram",
    "HistogramSnapshot",
    "empty_snapshot",
    "PromBuilder",
    "PromPage",
    "PromSample",
    "base_family",
    "escape_label_value",
    "format_le",
    "format_value",
    "parse_prom_text",
    "unescape_label_value",
    "NULL_TRACE",
    "FlightRecorder",
    "Span",
    "Trace",
    "auto_dump",
    "configure",
    "maybe_trace",
    "mono_to_wall_us",
    "new_trace_id",
    "recorder",
    "tracing_enabled",
]
