"""Request tracing: spans, cross-process trace ids, and a flight recorder.

Design constraints, in priority order:

1. **~Zero cost when disabled.** ``maybe_trace`` returns the shared
   ``NULL_TRACE`` singleton whose methods are constant-time no-ops; hot
   paths hold one attribute check, no allocation, no lock. The bench gate
   enforces this stays inside the existing tolerances.
2. **Monotonic clocks, wall alignment.** Spans are timed with
   ``time.monotonic()`` (immune to NTP steps). Each process records one
   (wall, mono) epoch pair at import; export converts mono timestamps to
   the wall axis so spans from router + workers line up on one Perfetto
   timeline to within clock-sync error.
3. **Creator finishes.** The tier that *creates* a Trace (frontend
   handler, router request, scene granule, or ``YCHGService.submit`` when
   called without one) calls ``finish()``; everyone handed an existing
   trace only adds spans. ``finish`` is idempotent, so belt-and-braces
   finishing in error paths is safe.

The flight recorder keeps the most recent N *completed* traces in a ring
and serialises them as Chrome-trace JSON (the ``traceEvents`` array form)
for ``GET /debug/traces``, ``serve.py --trace-dump``, and the SIGTERM /
dispatch-crash auto-dump.
"""

from __future__ import annotations

import collections
import json
import os
import threading
import time
from typing import Dict, List, Optional, Tuple

# One (wall, mono) epoch pair per process: chrome export maps a monotonic
# timestamp t to wall-axis microseconds as (_WALL0 + (t - _MONO0)) * 1e6,
# so traces from different processes share one timeline.
_WALL0 = time.time()
_MONO0 = time.monotonic()


def mono_to_wall_us(t_mono: float) -> float:
    return (_WALL0 + (t_mono - _MONO0)) * 1e6


class _State:
    """Process-global tracing switches (env-seeded, configure()-mutable)."""

    def __init__(self):
        self.enabled = os.environ.get("YCHG_TRACE", "1") != "0"
        self.dump_path: Optional[str] = os.environ.get("YCHG_TRACE_DUMP")
        self.capacity = 256


_STATE = _State()
_UNSET = object()


def configure(enabled=_UNSET, dump_path=_UNSET, capacity=_UNSET) -> None:
    """Override tracing switches (serve.py --trace-dump lands here)."""
    if enabled is not _UNSET:
        _STATE.enabled = bool(enabled)
    if dump_path is not _UNSET:
        _STATE.dump_path = dump_path
    if capacity is not _UNSET:
        _recorder.resize(int(capacity))
        _STATE.capacity = int(capacity)


def tracing_enabled() -> bool:
    return _STATE.enabled


def new_trace_id() -> str:
    return os.urandom(8).hex()


class Span:
    """One named interval inside a trace. Use as a context manager or via
    Trace.add() with explicit timestamps."""

    __slots__ = ("name", "t0", "t1", "meta", "_trace")

    def __init__(self, trace: "Trace", name: str, **meta):
        self._trace = trace
        self.name = name
        self.t0 = 0.0
        self.t1 = 0.0
        self.meta = meta

    def __enter__(self) -> "Span":
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *exc) -> None:
        self.t1 = time.monotonic()
        self._trace._record(self)
        return None


class Trace:
    """A bag of spans sharing one trace id. Lock-light: span appends take
    one short lock; cross-thread adds (scheduler/dispatch threads joining
    a submit-side trace) are the norm, not the exception."""

    __slots__ = ("trace_id", "process", "_spans", "_lock", "_finished")

    def __init__(self, trace_id: Optional[str] = None,
                 process: str = "service"):
        self.trace_id = trace_id or new_trace_id()
        self.process = process
        self._spans: List[Tuple[str, float, float, dict]] = []
        self._lock = threading.Lock()
        self._finished = False

    @property
    def enabled(self) -> bool:
        return True

    def span(self, name: str, **meta) -> Span:
        return Span(self, name, **meta)

    def add(self, name: str, t0: float, t1: float, **meta) -> None:
        """Record an interval from timestamps already in hand (monotonic
        seconds). The instrumented code paths mostly use this: they note
        time.monotonic() at stage edges they needed anyway."""
        with self._lock:
            self._spans.append((name, t0, min_t1(t0, t1), meta))

    def _record(self, span: Span) -> None:
        self.add(span.name, span.t0, span.t1, **span.meta)

    def spans(self) -> List[Tuple[str, float, float, dict]]:
        with self._lock:
            return list(self._spans)

    def finish(self) -> None:
        """Hand the trace to the flight recorder; idempotent."""
        with self._lock:
            if self._finished or not self._spans:
                self._finished = True
                return
            self._finished = True
        _recorder.record(self)


class _NullTrace:
    """Shared do-nothing stand-in used whenever tracing is off. Every
    method is a constant-time no-op so call sites need no branching."""

    __slots__ = ()
    trace_id = ""
    process = ""
    enabled = False

    def span(self, name: str, **meta) -> "_NullSpan":
        return _NULL_SPAN

    def add(self, name: str, t0: float, t1: float, **meta) -> None:
        return None

    def spans(self) -> list:
        return []

    def finish(self) -> None:
        return None


class _NullSpan:
    __slots__ = ()
    name = ""
    t0 = 0.0
    t1 = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None


NULL_TRACE = _NullTrace()
_NULL_SPAN = _NullSpan()


def maybe_trace(trace_id: Optional[str] = None,
                process: str = "service"):
    """A live Trace when tracing is enabled, else NULL_TRACE."""
    if not _STATE.enabled:
        return NULL_TRACE
    return Trace(trace_id, process=process)


def min_t1(t0: float, t1: float) -> float:
    # monotonic should make t1 >= t0 automatic; clamp anyway so a caller
    # mixing up argument order cannot produce negative-duration spans
    return t1 if t1 >= t0 else t0


class FlightRecorder:
    """Bounded ring of the most recent completed traces in this process."""

    def __init__(self, capacity: int = 256):
        self._lock = threading.Lock()
        self._ring: collections.deque = collections.deque(maxlen=capacity)

    def resize(self, capacity: int) -> None:
        with self._lock:
            self._ring = collections.deque(self._ring, maxlen=capacity)

    def record(self, trace: Trace) -> None:
        with self._lock:
            self._ring.append(trace)

    def traces(self) -> List[Trace]:
        with self._lock:
            return list(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def chrome_events(self) -> List[Dict]:
        """Chrome-trace 'X' (complete) events for every recorded trace.
        pid is the real OS pid so a fleet dump shows router and workers as
        separate process tracks; tid groups spans by trace id so parallel
        requests stay on separate rows."""
        pid = os.getpid()
        events = []
        for trace in self.traces():
            for name, t0, t1, meta in trace.spans():
                args = {"trace_id": trace.trace_id}
                if meta:
                    args.update({k: str(v) for k, v in meta.items()})
                events.append({
                    "name": name,
                    "cat": trace.process,
                    "ph": "X",
                    "ts": mono_to_wall_us(t0),
                    "dur": max(0.0, (t1 - t0) * 1e6),
                    "pid": pid,
                    "tid": trace.trace_id,
                    "args": args,
                })
        return events

    def to_chrome_json(self) -> str:
        return json.dumps({"traceEvents": self.chrome_events(),
                           "displayTimeUnit": "ms"})

    def dump(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as fh:
            fh.write(self.to_chrome_json())


_recorder = FlightRecorder(_STATE.capacity)


def recorder() -> FlightRecorder:
    return _recorder


def auto_dump(reason: str) -> Optional[str]:
    """Dump the flight recorder to the configured path (SIGTERM handler,
    dispatch-loop crash). Returns the path written, or None when no dump
    path is configured or the write failed — never raises: a failing dump
    must not mask the original error."""
    path = _STATE.dump_path
    if not path:
        return None
    try:
        _recorder.dump(path)
        return path
    except OSError:
        return None
