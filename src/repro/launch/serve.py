"""CLI serve driver: --arch <id> --smoke serves batched requests; or
--workload ychg runs the paper's image-analysis service on mask batches.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke
  PYTHONPATH=src python -m repro.launch.serve --workload ychg --res 2048
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.archs import smoke_config
from repro.configs import get_config
from repro.models import count_params, init_params
from repro.serve import ServeEngine


def serve_lm(args):
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"{cfg.name}: {count_params(cfg) / 1e6:.2f}M params")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=args.prompt + args.max_new)
    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt)
    ).astype(np.int32)
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=args.max_new, temperature=0.7)
    dt = time.perf_counter() - t0
    print(f"generated {out.tokens.size} tokens in {dt:.2f}s "
          f"({out.tokens.size / dt:.1f} tok/s)")


def serve_ychg(args):
    """The paper's image-analysis workload behind the production service:
    requests batch through YCHGService -> YCHGEngine (not the legacy
    core.ychg.analyze_jit call). Three timed passes separate the costs:
    cold (includes backend compile), warm (steady-state compute on fresh
    masks), cached (repeat traffic served from the result cache). With
    --overload, a fourth pass offers a burst to a bounded-queue service
    (overload_policy="shed") and reports the shed rate — the admission
    control path CI smoke-checks."""
    from repro.data import modis
    from repro.engine import YCHGEngine
    from repro.service import ServiceConfig, ServiceOverloaded, YCHGService

    def timed_pass(svc, masks):
        t0 = time.perf_counter()
        outs = [f.result(timeout=600) for f in [svc.submit(m) for m in masks]]
        return time.perf_counter() - t0, outs

    masks = [modis.snowfield(args.res, seed=s) for s in range(args.batch)]
    fresh = [modis.snowfield(args.res, seed=args.batch + s)
             for s in range(args.batch)]
    px = args.batch * args.res * args.res
    engine = YCHGEngine()
    cfg = ServiceConfig(bucket_sides=(args.res,), max_batch=args.batch)
    with YCHGService(engine, cfg) as svc:
        t_cold, outs = timed_pass(svc, masks)       # compiles the bucket shape
        t_warm, _ = timed_pass(svc, fresh)          # steady-state compute
        before_cached = svc.metrics()
        t_cached, _ = timed_pass(svc, masks)        # repeat traffic: cache
        m = svc.metrics()
    # the cached pass's own hit rate (lifetime m.hit_rate would dilute it
    # with the cold/warm passes' unavoidable misses)
    cached_hit_rate = (m.cache_hits - before_cached.cache_hits) / args.batch
    edges = [int(np.asarray(o.n_hyperedges)[0]) for o in outs]
    print(f"yCHG service[{m.backend}]: {args.batch} x {args.res}^2 masks")
    print(f"  cold  {t_cold * 1e3:8.1f}ms (includes compile)")
    print(f"  warm  {t_warm * 1e3:8.1f}ms ({px / t_warm / 1e6:.0f} Mpx/s)")
    print(f"  cached{t_cached * 1e3:8.1f}ms "
          f"({px / t_cached / 1e6:.0f} Mpx/s, hit rate {cached_hit_rate:.0%})")
    print(f"  p50 {m.p50_latency_ms:.1f}ms p95 {m.p95_latency_ms:.1f}ms over "
          f"{m.completed} requests ({m.completed_from_cache} from cache) "
          f"in {m.batches} device batches; hyperedges per tile: {edges}")
    if args.overload:
        # admission control under a deliberate burst: a bounded queue with
        # overload_policy="shed" fails the excess fast instead of letting
        # latency balloon. The long delay window holds the two admitted
        # requests pending, so the shed count is deterministic.
        n_burst = 4 * args.batch
        burst = [modis.snowfield(args.res, seed=10_000 + s)
                 for s in range(n_burst)]
        ocfg = ServiceConfig(bucket_sides=(args.res,), max_batch=args.batch,
                             max_delay_ms=200.0, max_queue_depth=2,
                             overload_policy="shed")
        shed, futures = 0, []
        with YCHGService(engine, ocfg) as osvc:
            for b in burst:
                try:
                    futures.append(osvc.submit(b))
                except ServiceOverloaded:
                    shed += 1
            om = osvc.metrics()
        for f in futures:
            f.result(timeout=600)   # admitted requests still resolve
        print(f"  overload burst of {n_burst} at max_queue_depth=2: "
              f"{len(futures)} admitted, {shed} shed "
              f"(shed rate {shed / n_burst:.0%})")
        if shed == 0 or om.shed != shed:
            raise SystemExit(
                "overload pass failed: admission control shed nothing")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm", choices=["lm", "ychg"])
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--res", type=int, default=1024)
    ap.add_argument("--overload", action="store_true",
                    help="ychg only: add a bounded-queue overload pass and "
                         "fail unless admission control sheds")
    args = ap.parse_args()
    if args.workload == "ychg":
        serve_ychg(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
