"""CLI serve driver: --arch <id> --smoke serves batched requests; or
--workload ychg runs the paper's image-analysis service on mask batches.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke
  PYTHONPATH=src python -m repro.launch.serve --workload ychg --res 2048
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.archs import smoke_config
from repro.configs import get_config
from repro.models import count_params, init_params
from repro.serve import ServeEngine


def serve_lm(args):
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"{cfg.name}: {count_params(cfg) / 1e6:.2f}M params")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=args.prompt + args.max_new)
    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt)
    ).astype(np.int32)
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=args.max_new, temperature=0.7)
    dt = time.perf_counter() - t0
    print(f"generated {out.tokens.size} tokens in {dt:.2f}s "
          f"({out.tokens.size / dt:.1f} tok/s)")


def serve_ychg(args):
    from repro.core import ychg
    from repro.data import modis

    batch = np.stack([
        modis.snowfield(args.res, seed=s) for s in range(args.batch)
    ])
    t0 = time.perf_counter()
    s = ychg.analyze_jit(batch)
    jax.block_until_ready(s.n_hyperedges)
    dt = time.perf_counter() - t0
    px = batch.size
    print(f"yCHG service: {args.batch} x {args.res}^2 masks in {dt * 1e3:.1f}ms "
          f"({px / dt / 1e6:.0f} Mpx/s); hyperedges per tile: "
          f"{np.asarray(s.n_hyperedges).tolist()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm", choices=["lm", "ychg"])
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--res", type=int, default=1024)
    args = ap.parse_args()
    if args.workload == "ychg":
        serve_ychg(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
