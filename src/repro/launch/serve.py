"""CLI serve driver: --arch <id> --smoke serves batched requests; or
--workload ychg runs the paper's image-analysis service on mask batches,
in-process or over the network front end.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke
  PYTHONPATH=src python -m repro.launch.serve --workload ychg --res 2048
  # network modes (repro.frontend):
  PYTHONPATH=src python -m repro.launch.serve --workload ychg \\
      --listen 127.0.0.1:8788                  # serve over loopback HTTP
  PYTHONPATH=src python -m repro.launch.serve --workload ychg \\
      --connect http://127.0.0.1:8788          # drive a remote server
  PYTHONPATH=src python -m repro.launch.serve --workload ychg \\
      --res 64 --batch 4 --frontend-smoke      # CI end-to-end assert
  PYTHONPATH=src python -m repro.launch.serve --workload ychg \\
      --fleet 4 --listen 127.0.0.1:8788        # router over 4 workers
  PYTHONPATH=src python -m repro.launch.serve --workload ychg \\
      --res 64 --batch 4 --fleet-smoke         # CI fleet assert
"""

from __future__ import annotations

import argparse
import threading
import time

import jax
import numpy as np

from repro.configs.archs import smoke_config
from repro.configs import get_config
from repro.models import count_params, init_params
from repro.serve import ServeEngine


def serve_lm(args):
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"{cfg.name}: {count_params(cfg) / 1e6:.2f}M params")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=args.prompt + args.max_new)
    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt)
    ).astype(np.int32)
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=args.max_new, temperature=0.7)
    dt = time.perf_counter() - t0
    print(f"generated {out.tokens.size} tokens in {dt:.2f}s "
          f"({out.tokens.size / dt:.1f} tok/s)")


def serve_ychg(args):
    """The paper's image-analysis workload behind the production service:
    requests batch through YCHGService -> YCHGEngine (not the legacy
    core.ychg.analyze_jit call). Three timed passes separate the costs:
    cold (includes backend compile), warm (steady-state compute on fresh
    masks), cached (repeat traffic served from the result cache). With
    --overload, a fourth pass offers a burst to a bounded-queue service
    (overload_policy="shed") and reports the shed rate — the admission
    control path CI smoke-checks."""
    from repro.data import modis
    from repro.engine import YCHGEngine
    from repro.service import ServiceConfig, ServiceOverloaded, YCHGService

    def timed_pass(svc, masks):
        t0 = time.perf_counter()
        outs = [f.result(timeout=600) for f in [svc.submit(m) for m in masks]]
        return time.perf_counter() - t0, outs

    masks = [modis.snowfield(args.res, seed=s) for s in range(args.batch)]
    fresh = [modis.snowfield(args.res, seed=args.batch + s)
             for s in range(args.batch)]
    px = args.batch * args.res * args.res
    engine = YCHGEngine()
    cfg = ServiceConfig(bucket_sides=(args.res,), max_batch=args.batch)
    with YCHGService(engine, cfg) as svc:
        t_cold, outs = timed_pass(svc, masks)       # compiles the bucket shape
        t_warm, _ = timed_pass(svc, fresh)          # steady-state compute
        before_cached = svc.metrics()
        t_cached, _ = timed_pass(svc, masks)        # repeat traffic: cache
        m = svc.metrics()
    # the cached pass's own hit rate (lifetime m.hit_rate would dilute it
    # with the cold/warm passes' unavoidable misses)
    cached_hit_rate = (m.cache_hits - before_cached.cache_hits) / args.batch
    edges = [int(np.asarray(o.n_hyperedges)[0]) for o in outs]
    print(f"yCHG service[{m.backend}]: {args.batch} x {args.res}^2 masks")
    print(f"  cold  {t_cold * 1e3:8.1f}ms (includes compile)")
    print(f"  warm  {t_warm * 1e3:8.1f}ms ({px / t_warm / 1e6:.0f} Mpx/s)")
    print(f"  cached{t_cached * 1e3:8.1f}ms "
          f"({px / t_cached / 1e6:.0f} Mpx/s, hit rate {cached_hit_rate:.0%})")
    print(f"  p50 {m.p50_latency_ms:.1f}ms p95 {m.p95_latency_ms:.1f}ms over "
          f"{m.completed} requests ({m.completed_from_cache} from cache) "
          f"in {m.batches} device batches; hyperedges per tile: {edges}")
    if args.overload:
        # admission control under a deliberate burst: a bounded queue with
        # overload_policy="shed" fails the excess fast instead of letting
        # latency balloon. The long delay window holds the two admitted
        # requests pending, so the shed count is deterministic.
        n_burst = 4 * args.batch
        burst = [modis.snowfield(args.res, seed=10_000 + s)
                 for s in range(n_burst)]
        ocfg = ServiceConfig(bucket_sides=(args.res,), max_batch=args.batch,
                             max_delay_ms=200.0, max_queue_depth=2,
                             overload_policy="shed")
        shed, futures = 0, []
        with YCHGService(engine, ocfg) as osvc:
            for b in burst:
                try:
                    futures.append(osvc.submit(b))
                except ServiceOverloaded:
                    shed += 1
            om = osvc.metrics()
        for f in futures:
            f.result(timeout=600)   # admitted requests still resolve
        print(f"  overload burst of {n_burst} at max_queue_depth=2: "
              f"{len(futures)} admitted, {shed} shed "
              f"(shed rate {shed / n_burst:.0%})")
        if shed == 0 or om.shed != shed:
            raise SystemExit(
                "overload pass failed: admission control shed nothing")


def _parse_hostport(s: str, default_host: str = "127.0.0.1"):
    """"HOST:PORT", ":PORT", or "PORT" -> (host, port)."""
    if "//" in s:
        s = s.split("//", 1)[1]
    s = s.rstrip("/")
    host, _, port = s.rpartition(":")
    return (host or default_host), int(port)


def _service_config(args, **overrides):
    from repro.service import ServiceConfig

    sides = (tuple(int(b) for b in args.buckets.split(","))
             if args.buckets else (args.res,))
    knobs = dict(bucket_sides=sides, max_batch=args.batch,
                 max_queue_depth=args.max_queue_depth,
                 bucket_queue_depth=args.bucket_queue_depth,
                 overload_policy=args.policy)
    knobs.update(overrides)
    return ServiceConfig(**knobs)


def serve_listen(args):
    """Serve the ROI service over loopback/network HTTP (+ optional RPC)
    until interrupted — the production front end behind a CLI flag."""
    from repro.engine import YCHGEngine
    from repro.frontend import ServerThread
    from repro.service import YCHGService

    host, port = _parse_hostport(args.listen)
    rpc_port = (_parse_hostport(args.rpc_listen)[1]
                if args.rpc_listen else None)
    with YCHGService(YCHGEngine(), _service_config(args)) as svc:
        with ServerThread(svc, host=host, port=port,
                          rpc_port=rpc_port) as srv:
            extra = (f" (rpc on {host}:{srv.rpc_port})"
                     if rpc_port is not None else "")
            print(f"yCHG frontend listening on http://{host}:{srv.port}"
                  f"{extra}; buckets {svc.config.bucket_sides}, "
                  f"max_batch {svc.config.max_batch}", flush=True)
            try:
                threading.Event().wait()
            except KeyboardInterrupt:
                print("shutting down", flush=True)


def serve_connect(args):
    """Client mode: drive a running front end with the mask workload and
    report wire-level timing (the network twin of the in-process pass)."""
    from repro.data import modis
    from repro.frontend import YCHGClient

    host, port = _parse_hostport(args.connect)
    masks = [modis.snowfield(args.res, seed=s) for s in range(args.batch)]
    px = args.batch * args.res * args.res
    with YCHGClient(host, port) as client:
        health = client.wait_ready(timeout=60.0)
        print(f"connected to {host}:{port}: backend {health['backend']}")
        t0 = time.perf_counter()
        items = list(client.analyze_batch(masks))
        dt = time.perf_counter() - t0
        failed = [it for it in items if not it.ok]
        if failed:
            raise SystemExit(
                f"{len(failed)} of {len(items)} requests failed; first: "
                f"{failed[0].status} {failed[0].error}")
        edges = [int(it.result["n_hyperedges"]) for it in
                 sorted(items, key=lambda it: it.id)]
        print(f"  wire  {dt * 1e3:8.1f}ms for {args.batch} x {args.res}^2 "
              f"masks ({px / dt / 1e6:.0f} Mpx/s); hyperedges: {edges}")


def frontend_smoke(args):
    """CI end-to-end assert over a real loopback socket (ephemeral port):

      1. a streamed client batch is BIT-IDENTICAL (values, dtypes, shapes)
         to in-process ``YCHGService.submit`` on the same masks;
      2. at a full admission queue the wire answer is HTTP 429 with a
         Retry-After, and the service's shed counter moves (visible in
         /metrics down to the per-bucket counter).

    Exits nonzero on any failure — the frontend-smoke CI job runs this.
    """
    from repro.data import modis
    from repro.engine import YCHGEngine
    from repro.frontend import FrontendOverloaded, ServerThread, YCHGClient
    from repro.service import YCHGService

    masks = [modis.snowfield(args.res, seed=s) for s in range(args.batch)]
    engine = YCHGEngine()
    with YCHGService(engine, _service_config(args)) as svc, \
            ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        items = {it.id: it for it in client.analyze_batch(masks)}
        want = [svc.submit(m).result(timeout=600).to_host() for m in masks]
        for i, host_res in enumerate(want):
            item = items.get(i)
            if item is None or not item.ok:
                raise SystemExit(f"frontend smoke: mask {i} failed over the "
                                 f"wire: {item and item.error}")
            for field, arr in host_res.items():
                a, b = np.asarray(arr), item.result[field]
                if not (np.array_equal(a, b) and a.dtype == b.dtype
                        and a.shape == b.shape):
                    raise SystemExit(
                        f"frontend smoke: field {field!r} of mask {i} is "
                        f"not bit-identical over the wire")
        print(f"frontend smoke: {len(masks)} masks round-tripped over "
              f"loopback HTTP bit-identical to in-process submit")

    # overload leg: ONE admission slot, held by an in-process submit parked
    # in a long delay window, so the wire request deterministically sheds
    ocfg = _service_config(args, max_delay_ms=10_000.0, max_queue_depth=1,
                           bucket_queue_depth=1, overload_policy="shed")
    with YCHGService(engine, ocfg) as osvc:
        holder = osvc.submit(masks[0])
        with ServerThread(osvc) as srv, \
                YCHGClient("127.0.0.1", srv.port) as client:
            try:
                client.analyze(masks[1])
                raise SystemExit("frontend smoke: expected HTTP 429, "
                                 "got a result")
            except FrontendOverloaded as e:
                if not e.retry_after_s > 0:
                    raise SystemExit("frontend smoke: 429 carried no "
                                     "positive retry_after_s")
            metrics = client.metrics_text()
        for needle in ("ychg_shed_total 1", "ychg_shed_bucket_total{"):
            if needle not in metrics:
                raise SystemExit(
                    f"frontend smoke: {needle!r} missing from /metrics "
                    f"after an overload shed")
    holder.result(timeout=600)   # the admitted request still resolves
    print("frontend smoke: overload answered 429 with Retry-After and the "
          "per-bucket shed counter moved")


def _worker_args(args):
    """Worker-CLI knobs mirroring this invocation's service knobs."""
    wa = ["--buckets", args.buckets if args.buckets else str(args.res),
          "--max-batch", str(args.batch), "--policy", args.policy]
    if args.max_queue_depth is not None:
        wa += ["--max-queue-depth", str(args.max_queue_depth)]
    if args.bucket_queue_depth is not None:
        wa += ["--bucket-queue-depth", str(args.bucket_queue_depth)]
    return wa


def _router_config(args, **overrides):
    from repro.fleet import RouterConfig

    sides = (tuple(int(b) for b in args.buckets.split(","))
             if args.buckets else (args.res,))
    knobs = dict(bucket_sides=sides, max_batch=args.batch,
                 max_queue_depth=args.max_queue_depth,
                 bucket_queue_depth=args.bucket_queue_depth,
                 overload_policy=args.policy)
    knobs.update(overrides)
    return RouterConfig(**knobs)


def serve_fleet(args):
    """Serve a worker-process fleet behind the consistent-hash router
    until interrupted: ``--fleet N`` is ``--listen`` at fleet scale."""
    from repro.fleet import FleetRouter, FleetSupervisor, RouterThread

    host, port = (_parse_hostport(args.listen) if args.listen
                  else ("127.0.0.1", 8788))
    sup = FleetSupervisor(args.fleet, worker_args=_worker_args(args))
    print(f"spawning {args.fleet} workers...", flush=True)
    try:
        links = sup.start()
        router = FleetRouter(links, _router_config(args), host=host,
                             port=port, supervisor=sup)
        with RouterThread(router) as rt:
            workers = ", ".join(
                f"{l.name}=rpc:{l.rpc_port}" for l in links)
            print(f"yCHG fleet router on http://{host}:{rt.port} over "
                  f"{len(links)} workers ({workers})", flush=True)
            try:
                threading.Event().wait()
            except KeyboardInterrupt:
                print("shutting down fleet", flush=True)
    finally:
        sup.stop()


def fleet_smoke(args):
    """CI end-to-end assert for the fleet: router over 2 subprocess
    workers on loopback (ephemeral ports everywhere).

      1. **bit-identity** — a streamed batch through router -> worker RPC
         is byte-identical (values, dtypes, shapes) to in-process
         ``YCHGService.submit`` on the same masks;
      2. **rerouting** — hard-kill the worker owning one mask's keyspace;
         the repeat analyze fails over to the survivor, still matches,
         and ``ychg_fleet_rerouted_total`` moves;
      3. **peering** — restart the dead slot (same ring name, empty
         cache) and repeat the mask once more: the restarted owner
         adopts the survivor's cached entry instead of recomputing, and
         the rolled-up /metrics page shows
         ``ychg_cache_peer_hits_total`` > 0.

    Exits nonzero on any failure — the fleet-smoke CI job runs this.
    """
    import asyncio

    from repro.data import modis
    from repro.engine import YCHGEngine
    from repro.fleet import (
        FleetRouter,
        FleetSupervisor,
        HashRing,
        RouterThread,
    )
    from repro.fleet.router import routing_key
    from repro.frontend import YCHGClient
    from repro.service import YCHGService

    def counter(text, name):
        for line in text.splitlines():
            if line.startswith(name + " "):
                return float(line.rsplit(" ", 1)[1])
        return 0.0

    def check_identical(leg, got, want_res):
        for field, arr in want_res.items():
            a, b = np.asarray(arr), got[field]
            if not (np.array_equal(a, b) and a.dtype == b.dtype
                    and a.shape == b.shape):
                raise SystemExit(f"fleet smoke [{leg}]: field {field!r} "
                                 f"not bit-identical through the router")

    masks = [modis.snowfield(args.res, seed=s) for s in range(args.batch)]
    with YCHGService(YCHGEngine(), _service_config(args)) as svc:
        want = [svc.submit(m).result(timeout=600).to_host() for m in masks]

    sup = FleetSupervisor(2, worker_args=_worker_args(args))
    try:
        links = sup.start()
        # health loop effectively dormant: the smoke drives the death ->
        # reroute -> restart -> peer-hit sequence deterministically
        router = FleetRouter(links, _router_config(
            args, health_interval_s=3600.0), supervisor=sup)
        with RouterThread(router) as rt, \
                YCHGClient("127.0.0.1", rt.port) as client:
            client.wait_ready(timeout=120.0)
            items = {it.id: it for it in client.analyze_batch(masks)}
            for i, want_res in enumerate(want):
                item = items.get(i)
                if item is None or not item.ok:
                    raise SystemExit(
                        f"fleet smoke [identity]: mask {i} failed through "
                        f"the router: {item and item.error}")
                check_identical("identity", item.result, want_res)
            print(f"fleet smoke: {len(masks)} masks through router over 2 "
                  f"workers bit-identical to in-process submit", flush=True)

            ring = HashRing([l.name for l in links],
                            router.config.replicas)
            owner = ring.node_for(routing_key(masks[0]))
            owner_link = next(l for l in links if l.name == owner)
            owner_link.process.kill()
            owner_link.process.wait(timeout=30)
            check_identical("reroute", client.analyze(masks[0]), want[0])
            rerouted = counter(client.metrics_text(),
                               "ychg_fleet_rerouted_total")
            if rerouted < 1:
                raise SystemExit("fleet smoke [reroute]: killed the owner "
                                 "but ychg_fleet_rerouted_total never moved")
            print(f"fleet smoke: killed {owner}, request rerouted to the "
                  f"survivor and stayed bit-identical", flush=True)

            # one manual health pass: notices the corpse, restarts the
            # slot under its old name, re-broadcasts the peer set
            asyncio.run_coroutine_threadsafe(
                router.check_workers(), rt._loop).result(timeout=300)
            health = client.health()
            if not all(health["workers"].values()):
                raise SystemExit(f"fleet smoke [peering]: restart left "
                                 f"workers down: {health['workers']}")
            check_identical("peering", client.analyze(masks[0]), want[0])
            peer_hits = counter(client.metrics_text(),
                                "ychg_cache_peer_hits_total")
            if peer_hits < 1:
                raise SystemExit(
                    "fleet smoke [peering]: restarted owner served the "
                    "repeat mask without a sibling-cache hit "
                    f"(ychg_cache_peer_hits_total={peer_hits})")
            print(f"fleet smoke: restarted {owner} served repeat traffic "
                  f"from the survivor's cache (peer hits {peer_hits:.0f})",
                  flush=True)
    finally:
        sup.stop()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm", choices=["lm", "ychg"])
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--res", type=int, default=1024)
    ap.add_argument("--overload", action="store_true",
                    help="ychg only: add a bounded-queue overload pass and "
                         "fail unless admission control sheds")
    ap.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="ychg only: serve over HTTP until interrupted")
    ap.add_argument("--rpc-listen", default=None, metavar="HOST:PORT",
                    help="with --listen: also serve the framed TCP RPC")
    ap.add_argument("--connect", default=None, metavar="URL",
                    help="ychg only: run the workload against a running "
                         "front end (http://HOST:PORT)")
    ap.add_argument("--frontend-smoke", action="store_true",
                    help="ychg only: loopback HTTP end-to-end assert "
                         "(bit-identical round trip + 429 on overload)")
    ap.add_argument("--fleet", type=int, default=None, metavar="N",
                    help="ychg only: serve N worker processes behind the "
                         "consistent-hash router (with --listen for the "
                         "router's HOST:PORT)")
    ap.add_argument("--fleet-smoke", action="store_true",
                    help="ychg only: loopback fleet end-to-end assert "
                         "(bit-identity, kill-one-worker rerouting, "
                         "peered-cache hit)")
    ap.add_argument("--buckets", default=None,
                    help="comma-separated bucket sides (default: --res)")
    ap.add_argument("--max-queue-depth", type=int, default=None)
    ap.add_argument("--bucket-queue-depth", type=int, default=None)
    ap.add_argument("--policy", default="block", choices=["block", "shed"],
                    help="overload policy for --listen/--frontend-smoke")
    args = ap.parse_args()
    if args.fleet_smoke:
        fleet_smoke(args)
    elif args.fleet:
        serve_fleet(args)
    elif args.frontend_smoke:
        frontend_smoke(args)
    elif args.listen:
        serve_listen(args)
    elif args.connect:
        serve_connect(args)
    elif args.workload == "ychg":
        serve_ychg(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
