"""CLI serve driver: --arch <id> --smoke serves batched requests; or
--workload ychg runs the paper's image-analysis service on mask batches,
in-process or over the network front end.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke
  PYTHONPATH=src python -m repro.launch.serve --workload ychg --res 2048
  # network modes (repro.frontend):
  PYTHONPATH=src python -m repro.launch.serve --workload ychg \\
      --listen 127.0.0.1:8788                  # serve over loopback HTTP
  PYTHONPATH=src python -m repro.launch.serve --workload ychg \\
      --connect http://127.0.0.1:8788          # drive a remote server
  PYTHONPATH=src python -m repro.launch.serve --workload ychg \\
      --res 64 --batch 4 --frontend-smoke      # CI end-to-end assert
  PYTHONPATH=src python -m repro.launch.serve --workload ychg \\
      --fleet 4 --listen 127.0.0.1:8788        # router over 4 workers
  PYTHONPATH=src python -m repro.launch.serve --workload ychg \\
      --res 64 --batch 4 --fleet-smoke         # CI fleet assert
  # granule-scale bulk analysis (repro.scene):
  PYTHONPATH=src python -m repro.launch.serve --workload ychg scene \\
      --granules 4 --scene-height 4096 --scene-width 2048 \\
      --out results/ --ckpt ckpt/               # resumable bulk job
  PYTHONPATH=src python -m repro.launch.serve --workload ychg \\
      --scene-smoke                             # CI scene assert
  PYTHONPATH=src python -m repro.launch.serve --workload ychg \\
      --res 64 --batch 4 --slo-smoke            # CI traffic-class assert
"""

from __future__ import annotations

import argparse
import threading
import time

import jax
import numpy as np

from repro import obs
from repro.configs.archs import smoke_config
from repro.configs import get_config
from repro.models import count_params, init_params
from repro.serve import ServeEngine


def serve_lm(args):
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"{cfg.name}: {count_params(cfg) / 1e6:.2f}M params")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=args.prompt + args.max_new)
    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt)
    ).astype(np.int32)
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=args.max_new, temperature=0.7)
    dt = time.perf_counter() - t0
    print(f"generated {out.tokens.size} tokens in {dt:.2f}s "
          f"({out.tokens.size / dt:.1f} tok/s)")


# one human-readable scalar per op for the workload's per-tile report
_OP_STAT_NAME = {"ychg": "hyperedges", "ccl": "components",
                 "denoise": "mean"}


def _op_stat(op, out):
    if op == "ychg":
        return int(np.asarray(out.n_hyperedges)[0])
    if op == "ccl":
        return int(np.asarray(out.n_components).reshape(-1)[0])
    return round(float(np.asarray(out.image).mean()), 4)


def serve_ychg(args):
    """The paper's image-analysis workload behind the production service:
    requests batch through YCHGService -> Engine (not the legacy
    core.ychg.analyze_jit call). Three timed passes separate the costs:
    cold (includes backend compile), warm (steady-state compute on fresh
    masks), cached (repeat traffic served from the result cache). With
    --overload, a fourth pass offers a burst to a bounded-queue service
    (overload_policy="shed") and reports the shed rate — the admission
    control path CI smoke-checks."""
    from repro.data import modis
    from repro.engine import Engine
    from repro.service import ServiceConfig, ServiceOverloaded, YCHGService

    op = args.op

    def timed_pass(svc, masks):
        t0 = time.perf_counter()
        outs = [f.result(timeout=600)
                for f in [svc.submit(m, op=op) for m in masks]]
        return time.perf_counter() - t0, outs

    masks = [modis.snowfield(args.res, seed=s) for s in range(args.batch)]
    fresh = [modis.snowfield(args.res, seed=args.batch + s)
             for s in range(args.batch)]
    px = args.batch * args.res * args.res
    engine = Engine()
    cfg = ServiceConfig(bucket_sides=(args.res,), max_batch=args.batch)
    with YCHGService(engine, cfg) as svc:
        t_cold, outs = timed_pass(svc, masks)       # compiles the bucket shape
        t_warm, _ = timed_pass(svc, fresh)          # steady-state compute
        before_cached = svc.metrics()
        t_cached, _ = timed_pass(svc, masks)        # repeat traffic: cache
        m = svc.metrics()
    # the cached pass's own hit rate (lifetime m.hit_rate would dilute it
    # with the cold/warm passes' unavoidable misses)
    cached_hit_rate = (m.cache_hits - before_cached.cache_hits) / args.batch
    edges = [_op_stat(op, o) for o in outs]
    print(f"{op} service[{m.backend}]: {args.batch} x {args.res}^2 masks")
    print(f"  cold  {t_cold * 1e3:8.1f}ms (includes compile)")
    print(f"  warm  {t_warm * 1e3:8.1f}ms ({px / t_warm / 1e6:.0f} Mpx/s)")
    print(f"  cached{t_cached * 1e3:8.1f}ms "
          f"({px / t_cached / 1e6:.0f} Mpx/s, hit rate {cached_hit_rate:.0%})")
    print(f"  p50 {m.p50_latency_ms:.1f}ms p95 {m.p95_latency_ms:.1f}ms over "
          f"{m.completed} requests ({m.completed_from_cache} from cache) "
          f"in {m.batches} device batches; {_OP_STAT_NAME[op]} per tile: "
          f"{edges}")
    if args.overload:
        # admission control under a deliberate burst: a bounded queue with
        # overload_policy="shed" fails the excess fast instead of letting
        # latency balloon. The long delay window holds the two admitted
        # requests pending, so the shed count is deterministic.
        n_burst = 4 * args.batch
        burst = [modis.snowfield(args.res, seed=10_000 + s)
                 for s in range(n_burst)]
        ocfg = ServiceConfig(bucket_sides=(args.res,), max_batch=args.batch,
                             max_delay_ms=200.0, max_queue_depth=2,
                             overload_policy="shed")
        shed, futures = 0, []
        with YCHGService(engine, ocfg) as osvc:
            for b in burst:
                try:
                    futures.append(osvc.submit(b))
                except ServiceOverloaded:
                    shed += 1
            om = osvc.metrics()
        for f in futures:
            f.result(timeout=600)   # admitted requests still resolve
        print(f"  overload burst of {n_burst} at max_queue_depth=2: "
              f"{len(futures)} admitted, {shed} shed "
              f"(shed rate {shed / n_burst:.0%})")
        if shed == 0 or om.shed != shed:
            raise SystemExit(
                "overload pass failed: admission control shed nothing")


def _parse_hostport(s: str, default_host: str = "127.0.0.1"):
    """"HOST:PORT", ":PORT", or "PORT" -> (host, port)."""
    if "//" in s:
        s = s.split("//", 1)[1]
    s = s.rstrip("/")
    host, _, port = s.rpartition(":")
    return (host or default_host), int(port)


def _service_config(args, **overrides):
    from repro.service import ServiceConfig

    sides = (tuple(int(b) for b in args.buckets.split(","))
             if args.buckets else (args.res,))
    knobs = dict(bucket_sides=sides, max_batch=args.batch,
                 max_queue_depth=args.max_queue_depth,
                 bucket_queue_depth=args.bucket_queue_depth,
                 overload_policy=args.policy)
    knobs.update(overrides)
    return ServiceConfig(**knobs)


def serve_listen(args):
    """Serve the ROI service over loopback/network HTTP (+ optional RPC)
    until interrupted — the production front end behind a CLI flag."""
    from repro.engine import Engine
    from repro.frontend import ServerThread
    from repro.service import YCHGService

    host, port = _parse_hostport(args.listen)
    rpc_port = (_parse_hostport(args.rpc_listen)[1]
                if args.rpc_listen else None)
    with YCHGService(Engine(), _service_config(args)) as svc:
        with ServerThread(svc, host=host, port=port,
                          rpc_port=rpc_port) as srv:
            extra = (f" (rpc on {host}:{srv.rpc_port})"
                     if rpc_port is not None else "")
            print(f"yCHG frontend listening on http://{host}:{srv.port}"
                  f"{extra}; buckets {svc.config.bucket_sides}, "
                  f"max_batch {svc.config.max_batch}", flush=True)
            try:
                threading.Event().wait()
            except KeyboardInterrupt:
                print("shutting down", flush=True)
                path = obs.auto_dump("serve-shutdown")
                if path:
                    print(f"flight recorder dumped to {path}", flush=True)


def serve_connect(args):
    """Client mode: drive a running front end with the mask workload and
    report wire-level timing (the network twin of the in-process pass)."""
    from repro.data import modis
    from repro.frontend import YCHGClient

    host, port = _parse_hostport(args.connect)
    masks = [modis.snowfield(args.res, seed=s) for s in range(args.batch)]
    px = args.batch * args.res * args.res
    with YCHGClient(host, port) as client:
        health = client.wait_ready(timeout=60.0)
        print(f"connected to {host}:{port}: backend {health['backend']}")
        t0 = time.perf_counter()
        items = list(client.analyze_batch(masks))
        dt = time.perf_counter() - t0
        failed = [it for it in items if not it.ok]
        if failed:
            raise SystemExit(
                f"{len(failed)} of {len(items)} requests failed; first: "
                f"{failed[0].status} {failed[0].error}")
        edges = [int(it.result["n_hyperedges"]) for it in
                 sorted(items, key=lambda it: it.id)]
        print(f"  wire  {dt * 1e3:8.1f}ms for {args.batch} x {args.res}^2 "
              f"masks ({px / dt / 1e6:.0f} Mpx/s); hyperedges: {edges}")


def frontend_smoke(args):
    """CI end-to-end assert over a real loopback socket (ephemeral port):

      1. a streamed client batch is BIT-IDENTICAL (values, dtypes, shapes)
         to in-process ``YCHGService.submit`` on the same masks;
      2. one traced request leaves a single flight-recorder trace whose
         spans cover client -> frontend -> scheduler -> engine in order
         (skipped under ``YCHG_TRACE=0``);
      3. every ``/metrics`` series parses as Prometheus text, histogram
         ``_sum``/``_count`` agree with their buckets, and the latency
         histogram's total count equals completed-minus-cache-served;
      4. at a full admission queue the wire answer is HTTP 429 with a
         Retry-After, and the service's shed counter moves (visible in
         /metrics down to the per-bucket counter).

    Exits nonzero on any failure — the frontend-smoke CI job runs this.
    """
    from repro.data import modis
    from repro.engine import Engine
    from repro.frontend import FrontendOverloaded, ServerThread, YCHGClient
    from repro.obs import base_family, parse_prom_text
    from repro.service import YCHGService

    masks = [modis.snowfield(args.res, seed=s) for s in range(args.batch)]
    engine = Engine()
    with YCHGService(engine, _service_config(args)) as svc, \
            ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        items = {it.id: it for it in client.analyze_batch(masks)}
        want = [svc.submit(m).result(timeout=600).to_host() for m in masks]
        for i, host_res in enumerate(want):
            item = items.get(i)
            if item is None or not item.ok:
                raise SystemExit(f"frontend smoke: mask {i} failed over the "
                                 f"wire: {item and item.error}")
            for field, arr in host_res.items():
                a, b = np.asarray(arr), item.result[field]
                if not (np.array_equal(a, b) and a.dtype == b.dtype
                        and a.shape == b.shape):
                    raise SystemExit(
                        f"frontend smoke: field {field!r} of mask {i} is "
                        f"not bit-identical over the wire")
        print(f"frontend smoke: {len(masks)} masks round-tripped over "
              f"loopback HTTP bit-identical to in-process submit")

        # trace leg: one fresh mask end to end, then one trace id in the
        # flight recorder must cover every stage of the request
        if obs.tracing_enabled():
            tid = obs.new_trace_id()
            client.analyze(modis.snowfield(args.res, seed=4242),
                           trace_id=tid)
            events = [e for e in client.debug_traces().get("traceEvents", [])
                      if e.get("args", {}).get("trace_id") == tid]
            names = {e["name"] for e in events}
            needed = {"client.encode", "client.wire", "frontend.parse",
                      "cache.probe", "scheduler.admission",
                      "scheduler.queue_wait", "scheduler.flush",
                      "engine.compute", "engine.crop"}
            if needed - names:
                raise SystemExit(f"frontend smoke [trace]: spans missing "
                                 f"from the flight recorder: "
                                 f"{sorted(needed - names)}")
            ts = {e["name"]: e["ts"] for e in events}
            chain = ["client.encode", "frontend.parse",
                     "scheduler.admission", "engine.compute", "engine.crop"]
            for a, b in zip(chain, chain[1:]):
                if ts[b] < ts[a]:   # same process, same clock: strict
                    raise SystemExit(f"frontend smoke [trace]: span {b!r} "
                                     f"starts before {a!r}")
            print("frontend smoke: one trace covers client -> frontend -> "
                  "scheduler -> engine with ordered spans", flush=True)

        # metrics leg: the whole page must parse; histograms must be
        # internally consistent and tie out against the request counters
        page = parse_prom_text(client.metrics_text())
        lat_count = 0.0
        for fam in sorted(n for n, t in page.types.items()
                          if t == "histogram"):
            series = {}
            for s in page.samples:
                if base_family(s.name) != fam:
                    continue
                key = tuple(p for p in s.labels if p[0] != "le")
                d = series.setdefault(key, {"b": [], "sum": None,
                                            "count": None})
                if s.name.endswith("_bucket"):
                    d["b"].append(s.value)
                elif s.name.endswith("_sum"):
                    d["sum"] = s.value
                elif s.name.endswith("_count"):
                    d["count"] = s.value
            for key, d in series.items():
                if d["sum"] is None or d["count"] is None or not d["b"]:
                    raise SystemExit(
                        f"frontend smoke [metrics]: histogram {fam} series "
                        f"{dict(key)} is missing _sum/_count/buckets")
                if d["b"] != sorted(d["b"]) or d["b"][-1] != d["count"]:
                    raise SystemExit(
                        f"frontend smoke [metrics]: histogram {fam} series "
                        f"{dict(key)} buckets disagree with _count")
                if fam == "ychg_request_latency_seconds":
                    lat_count += d["count"]

        def scalar(name):
            vals = [s.value for s in page.samples
                    if s.name == name and not s.labels]
            return vals[0] if vals else 0.0

        want_count = (scalar("ychg_completed_total")
                      - scalar("ychg_completed_from_cache_total"))
        if lat_count != want_count:
            raise SystemExit(
                f"frontend smoke [metrics]: latency histogram count "
                f"{lat_count} != completed-minus-cached {want_count}")
        print(f"frontend smoke: /metrics parsed clean; latency histogram "
              f"count {lat_count:.0f} ties out against the request "
              f"counters", flush=True)

    # overload leg: ONE admission slot, held by an in-process submit parked
    # in a long delay window, so the wire request deterministically sheds
    ocfg = _service_config(args, max_delay_ms=10_000.0, max_queue_depth=1,
                           bucket_queue_depth=1, overload_policy="shed")
    with YCHGService(engine, ocfg) as osvc:
        holder = osvc.submit(masks[0])
        with ServerThread(osvc) as srv, \
                YCHGClient("127.0.0.1", srv.port) as client:
            try:
                client.analyze(masks[1])
                raise SystemExit("frontend smoke: expected HTTP 429, "
                                 "got a result")
            except FrontendOverloaded as e:
                if not e.retry_after_s > 0:
                    raise SystemExit("frontend smoke: 429 carried no "
                                     "positive retry_after_s")
            metrics = client.metrics_text()
        for needle in ("ychg_shed_total 1", "ychg_shed_bucket_total{"):
            if needle not in metrics:
                raise SystemExit(
                    f"frontend smoke: {needle!r} missing from /metrics "
                    f"after an overload shed")
    holder.result(timeout=600)   # the admitted request still resolves
    print("frontend smoke: overload answered 429 with Retry-After and the "
          "per-bucket shed counter moved")


def op_smoke(args):
    """CI end-to-end assert for the multi-op platform over loopback HTTP:

      1. **per-op bit-identity** — for every registered op, one request
         over ``POST /v1/{op}`` is BIT-IDENTICAL (values, dtypes, shapes)
         to the op's in-repo reference function on the same input;
      2. **pipeline == separate requests** — one ``POST /v1/pipeline``
         compound request (denoise -> ychg, device-resident between
         stages) equals feeding stage 1's wire output back as stage 2's
         request, field for field;
      3. **routing** — an unknown op answers 404 JSON naming the
         registered ops, and ``/metrics`` exports the dispatch histogram
         with one ``op=`` label per op served.

    Exits nonzero on any failure — the op-smoke CI job runs this.
    """
    import json as _json

    import jax.numpy as jnp

    from repro.data import modis
    from repro.engine import Engine
    from repro.engine.ops import get_op, op_names
    from repro.frontend import FrontendError, ServerThread, YCHGClient
    from repro.service import ServiceConfig, YCHGService

    rng = np.random.default_rng(11)
    inputs = {
        "ychg": modis.snowfield(args.res, seed=0),
        "ccl": modis.snowfield(args.res, seed=1),
        "denoise": rng.random((args.res, args.res)).astype(np.float32),
    }
    cfg = ServiceConfig(bucket_sides=(args.res,), max_batch=args.batch)
    with YCHGService(Engine(), cfg) as svc, \
            ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        client.wait_ready(timeout=120.0)
        for op in sorted(op_names()):
            x = inputs[op]
            got = client.analyze(x, op=op)
            spec = get_op(op)
            # masks fill their bucket exactly (res == bucket side), so the
            # service's crop is the identity and the wire result must equal
            # the reference, rendered in the single-request (batched=False)
            # layout the service serves
            want = spec.from_summary(
                spec.reference(jnp.asarray(x)[None]), False).to_host()
            for field, arr in want.items():
                a, b = np.asarray(arr), got[field]
                if not (np.array_equal(a, b) and a.dtype == b.dtype
                        and a.shape == b.shape):
                    raise SystemExit(
                        f"op smoke [{op}]: field {field!r} not bit-identical "
                        f"to the in-repo reference over the wire")
            print(f"op smoke: /v1/{op} bit-identical to its reference",
                  flush=True)

        # pipeline leg: the compound request vs its stages as separate
        # wire requests — the device-resident chain must be bit-exact
        img = inputs["denoise"]
        compound = client.pipeline(img, ["denoise", "ychg"])
        stage1 = client.analyze(img, op="denoise")
        stage2 = client.analyze(stage1["image"], op="ychg")
        for field, arr in stage2.items():
            a, b = np.asarray(arr), compound[field]
            if not (np.array_equal(a, b) and a.dtype == b.dtype
                    and a.shape == b.shape):
                raise SystemExit(
                    f"op smoke [pipeline]: field {field!r} of the compound "
                    f"denoise+ychg request differs from separate requests")
        print("op smoke: /v1/pipeline denoise+ychg == the stages issued as "
              "separate requests", flush=True)

        # routing leg: unknown op -> 404 JSON naming the registry
        try:
            client.analyze(inputs["ychg"], op="warp")
            raise SystemExit("op smoke: unknown op answered 200")
        except FrontendError as e:
            if e.status != 404:
                raise SystemExit(
                    f"op smoke: unknown op answered {e.status}, wanted 404")
            body = _json.loads(str(e))
            if sorted(body.get("ops", [])) != sorted(op_names()):
                raise SystemExit(
                    f"op smoke: 404 body named ops {body.get('ops')}, "
                    f"wanted {sorted(op_names())}")
        metrics = client.metrics_text()
        for op in op_names():
            needle = f'ychg_engine_dispatch_seconds_count{{op="{op}"'
            if needle not in metrics:
                raise SystemExit(
                    f"op smoke: dispatch histogram missing an op={op!r} "
                    f"series after serving it")
        print("op smoke: unknown op answered 404 naming the registry; "
              "dispatch histogram carries one op= label per op", flush=True)


def slo_smoke(args):
    """CI end-to-end assert for traffic classes over loopback HTTP
    (docs/traffic.md): the class/deadline/tenant headers must reach the
    scheduler and change admission, visibly in the wire answer and on
    ``/metrics``.

      1. **priority preemption** — against a parked batch-class backlog,
         an interactive-class wire request overtakes the backlog: its
         completion timestamp precedes the last batch completion and
         batch requests are still pending when it returns. A
         deterministic sub-leg (one admission slot, held) then sheds a
         batch-class wire request and asserts the 429 carries
         ``kind="overload"`` and ``ychg_shed_class_total{class="batch"}``
         moves.
      2. **deadline shed** — with the drain-rate estimator white-box
         seeded to exactly 2 requests/s, a wire request with
         ``X-YCHG-Deadline-Ms: 100`` sheds at admission with
         ``kind="deadline"`` and the honest Retry-After
         ``predicted 0.5s - deadline 0.1s = 0.4s``; a dead-on-arrival
         ``deadline_ms=0`` probe sheds with the clamp floor (0.05s).
      3. **tenant quota** — a two-token burst tenant admits 2 of 4 wire
         requests and sheds the rest with ``kind="quota"`` and the
         30s-clamped Retry-After, while another tenant admits freely;
         ``ychg_shed_tenant_total{tenant="acme"}`` counts exactly the
         sheds.

    Exits nonzero on any failure — the slo-smoke CI job runs this.
    """
    from repro.data import modis
    from repro.engine import Engine
    from repro.frontend import FrontendOverloaded, ServerThread, YCHGClient
    from repro.service import ServiceConfig, YCHGService

    res, batch_res = args.res, 2 * args.res
    engine = Engine()

    def expect_shed(client, kind, **kw):
        try:
            client.analyze(modis.snowfield(res, seed=kw.pop("seed")), **kw)
        except FrontendOverloaded as e:
            if e.kind != kind:
                raise SystemExit(f"slo smoke: shed carried kind={e.kind!r}, "
                                 f"wanted {kind!r}")
            return e
        raise SystemExit(f"slo smoke: expected a {kind} 429, got a result")

    def counter(text, needle):
        for line in text.splitlines():
            if line.startswith(needle):
                return float(line.rsplit(" ", 1)[1])
        return 0.0

    # ---- leg 1: priority preemption against a live batch backlog
    cfg = ServiceConfig(bucket_sides=(res, batch_res),
                        max_batch=args.batch, max_delay_ms=2.0)
    with YCHGService(engine, cfg) as svc, \
            ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        client.wait_ready(timeout=120.0)
        # warm ONLY the interactive bucket: the batch backlog's first
        # flush then includes the batch bucket's compile, so the backlog
        # is reliably still pending when the interactive request lands
        client.analyze(modis.snowfield(res, seed=100), klass="interactive")
        done_at = {}
        batch_futs = [svc.submit(modis.snowfield(batch_res, seed=200 + i),
                                 klass="batch")
                      for i in range(4 * args.batch)]
        for i, f in enumerate(batch_futs):
            f.add_done_callback(
                lambda _f, i=i: done_at.setdefault(i, time.perf_counter()))
        client.analyze(modis.snowfield(res, seed=300), klass="interactive")
        t_interactive = time.perf_counter()
        pending = sum(1 for f in batch_futs if not f.done())
        for f in batch_futs:
            f.result(timeout=600)
        deadline = time.perf_counter() + 30.0
        while (len(done_at) < len(batch_futs)
               and time.perf_counter() < deadline):
            time.sleep(0.001)   # done-callbacks can lag result() briefly
        if pending == 0 or t_interactive >= max(done_at.values()):
            raise SystemExit(
                f"slo smoke [priority]: interactive request did not "
                f"overtake the batch backlog ({pending} of "
                f"{len(batch_futs)} batch requests pending at its "
                f"completion)")
        print(f"slo smoke: interactive wire request overtook the "
              f"batch-class backlog ({pending}/{len(batch_futs)} batch "
              f"requests still pending at its completion)", flush=True)

    # leg 1b: deterministic class-labelled shed — ONE admission slot,
    # held by a parked submit, so the batch-class wire request sheds
    ocfg = ServiceConfig(bucket_sides=(res,), max_batch=args.batch,
                         max_delay_ms=10_000.0, max_queue_depth=1,
                         bucket_queue_depth=1, overload_policy="shed")
    with YCHGService(engine, ocfg) as osvc:
        holder = osvc.submit(modis.snowfield(res, seed=400))
        with ServerThread(osvc) as srv, \
                YCHGClient("127.0.0.1", srv.port) as client:
            e = expect_shed(client, "overload", seed=401, klass="batch")
            if not e.retry_after_s > 0:
                raise SystemExit("slo smoke [priority]: overload 429 "
                                 "carried no positive retry_after_s")
            shed = counter(client.metrics_text(),
                           'ychg_shed_class_total{class="batch"}')
        holder.result(timeout=600)
    if shed != 1:
        raise SystemExit(f"slo smoke [priority]: shed_class_total for the "
                         f"batch class is {shed}, wanted 1")
    print('slo smoke: wire shed counted under '
          'ychg_shed_class_total{class="batch"}', flush=True)

    # ---- leg 2: deadline shed with an honest Retry-After. Seed the
    # drain-rate estimator white-box to exactly 2 req/s on an idle
    # service (depth 0): predicted wait is (0+1)/2 = 0.5s, so a 100ms
    # deadline sheds with retry_after = 0.5 - 0.1 = 0.4s exactly.
    with YCHGService(engine, ServiceConfig(
            bucket_sides=(res,), max_batch=args.batch)) as svc, \
            ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        # cold estimator first: deadline_ms=0 is dead on arrival even
        # without evidence, and its zero lateness clamps to the floor
        dead = expect_shed(client, "deadline", seed=501, deadline_ms=0.0)
        if abs(dead.retry_after_s - 0.05) > 1e-9:
            raise SystemExit(
                f"slo smoke [deadline]: dead-on-arrival retry_after_s "
                f"{dead.retry_after_s} != the 0.05s clamp floor")
        est = svc._scheduler._drain_rate
        est.observe(0, now=0.0)
        est.observe(20, now=10.0)
        e = expect_shed(client, "deadline", seed=500, deadline_ms=100.0)
        if abs(e.retry_after_s - 0.4) > 1e-9:
            raise SystemExit(
                f"slo smoke [deadline]: retry_after_s {e.retry_after_s} "
                f"!= the honest lateness 0.4s (predicted 0.5s - "
                f"deadline 0.1s)")
        sheds = counter(client.metrics_text(), "ychg_shed_deadline_total")
        if sheds != 2:
            raise SystemExit(f"slo smoke [deadline]: "
                             f"ychg_shed_deadline_total {sheds}, wanted 2")
    print("slo smoke: 100ms deadline shed at admission with the honest "
          "0.4s Retry-After; dead-on-arrival probe shed at the clamp "
          "floor", flush=True)

    # ---- leg 3: tenant token buckets over the wire. burst=2 at a
    # starvation refill rate: 2 of 4 "acme" requests admit, 2 shed with
    # the 30s-clamped Retry-After; "beta" admits freely.
    tcfg = ServiceConfig(bucket_sides=(res,), max_batch=args.batch,
                         max_delay_ms=2.0, tenant_rate=0.001,
                         tenant_burst=2)
    with YCHGService(engine, tcfg) as svc, \
            ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        admitted, sheds = 0, 0
        for i in range(4):
            try:
                client.analyze(modis.snowfield(res, seed=600 + i),
                               tenant="acme")
                admitted += 1
            except FrontendOverloaded as e:
                if e.kind != "quota":
                    raise SystemExit(f"slo smoke [quota]: shed carried "
                                     f"kind={e.kind!r}, wanted 'quota'")
                if e.retry_after_s != 30.0:
                    raise SystemExit(
                        f"slo smoke [quota]: retry_after_s "
                        f"{e.retry_after_s} != the 30s clamp for a "
                        f"starvation-rate refill")
                sheds += 1
        client.analyze(modis.snowfield(res, seed=700), tenant="beta")
        metrics = client.metrics_text()
        if (admitted, sheds) != (2, 2):
            raise SystemExit(f"slo smoke [quota]: burst 2 of 4 offered "
                             f"should admit 2 and shed 2, got "
                             f"({admitted}, {sheds})")
        by_tenant = counter(metrics, 'ychg_shed_tenant_total{tenant="acme"}')
        if by_tenant != sheds or counter(
                metrics, "ychg_shed_quota_total") != sheds:
            raise SystemExit(
                f"slo smoke [quota]: /metrics counted {by_tenant} acme "
                f"sheds, client saw {sheds}")
    print("slo smoke: tenant quota admitted the burst, shed the rest "
          "with kind=quota and the clamped Retry-After; counters tie "
          "out per tenant", flush=True)


def _worker_args(args):
    """Worker-CLI knobs mirroring this invocation's service knobs."""
    wa = ["--buckets", args.buckets if args.buckets else str(args.res),
          "--max-batch", str(args.batch), "--policy", args.policy]
    if args.max_queue_depth is not None:
        wa += ["--max-queue-depth", str(args.max_queue_depth)]
    if args.bucket_queue_depth is not None:
        wa += ["--bucket-queue-depth", str(args.bucket_queue_depth)]
    if args.compile_cache:
        wa += ["--compile-cache", args.compile_cache]
    if args.trace_dump:
        wa += ["--trace-dump", args.trace_dump]
    return wa


def _router_config(args, **overrides):
    from repro.fleet import RouterConfig

    sides = (tuple(int(b) for b in args.buckets.split(","))
             if args.buckets else (args.res,))
    knobs = dict(bucket_sides=sides, max_batch=args.batch,
                 max_queue_depth=args.max_queue_depth,
                 bucket_queue_depth=args.bucket_queue_depth,
                 overload_policy=args.policy)
    knobs.update(overrides)
    return RouterConfig(**knobs)


def serve_fleet(args):
    """Serve a worker-process fleet behind the consistent-hash router
    until interrupted: ``--fleet N`` is ``--listen`` at fleet scale."""
    from repro.fleet import FleetRouter, FleetSupervisor, RouterThread

    host, port = (_parse_hostport(args.listen) if args.listen
                  else ("127.0.0.1", 8788))
    sup = FleetSupervisor(args.fleet, worker_args=_worker_args(args))
    print(f"spawning {args.fleet} workers...", flush=True)
    try:
        links = sup.start()
        router = FleetRouter(links, _router_config(args), host=host,
                             port=port, supervisor=sup)
        with RouterThread(router) as rt:
            workers = ", ".join(
                f"{l.name}=rpc:{l.rpc_port}" for l in links)
            print(f"yCHG fleet router on http://{host}:{rt.port} over "
                  f"{len(links)} workers ({workers})", flush=True)
            try:
                threading.Event().wait()
            except KeyboardInterrupt:
                print("shutting down fleet", flush=True)
    finally:
        sup.stop()


def fleet_smoke(args):
    """CI end-to-end assert for the fleet: router over 2 subprocess
    workers on loopback (ephemeral ports everywhere).

      1. **bit-identity** — a streamed batch through router -> worker RPC
         is byte-identical (values, dtypes, shapes) to in-process
         ``YCHGService.submit`` on the same masks;
      2. **rerouting** — hard-kill the worker owning one mask's keyspace;
         the repeat analyze fails over to the survivor, still matches,
         and ``ychg_fleet_rerouted_total`` moves;
      3. **peering** — restart the dead slot (same ring name, empty
         cache) and repeat the mask once more: the restarted owner
         adopts the survivor's cached entry instead of recomputing, and
         the rolled-up /metrics page shows
         ``ychg_cache_peer_hits_total`` > 0.

    Exits nonzero on any failure — the fleet-smoke CI job runs this.
    """
    import asyncio

    from repro.data import modis
    from repro.engine import Engine
    from repro.fleet import (
        FleetRouter,
        FleetSupervisor,
        HashRing,
        RouterThread,
    )
    from repro.fleet.router import routing_key
    from repro.frontend import YCHGClient
    from repro.service import YCHGService

    def counter(text, name):
        for line in text.splitlines():
            if line.startswith(name + " "):
                return float(line.rsplit(" ", 1)[1])
        return 0.0

    def check_identical(leg, got, want_res):
        for field, arr in want_res.items():
            a, b = np.asarray(arr), got[field]
            if not (np.array_equal(a, b) and a.dtype == b.dtype
                    and a.shape == b.shape):
                raise SystemExit(f"fleet smoke [{leg}]: field {field!r} "
                                 f"not bit-identical through the router")

    masks = [modis.snowfield(args.res, seed=s) for s in range(args.batch)]
    with YCHGService(Engine(), _service_config(args)) as svc:
        want = [svc.submit(m).result(timeout=600).to_host() for m in masks]

    sup = FleetSupervisor(2, worker_args=_worker_args(args))
    try:
        links = sup.start()
        # health loop effectively dormant: the smoke drives the death ->
        # reroute -> restart -> peer-hit sequence deterministically
        router = FleetRouter(links, _router_config(
            args, health_interval_s=3600.0), supervisor=sup)
        with RouterThread(router) as rt, \
                YCHGClient("127.0.0.1", rt.port) as client:
            client.wait_ready(timeout=120.0)
            items = {it.id: it for it in client.analyze_batch(masks)}
            for i, want_res in enumerate(want):
                item = items.get(i)
                if item is None or not item.ok:
                    raise SystemExit(
                        f"fleet smoke [identity]: mask {i} failed through "
                        f"the router: {item and item.error}")
                check_identical("identity", item.result, want_res)
            print(f"fleet smoke: {len(masks)} masks through router over 2 "
                  f"workers bit-identical to in-process submit", flush=True)

            # trace leg: one traced batch, then merge the client-local,
            # router, and per-worker flight recorders and assert a single
            # trace id stitches spans across >= 2 processes in order
            if obs.tracing_enabled():
                tid = obs.new_trace_id()
                fresh = [modis.snowfield(args.res, seed=7000 + s)
                         for s in range(2)]
                for it in client.analyze_batch(fresh, trace_id=tid):
                    if not it.ok:
                        raise SystemExit(f"fleet smoke [trace]: traced "
                                         f"batch failed: {it.error}")
                events = list(obs.recorder().chrome_events())
                events += client.debug_traces().get("traceEvents", [])
                for l in links:
                    with YCHGClient(l.host, l.http_port) as wc:
                        events += wc.debug_traces().get("traceEvents", [])
                events = [e for e in events
                          if e.get("args", {}).get("trace_id") == tid]
                names = {e["name"] for e in events}
                needed = {"client.encode", "router.admission",
                          "router.forward", "frontend.parse",
                          "scheduler.queue_wait", "engine.compute"}
                if needed - names:
                    raise SystemExit(f"fleet smoke [trace]: spans missing "
                                     f"across the fleet recorders: "
                                     f"{sorted(needed - names)}")
                pids = {e["pid"] for e in events}
                if len(pids) < 2:
                    raise SystemExit(
                        f"fleet smoke [trace]: trace {tid} never crossed a "
                        f"process boundary (pids {sorted(pids)})")
                ts = {}
                for e in events:   # earliest start per span name
                    ts[e["name"]] = min(ts.get(e["name"], e["ts"]), e["ts"])
                slack_us = 100_000   # cross-process wall alignment slack
                chain = ["client.encode", "router.admission",
                         "frontend.parse", "engine.compute"]
                for a, b in zip(chain, chain[1:]):
                    if ts[b] + slack_us < ts[a]:
                        raise SystemExit(f"fleet smoke [trace]: span {b!r} "
                                         f"starts before {a!r}")
                import json as _json
                _json.loads(_json.dumps({"traceEvents": events}))
                print(f"fleet smoke: trace {tid} stitches "
                      f"{len(events)} spans across {len(pids)} processes "
                      f"(client -> router -> worker)", flush=True)

            ring = HashRing([l.name for l in links],
                            router.config.replicas)
            owner = ring.node_for(routing_key(masks[0]))
            owner_link = next(l for l in links if l.name == owner)
            owner_link.process.kill()
            owner_link.process.wait(timeout=30)
            check_identical("reroute", client.analyze(masks[0]), want[0])
            rerouted = counter(client.metrics_text(),
                               "ychg_fleet_rerouted_total")
            if rerouted < 1:
                raise SystemExit("fleet smoke [reroute]: killed the owner "
                                 "but ychg_fleet_rerouted_total never moved")
            print(f"fleet smoke: killed {owner}, request rerouted to the "
                  f"survivor and stayed bit-identical", flush=True)

            # one manual health pass: notices the corpse, restarts the
            # slot under its old name, re-broadcasts the peer set
            asyncio.run_coroutine_threadsafe(
                router.check_workers(), rt._loop).result(timeout=300)
            health = client.health()
            if not all(health["workers"].values()):
                raise SystemExit(f"fleet smoke [peering]: restart left "
                                 f"workers down: {health['workers']}")
            check_identical("peering", client.analyze(masks[0]), want[0])
            peer_hits = counter(client.metrics_text(),
                                "ychg_cache_peer_hits_total")
            if peer_hits < 1:
                raise SystemExit(
                    "fleet smoke [peering]: restarted owner served the "
                    "repeat mask without a sibling-cache hit "
                    f"(ychg_cache_peer_hits_total={peer_hits})")
            print(f"fleet smoke: restarted {owner} served repeat traffic "
                  f"from the survivor's cache (peer hits {peer_hits:.0f})",
                  flush=True)
    finally:
        sup.stop()


def _scene_manifest(args):
    from repro.scene import manifest_from_json, synthetic_manifest

    if args.manifest:
        with open(args.manifest) as f:
            return manifest_from_json(f.read())
    return synthetic_manifest(args.granules, args.scene_height,
                              args.scene_width, seed=args.seed)


def scene_run(args):
    """``serve.py ... scene``: run a granule manifest as a resumable bulk
    job. SIGTERM/SIGINT checkpoint the current tile row and exit cleanly;
    rerunning the same command resumes from the last checkpoint and the
    output files come out byte-identical to an uninterrupted run."""
    import signal

    from repro.engine import Engine
    from repro.scene import BulkJob, BulkJobConfig, SceneProgress

    manifest = _scene_manifest(args)
    cfg = BulkJobConfig(out_dir=args.out, ckpt_dir=args.ckpt,
                        tile_h=args.tile_h, stack_tiles=args.stack,
                        checkpoint_every=args.checkpoint_every)
    progress = SceneProgress()
    job = BulkJob(Engine(), manifest, cfg, progress=progress)
    stop = threading.Event()
    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, lambda *_: stop.set())

    px = sum(s.pixels for s in manifest)
    print(f"bulk job: {len(manifest)} granules "
          f"({px / 1e6:.1f} Mpx total), tile_h {cfg.tile_h}, "
          f"stacks of {cfg.stack_tiles}, checkpoint every "
          f"{cfg.checkpoint_every} stacks -> {args.ckpt}", flush=True)
    report = job.run(max_stacks=args.max_stacks, should_stop=stop.is_set)
    snap = progress.snapshot()
    done_px = report.tiles_done * cfg.tile_h * manifest[0].width
    rate = (done_px / report.elapsed_s / 1e6
            if report.elapsed_s > 0 else 0.0)
    print(f"bulk job {report.status}: {report.granules_done} granules, "
          f"{report.tiles_done} tiles in {report.elapsed_s:.2f}s "
          f"({rate:.0f} Mpx/s); tiles {snap.tiles_done}/{snap.tiles_total}, "
          f"resumes {report.resumes}, "
          f"stitch {snap.stitch_time_s * 1e3:.1f}ms", flush=True)
    for path in report.written:
        print(f"  wrote {path}", flush=True)
    dump = obs.auto_dump("scene-run-end")
    if dump:
        print(f"flight recorder dumped to {dump}", flush=True)
    if not report.completed:
        print("interrupted — rerun the same command to resume from the "
              "checkpoint", flush=True)


def scene_smoke(args):
    """CI end-to-end assert for the scene subsystem (repro.scene):

      1. **stitch bit-identity** — streaming a synthetic granule through
         ``SceneRunner`` (ragged last strip included) produces all seven
         result fields BIT-IDENTICAL (values, dtypes, shapes) to one
         whole-scene ``engine.analyze`` call;
      2. **kill -> resume byte-identity** — a ``BulkJob`` stopped
         mid-granule (with its newest checkpoint then truncated, so the
         Checkpointer must fall back to the previous valid one) resumes
         and writes result files byte-identical to an uninterrupted run;
      3. **online/offline agreement** — the same tiles replayed through
         the HTTP front end's NDJSON batch endpoint match per-tile
         ``engine.analyze`` bit for bit, ``stitch_tile_runs`` over the
         wire results equals the offline scene runs, and the attached
         ``SceneProgress`` surfaces in ``/metrics``.

    Exits nonzero on any failure — the scene-smoke CI job runs this.
    """
    import glob
    import os
    import tempfile
    import warnings

    from repro.data import scenes
    from repro.engine import Engine
    from repro.frontend import ServerThread, YCHGClient
    from repro.scene import (
        BulkJob,
        BulkJobConfig,
        GranuleReader,
        SceneProgress,
        SceneRunner,
        read_scene_result,
        stitch_tile_runs,
        synthetic_manifest,
    )
    from repro.service import ServiceConfig, YCHGService

    engine = Engine()

    # leg 1: stitch bit-identity, ragged last strip (45 = 3*16 - 3)
    h, w, tile_h = 45, args.res, 16
    mask = scenes.scene(h, w, seed=7, cell=8)
    reader = GranuleReader.from_array(mask, tile_h, granule_id="smoke")
    got = SceneRunner(engine, stack_tiles=2).analyze_scene(reader).to_host()
    want = engine.analyze(mask).to_host()
    for field, arr in want.items():
        a, b = np.asarray(arr), got[field]
        if not (np.array_equal(a, b) and a.dtype == b.dtype
                and a.shape == b.shape):
            raise SystemExit(f"scene smoke [stitch]: field {field!r} of the "
                             f"stitched result is not bit-identical to the "
                             f"whole-scene analysis")
    print(f"scene smoke: {reader.n_tiles} stitched strips of a {h}x{w} "
          f"scene bit-identical to one whole-scene call", flush=True)

    # leg 2: kill -> resume byte-identity through a corrupted checkpoint
    manifest = synthetic_manifest(2, 40, args.res, seed=3, cell=8)
    with tempfile.TemporaryDirectory() as tmp:
        def job(tag, progress=None):
            return BulkJob(engine, manifest, BulkJobConfig(
                out_dir=os.path.join(tmp, tag, "out"),
                ckpt_dir=os.path.join(tmp, tag, "ckpt"),
                tile_h=8, stack_tiles=1, checkpoint_every=1),
                progress=progress)

        straight = job("straight").run()
        if not straight.completed:
            raise SystemExit("scene smoke [resume]: uninterrupted run did "
                             "not complete")
        first = job("killed").run(max_stacks=3)
        if first.completed:
            raise SystemExit("scene smoke [resume]: max_stacks=3 should "
                             "have interrupted the job mid-granule")
        # hard-kill flavour: truncate the newest checkpoint's shard so the
        # resume must warn and fall back to the previous valid step
        steps = sorted(glob.glob(os.path.join(tmp, "killed", "ckpt",
                                              "step_*")))
        shard = glob.glob(os.path.join(steps[-1], "*.npz"))[0]
        with open(shard, "r+b") as f:
            f.truncate(8)
        progress = SceneProgress()
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            second = job("killed", progress).run()
        if not any(issubclass(c.category, RuntimeWarning) for c in caught):
            raise SystemExit("scene smoke [resume]: truncated checkpoint "
                             "resumed without a RuntimeWarning fallback")
        if not second.completed or second.resumes < 1:
            raise SystemExit(f"scene smoke [resume]: resumed run ended "
                             f"{second.status} with {second.resumes} resumes")
        for spec in manifest:
            a = os.path.join(tmp, "straight", "out",
                             f"{spec.granule_id}.ychg")
            b = os.path.join(tmp, "killed", "out", f"{spec.granule_id}.ychg")
            with open(a, "rb") as fa, open(b, "rb") as fb:
                if fa.read() != fb.read():
                    raise SystemExit(
                        f"scene smoke [resume]: {spec.granule_id} output "
                        f"differs between straight and killed+resumed runs")
        offline = read_scene_result(os.path.join(
            tmp, "straight", "out", f"{manifest[0].granule_id}.ychg"))
        snap = progress.snapshot()
        print(f"scene smoke: kill at stack 3 + corrupt newest checkpoint, "
              f"resume wrote byte-identical outputs "
              f"(resumes {second.resumes}, tiles "
              f"{snap.tiles_done}/{snap.tiles_total})", flush=True)

        # leg 3: online/offline agreement over loopback NDJSON. Buckets are
        # square on max(h, w), so (tile_h, W) strips land in the W bucket.
        spec = manifest[0]
        reader = GranuleReader.open(spec, 8)
        tiles = [reader.read_tile(t) for t in range(reader.n_tiles)]
        svc_cfg = ServiceConfig(bucket_sides=(spec.width,),
                                max_batch=args.batch)
        with YCHGService(engine, svc_cfg) as svc, \
                ServerThread(svc) as srv, \
                YCHGClient("127.0.0.1", srv.port) as client:
            svc.attach_scene_progress(progress)
            items = {it.id: it for it in client.analyze_batch(tiles)}
            tile_runs = []
            for i, tile in enumerate(tiles):
                item = items.get(i)
                if item is None or not item.ok:
                    raise SystemExit(
                        f"scene smoke [online]: tile {i} failed over the "
                        f"wire: {item and item.error}")
                for field, arr in engine.analyze(tile).to_host().items():
                    a, b = np.asarray(arr), item.result[field]
                    if not (np.array_equal(a, b) and a.dtype == b.dtype
                            and a.shape == b.shape):
                        raise SystemExit(
                            f"scene smoke [online]: field {field!r} of "
                            f"tile {i} not bit-identical over the wire")
                tile_runs.append(item.result["runs"])
            online_runs = stitch_tile_runs(tile_runs, tiles)
            if not np.array_equal(online_runs, offline.runs):
                raise SystemExit(
                    "scene smoke [online]: stitching the wire-served tile "
                    "runs does not match the offline scene result")
            metrics = client.metrics_text()
        for needle in ("ychg_scene_tiles_done", "ychg_scene_resumes_total"):
            if needle not in metrics:
                raise SystemExit(f"scene smoke [online]: {needle!r} missing "
                                 f"from /metrics with a scene progress "
                                 f"attached")
        print(f"scene smoke: {len(tiles)} tiles over loopback NDJSON "
              f"bit-identical per tile, online stitch == offline scene "
              f"result, scene gauges on /metrics", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("command", nargs="?", choices=["scene"],
                    help="optional subcommand: 'scene' runs a resumable "
                         "granule bulk job (repro.scene)")
    ap.add_argument("--workload", default="lm", choices=["lm", "ychg"])
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--res", type=int, default=1024)
    ap.add_argument("--op", default="ychg",
                    choices=["ychg", "ccl", "denoise"],
                    help="ychg workload only: which registered operator "
                         "the --workload/smoke masks run through")
    ap.add_argument("--op-smoke", action="store_true",
                    help="ychg only: multi-op loopback assert (per-op wire "
                         "bit-identity vs reference, pipeline == separate "
                         "requests, 404 on unknown op)")
    ap.add_argument("--overload", action="store_true",
                    help="ychg only: add a bounded-queue overload pass and "
                         "fail unless admission control sheds")
    ap.add_argument("--listen", default=None, metavar="HOST:PORT",
                    help="ychg only: serve over HTTP until interrupted")
    ap.add_argument("--rpc-listen", default=None, metavar="HOST:PORT",
                    help="with --listen: also serve the framed TCP RPC")
    ap.add_argument("--connect", default=None, metavar="URL",
                    help="ychg only: run the workload against a running "
                         "front end (http://HOST:PORT)")
    ap.add_argument("--frontend-smoke", action="store_true",
                    help="ychg only: loopback HTTP end-to-end assert "
                         "(bit-identical round trip + 429 on overload)")
    ap.add_argument("--fleet", type=int, default=None, metavar="N",
                    help="ychg only: serve N worker processes behind the "
                         "consistent-hash router (with --listen for the "
                         "router's HOST:PORT)")
    ap.add_argument("--fleet-smoke", action="store_true",
                    help="ychg only: loopback fleet end-to-end assert "
                         "(bit-identity, kill-one-worker rerouting, "
                         "peered-cache hit)")
    ap.add_argument("--buckets", default=None,
                    help="comma-separated bucket sides (default: --res)")
    ap.add_argument("--max-queue-depth", type=int, default=None)
    ap.add_argument("--bucket-queue-depth", type=int, default=None)
    ap.add_argument("--policy", default="block", choices=["block", "shed"],
                    help="overload policy for --listen/--frontend-smoke")
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="enable JAX's persistent compilation cache in DIR "
                         "(restarted workers / resumed bulk jobs reload "
                         "their compiles from disk); plumbed to --fleet "
                         "workers")
    ap.add_argument("--trace-dump", default=None, metavar="PATH",
                    help="dump the flight recorder (recent request traces) "
                         "as Chrome-trace JSON to PATH on shutdown; "
                         "plumbed to --fleet workers (each appends .<pid>)")
    ap.add_argument("--scene-smoke", action="store_true",
                    help="ychg only: scene subsystem end-to-end assert "
                         "(stitch bit-identity, kill->resume "
                         "byte-identity, online/offline agreement)")
    ap.add_argument("--slo-smoke", action="store_true",
                    help="ychg only: traffic-class loopback assert "
                         "(priority preemption, deadline shed with an "
                         "honest Retry-After, tenant-quota 429s)")
    scn = ap.add_argument_group("scene", "knobs for the 'scene' subcommand")
    scn.add_argument("--scene-height", type=int, default=2048)
    scn.add_argument("--scene-width", type=int, default=1024)
    scn.add_argument("--granules", type=int, default=2,
                     help="synthetic manifest size (ignored with --manifest)")
    scn.add_argument("--seed", type=int, default=0,
                     help="first synthetic granule's content seed")
    scn.add_argument("--manifest", default=None, metavar="JSON",
                     help="granule manifest file (repro.scene "
                          "manifest_to_json format) instead of synthetic")
    scn.add_argument("--tile-h", type=int, default=256,
                     help="strip height the scene is windowed into")
    scn.add_argument("--stack", type=int, default=4,
                     help="strips per device batch")
    scn.add_argument("--out", default="scene_out",
                     help="directory for <granule_id>.ychg results")
    scn.add_argument("--ckpt", default="scene_ckpt",
                     help="checkpoint directory (resume state lives here)")
    scn.add_argument("--checkpoint-every", type=int, default=4,
                     help="stacks between mid-granule checkpoints")
    scn.add_argument("--max-stacks", type=int, default=None,
                     help="stop (with a checkpoint) after N stacks")
    args = ap.parse_args()
    if args.trace_dump:
        obs.configure(dump_path=args.trace_dump)
    if args.compile_cache:
        from repro.launch.compilecache import enable_compile_cache

        if enable_compile_cache(args.compile_cache):
            print(f"compile cache: {args.compile_cache}", flush=True)
        else:
            print("compile cache: unsupported by this jax build, "
                  "continuing without", flush=True)
    def smoke(tag, fn):
        """Run a CI smoke leg; on ANY failure dump the flight recorder
        first (with --trace-dump, CI uploads it as a debugging artifact)
        and re-raise so the job still exits nonzero."""
        try:
            fn(args)
        except BaseException:
            path = obs.auto_dump(f"{tag}-failure")
            if path:
                print(f"{tag}: flight recorder dumped to {path}",
                      flush=True)
            raise

    if args.command == "scene":
        scene_run(args)
    elif args.scene_smoke:
        smoke("scene-smoke", scene_smoke)
    elif args.fleet_smoke:
        smoke("fleet-smoke", fleet_smoke)
    elif args.fleet:
        serve_fleet(args)
    elif args.op_smoke:
        smoke("op-smoke", op_smoke)
    elif args.frontend_smoke:
        smoke("frontend-smoke", frontend_smoke)
    elif args.slo_smoke:
        smoke("slo-smoke", slo_smoke)
    elif args.listen:
        serve_listen(args)
    elif args.connect:
        serve_connect(args)
    elif args.workload == "ychg":
        serve_ychg(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
