"""Opt-in JAX persistent compilation cache for serve/bulk entry points.

A restarted fleet worker or a resumed bulk job re-lowers and re-compiles
every rung of its bucket ladder from scratch — pure cold-start tax, since
the shapes are identical across restarts by construction (traffic cannot
change them, only config can). Pointing every process at one on-disk
cache directory makes the second process's compiles disk reads.

Deliberately opt-in (``serve.py --compile-cache DIR`` /
``fleet.worker --compile-cache DIR``): the default CPU interpret-mode
tests must not silently depend on cache state, and the cache directory is
a shared mutable resource the operator should own. Thresholds are set to
"cache everything" because the bucket ladder is a small closed set of
executables — eviction pressure is not a concern, restart latency is.
"""

from __future__ import annotations

import jax


def enable_compile_cache(directory: str) -> bool:
    """Point this process's JAX at a persistent compilation cache.

    Returns True when the cache was enabled, False when this jax build
    has no persistent-cache support (the caller keeps working, just
    without restart-time compile reuse).
    """
    try:
        jax.config.update("jax_compilation_cache_dir", directory)
        # cache every executable regardless of compile time or size: the
        # bucket ladder is a small closed set, and the whole point is that
        # a restart pays zero recompiles
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except AttributeError:       # ancient jax: no persistent cache knobs
        return False
    return True
