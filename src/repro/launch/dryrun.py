import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
host devices let jax.make_mesh build the production meshes; every step
function is jit-lowered with ShapeDtypeStruct inputs (no allocation — a
400B-param tree costs nothing), compiled by XLA SPMD for the real partition
count, and the compiled artifact yields memory_analysis / cost_analysis /
the optimized-HLO collective schedule for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                     # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
      --shape train_4k --mesh single --out results/dryrun
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config, list_archs, shapes_for  # noqa: E402
from repro.configs.base import ModelConfig, ShapeConfig  # noqa: E402
from repro.launch import analytic, roofline  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import (  # noqa: E402
    abstract_cache,
    abstract_params,
    cache_logical_axes,
    param_logical_axes,
)
from repro.models.model import active_params, count_params  # noqa: E402
from repro.optim.adamw import OptState, abstract_opt_state  # noqa: E402
from repro.sharding.logical import make_rules, spec_for, tree_shardings  # noqa: E402
from repro.train.step import (  # noqa: E402
    make_prefill_step,
    make_serve_step,
    make_train_step,
)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind == "train":
        d = {
            "tokens": jax.ShapeDtypeStruct((b, s), tok),
            "labels": jax.ShapeDtypeStruct((b, s), tok),
        }
    elif shape.kind == "prefill":
        d = {"tokens": jax.ShapeDtypeStruct((b, s), tok)}
    else:  # decode: one new token; the cache is a separate argument
        return {"tokens": jax.ShapeDtypeStruct((b, 1), tok)}
    if cfg.frontend != "none" and cfg.frontend_tokens:
        d["frontend_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_tokens, cfg.d_model), jnp.dtype(cfg.activation_dtype)
        )
    return d


def _batch_shardings(cfg, shape, rules, mesh, specs):
    out = {}
    for k, v in specs.items():
        if k in ("tokens", "labels"):
            axes = ("act_batch", "act_seq")
        else:  # frontend_embeds
            axes = ("act_batch", "act_seq", "act_embed")
        out[k] = NamedSharding(mesh, spec_for(axes, rules, mesh, v.shape))
    return out


def _lower(cfg, shape, mesh, rules):
    """jit-lower the cell's step with sharded ShapeDtypeStruct inputs."""
    params_sds = abstract_params(cfg)
    params_shd = tree_shardings(param_logical_axes(cfg), rules, mesh, params_sds)
    specs = input_specs(cfg, shape)
    batch_shd = _batch_shardings(cfg, shape, rules, mesh, specs)

    if shape.kind == "train":
        opt_sds = abstract_opt_state(params_sds)
        opt_shd = OptState(
            step=NamedSharding(mesh, P()), mu=params_shd, nu=params_shd
        )
        step = make_train_step(cfg, mesh, rules)
        return jax.jit(
            step, in_shardings=(params_shd, opt_shd, batch_shd)
        ).lower(params_sds, opt_sds, specs)
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, mesh, rules)
        return jax.jit(step, in_shardings=(params_shd, batch_shd)).lower(
            params_sds, specs
        )
    cache_sds = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    cache_shd = tree_shardings(cache_logical_axes(cfg), rules, mesh, cache_sds)
    step = make_serve_step(cfg, mesh, rules)
    return jax.jit(
        step,
        in_shardings=(
            params_shd, cache_shd, batch_shd["tokens"], NamedSharding(mesh, P()),
        ),
    ).lower(
        params_sds, cache_sds, specs["tokens"], jax.ShapeDtypeStruct((), jnp.int32)
    )


def _probe_metrics(cfg, shape, mesh, rules) -> Dict[str, float]:
    """One unrolled reduced-depth compile -> measured per-partition metrics."""
    compiled = _lower(cfg, shape, mesh, rules).compile()
    cost = roofline.cost_dict(compiled)
    coll = roofline.collective_bytes(compiled.as_text())
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    out.update(coll)
    return out


def _cell_rules(cfg: ModelConfig, shape: ShapeConfig):
    overrides = dict(cfg.decode_rule_overrides) if shape.kind == "decode" else {}
    overrides.update(shape.rule_overrides)
    return make_rules(shape.kind, overrides)


def run_cell(cfg: ModelConfig, shape: ShapeConfig, multi_pod: bool,
             verbose: bool = True, probes: bool = True,
             mesh_shape: tuple | None = None) -> Dict[str, Any]:
    if mesh_shape is not None:  # §Perf exploration; production mesh is (16,16)
        mesh = jax.make_mesh(mesh_shape, ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    rules = _cell_rules(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": "multi" if multi_pod else "single",
        "chips": mesh.size,
        "params": count_params(cfg),
        "active_params": active_params(cfg),
    }
    t0 = time.monotonic()
    try:
        lowered = _lower(cfg, shape, mesh, rules)
        rec["lower_s"] = round(time.monotonic() - t0, 2)

        t1 = time.monotonic()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.monotonic() - t1, 2)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k, 0) or 0)
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
        }
        cost = roofline.cost_dict(compiled)
        rec["cost_raw"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "note": "while-loop bodies counted once (scan-over-layers)",
        }
        rec["collectives_raw"] = roofline.collective_bytes(compiled.as_text())

        # ---- collective extrapolation from unrolled G=1 / G=2 probes (f32:
        # the CPU backend upcasts bf16 dots; float collective bytes are
        # clamped to bf16 width in the parser).
        period = len(cfg.layer_pattern)
        if probes:
            pcfg = cfg.scaled(
                scan_layers=False, param_dtype="float32",
                activation_dtype="float32",
            )
            m1 = _probe_metrics(pcfg.scaled(num_layers=period), shape, mesh, rules)
            m2 = _probe_metrics(pcfg.scaled(num_layers=2 * period), shape, mesh, rules)
            extr = roofline.extrapolate(m1, m2, cfg.num_groups)
            rec["collectives"] = {
                k: v for k, v in extr.items() if not k.startswith("_")
            }
            rec["collective_counts_per_group"] = {
                k: m2.get(k, 0) - m1.get(k, 0)
                for k in m1 if k.startswith("_count_")
            }
            rec["cost_extrapolated"] = {
                "flops": extr.get("flops", 0.0),
                "bytes_accessed": extr.get("bytes_accessed", 0.0),
                "note": "exact for decode cells; undercounts chunked "
                        "attention/ssm inner loops for train/prefill",
            }
            coll_pp = extr.get("total", 0.0)
        else:
            coll_pp = rec["collectives_raw"].get("total", 0.0)
            rec["collectives"] = rec["collectives_raw"]

        # ---- analytic flops/bytes (bf16 widths, implementation-faithful)
        an = analytic.report(cfg, shape)
        rec["analytic"] = an
        rec["roofline"] = roofline.terms(
            flops_global=an["flops"],
            bytes_global=an["hbm_bytes"],
            coll_bytes_per_partition=coll_pp,
            n_partitions=mesh.size,
        )
        mf = roofline.model_flops(cfg, shape)
        rec["model_flops"] = mf
        rec["useful_compute_ratio"] = mf / an["flops"] if an["flops"] else 0.0
        rec["dominant"] = roofline.dominant(rec["roofline"])
        rec["ok"] = True
        if verbose:
            r = rec["roofline"]
            print(
                f"[OK] {cfg.name} x {shape.name} x {rec['mesh']}: "
                f"compile={rec['compile_s']}s compute={r['compute_s']:.4g}s "
                f"mem={r['memory_s']:.4g}s coll={r['collective_s']:.4g}s "
                f"dominant={rec['dominant']} useful={rec['useful_compute_ratio']:.3f}",
                flush=True,
            )
    except Exception as e:  # noqa: BLE001 — record and continue, report at end
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[FAIL] {cfg.name} x {shape.name} x {rec['mesh']}: {rec['error']}",
                  flush=True)
    rec["total_s"] = round(time.monotonic() - t0, 2)
    return rec


def run_ychg_cells(out_dir: str, max_res: int = 2000) -> int:
    """Engine-driven yCHG dry-run: jit-lower + compile the workload's
    batched path per resolution without allocating a single scene.

    Uses the ``engine`` section of ``configs/ychg_modis.py`` (the canonical
    way this workload constructs a yCHG computation) with the jax backend,
    which compiles on any platform; records XLA cost/memory analysis per
    cell. Returns the number of failed cells.
    """
    from repro.configs.ychg_modis import config as ychg_config
    from repro.engine import Engine

    wl = ychg_config()
    engine = Engine(wl.engine.to_engine_config(backend="jax"))
    os.makedirs(out_dir, exist_ok=True)
    n_fail = 0
    for res in [r for r in wl.resolutions if r <= max_res]:
        tag = f"ychg__{wl.name}__b{wl.batch}_res{res}"
        rec: Dict[str, Any] = {
            "workload": wl.name,
            "backend": engine.resolve_backend(),
            "batch": wl.batch,
            "resolution": res,
        }
        t0 = time.monotonic()
        try:
            compiled = engine.lower((wl.batch, res, res)).compile()
            cost = roofline.cost_dict(compiled)
            mem = compiled.memory_analysis()
            rec["cost"] = {
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            }
            rec["memory"] = {
                k: int(getattr(mem, k, 0) or 0)
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes")
            }
            rec["ok"] = True
            print(f"[OK] {tag}: flops={rec['cost']['flops']:.3g} "
                  f"bytes={rec['cost']['bytes_accessed']:.3g}", flush=True)
        except Exception as e:  # noqa: BLE001 — record and continue
            rec["ok"] = False
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-2000:]
            n_fail += 1
            print(f"[FAIL] {tag}: {rec['error']}", flush=True)
        rec["total_s"] = round(time.monotonic() - t0, 2)
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return n_fail


# named config variants for the §Perf hillclimb
VARIANTS = {
    "base": lambda c: c,
    "a2a": lambda c: c.scaled(moe_impl="alltoall"),
    "remat_dots": lambda c: c.scaled(remat="dots"),
    "remat_none": lambda c: c.scaled(remat="none"),
    "chunk4k": lambda c: c.scaled(attn_chunk=4096),
    "chunk2k": lambda c: c.scaled(attn_chunk=2048),
    "a2a_dots": lambda c: c.scaled(moe_impl="alltoall", remat="dots"),
    "wq8": lambda c: c.scaled(weight_quant="int8"),
    # decode weight-stationary 2D expert sharding: experts over "model",
    # expert d_ff over "data" — weights never move; matmul partial sums
    # (activation-sized) psum over "data" instead. See §Perf cell B.
    "dec2d": lambda c: c.scaled(decode_rule_overrides={
        "embed": None, "mlp": "data", "act_mlp": "data"}),
    "dec2d_wq8": lambda c: c.scaled(weight_quant="int8", decode_rule_overrides={
        "embed": None, "mlp": "data", "act_mlp": "data"}),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="base", choices=sorted(VARIANTS))
    ap.add_argument("--mesh-shape", default=None,
                    help="e.g. 32x8 — §Perf exploration on the single-pod chip count")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--ychg", action="store_true",
                    help="dry-run the yCHG engine cells (configs/ychg_modis "
                         "engine section) instead of the LM arch sweep")
    ap.add_argument("--ychg-max-res", type=int, default=2000)
    args = ap.parse_args()
    if args.ychg:
        raise SystemExit(
            1 if run_ychg_cells(args.out, max_res=args.ychg_max_res) else 0
        )
    mesh_shape = (
        tuple(int(v) for v in args.mesh_shape.split("x"))
        if args.mesh_shape else None
    )

    archs = list_archs() if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_fail = 0
    for name in archs:
        cfg = VARIANTS[args.variant](get_config(name))
        for shape in shapes_for(cfg):
            if args.shape != "all" and shape.name != args.shape:
                continue
            for mp in meshes:
                suffix = "" if args.variant == "base" else f"__{args.variant}"
                if args.mesh_shape:
                    suffix += f"__m{args.mesh_shape}"
                tag = f"{name}__{shape.name}__{'multi' if mp else 'single'}{suffix}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            print(f"[skip] {tag} (cached)", flush=True)
                            n_ok += 1
                            continue
                # probes (for the roofline table) only on the single-pod mesh;
                # the multi-pod pass proves the "pod" axis shards.
                rec = run_cell(cfg, shape, mp, probes=not mp, mesh_shape=mesh_shape)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
