"""CLI train driver: --arch <id> [--smoke] trains on this host's devices.

Pod-scale runs use the same step builder with the production mesh (the
multi-pod dry-run proves those lower+compile); on a real cluster this entry
point is launched per host with jax.distributed.initialize.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 50 --batch 8 --seq 64
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.archs import smoke_config
from repro.data.synthetic import TokenDataset, TokenDatasetConfig
from repro.models import count_params, init_params
from repro.optim import adamw_init
from repro.train import TrainLoop, TrainLoopConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config of the same family (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"{cfg.name}: {count_params(cfg) / 1e6:.2f}M params "
          f"({len(jax.devices())} devices)")

    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    ds = TokenDataset(TokenDatasetConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
    ))
    step = jax.jit(make_train_step(
        cfg, peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps, grad_accum=args.grad_accum,
    ))
    loop = TrainLoop(step, TrainLoopConfig(
        total_steps=args.steps, ckpt_dir=args.ckpt, log_every=10,
    ))
    params, opt, start = loop.resume_or_init(params, opt)

    def batches():
        i = start
        while True:
            b = ds.batch(i)
            out = {k: jnp.asarray(v) for k, v in b.items()}
            if cfg.frontend != "none" and cfg.frontend_tokens:
                out["frontend_embeds"] = jnp.zeros(
                    (args.batch, cfg.frontend_tokens, cfg.d_model),
                    jnp.dtype(cfg.activation_dtype),
                )
            yield out
            i += 1

    loop.run(params, opt, batches(), start_step=start)


if __name__ == "__main__":
    main()
