"""Analytic FLOP / HBM-byte model of the *implementation* (not the ideal).

Why analytic: XLA's cost_analysis counts while-loop bodies once, and this
framework deliberately keeps HLO small via scan-over-layers + chunked
attention/SSM scans — so measured flops/bytes undercount by the trip counts.
Collectives are extrapolated from unrolled depth probes (launch/dryrun.py);
flops and HBM traffic come from the formulas here, which model what the code
actually lowers, including its inefficiencies:

  * chunked attention computes the FULL block rectangle with a causal mask
    (2x the causal half) — counted as implemented;
  * remat="full" recomputes the forward in backward: train multiplier
    4x fwd flops (fwd + recompute + 2x bwd) vs 3x without;
  * MoE capacity buffers compute cap*E token slots (cf x overprovision);
  * f32 where the implementation uses f32 (ssm/rwkv states, logits softmax).

Validated against cost_analysis on loop-free lowerings in
tests/test_analytic.py (smoke configs, scan_layers=False, no chunking).
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import LayerSpec, ModelConfig, ShapeConfig

WB = 2      # bf16 param/activation width on the TPU target
WF = 4      # f32 width


def _attn_flops_fwd(cfg, s: int, cache_len: int | None = None) -> float:
    """Per batch element. cache_len set => decode (s=1 new token)."""
    d, h, g, kd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    proj = 2 * s * (d * h * kd + 2 * d * g * kd + h * kd * d)
    kv_len = cache_len if cache_len is not None else s
    # implementation computes the full rectangle (causal mask, not skipped)
    scores = 4 * s * kv_len * h * kd
    return proj + scores


def _mla_flops_fwd(cfg, s: int, cache_len: int | None = None) -> float:
    d, h = cfg.d_model, cfg.num_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dqk = dn + dr
    down = 2 * s * (d * rq + d * rkv + d * dr)
    q_up = 2 * s * rq * h * dqk
    if cache_len is None:  # train/prefill: explicit k/v expansion
        kv_up = 2 * s * (rkv * h * dn + rkv * h * dv)
        scores = 2 * s * s * h * (dqk + dv)
        out = 2 * s * h * dv * d
        return down + q_up + kv_up + scores + out
    # absorbed decode: q absorb + scores on compressed cache + out absorb
    absorb = 2 * s * h * dn * rkv
    scores = 2 * s * cache_len * h * (rkv + dr) + 2 * s * cache_len * h * rkv
    out = 2 * s * h * rkv * dv + 2 * s * h * dv * d
    return down + q_up + absorb + scores + out


def _mamba_flops_fwd(cfg, s: int) -> float:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state_dim
    r = cfg.ssm_dt_rank
    proj = 2 * s * (d * 2 * di + di * r + r * di + 2 * di * n + di * d)
    conv = 2 * s * cfg.ssm_conv_dim * di
    scan = s * di * n * 10          # a=exp, a*h+b, C·h etc. (elementwise+reduce)
    return proj + conv + scan


def _rwkv_flops_fwd(cfg, s: int) -> float:
    d = cfg.d_model
    lr, dr = cfg.rwkv_mix_lora, cfg.rwkv_decay_lora
    kd = cfg.rwkv_head_dim
    h = d // kd
    loras = 2 * s * (d * 5 * lr + 5 * lr * d + d * dr + dr * d)
    mats = 2 * s * 5 * d * d       # r,k,v,g,o
    recur = s * h * kd * kd * 6    # kv outer, r·S, decay*S+kv
    return loras + mats + recur


def _channel_flops_fwd(cfg, spec: LayerSpec, s: int, batch: int) -> float:
    """Per batch element (MoE capacity depends on global tokens t = b*s)."""
    d, f = cfg.d_model, cfg.d_ff
    if spec.channel == "mlp":
        mats = 3 if cfg.mlp_act == "swiglu" else 2
        return 2 * s * mats * d * f
    if spec.channel == "moe":
        e, k = cfg.num_experts, cfg.experts_per_token
        t = batch * s
        cap = max(-(-int(cfg.moe_capacity_factor * t * k) // e), 8)
        slots_global = e * cap  # buffer compute, incl. cf overprovision
        routed = 2 * slots_global * 3 * d * f / batch
        router = 2 * s * d * e
        shared = 2 * s * 3 * d * f if cfg.name.startswith("llama4") else 0
        return routed + router + shared
    if spec.channel == "rwkv_ffn":
        return 2 * s * (d * f + f * d + d * d)
    raise ValueError(spec.channel)


def _mixer_flops_fwd(cfg, spec: LayerSpec, s: int, cache_len=None) -> float:
    if spec.mixer == "attn":
        return _attn_flops_fwd(cfg, s, cache_len)
    if spec.mixer == "mla":
        return _mla_flops_fwd(cfg, s, cache_len)
    if spec.mixer == "mamba":
        return _mamba_flops_fwd(cfg, s)
    if spec.mixer == "rwkv":
        return _rwkv_flops_fwd(cfg, s)
    raise ValueError(spec.mixer)


def _train_multiplier(cfg) -> float:
    return {"full": 4.0, "dots": 3.1, "none": 3.0}[cfg.remat]


def flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global FLOPs for one step of this cell, as implemented."""
    b, s = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    s_new = 1 if decode else s
    cache_len = s if decode else None
    per_layer = 0.0
    for spec in cfg.layer_pattern:
        per_layer += _mixer_flops_fwd(cfg, spec, s_new, cache_len)
        per_layer += _channel_flops_fwd(cfg, spec, s_new, b)
    body = per_layer * cfg.num_groups
    head = 2 * s_new * cfg.d_model * cfg.vocab_size  # lm_head matmul
    fwd = b * (body + head)
    if shape.kind == "train":
        return fwd * _train_multiplier(cfg)
    return fwd


def hbm_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global HBM traffic (bytes) for one step, coarse tensor-stream model:
    every matmul streams inputs + weights + output; chunked attention streams
    k/v per q-block (flash model: S^2/c growth); states in f32."""
    b, s = shape.global_batch, shape.seq_len
    decode = shape.kind == "decode"
    s_new = 1 if decode else s
    t = b * s_new  # global tokens processed
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    from repro.models import count_params

    p_total = count_params(cfg)

    # --- weights: streamed once per pass; train: fwd + remat + bwd + opt
    if shape.kind == "train":
        w_bytes = p_total * WB * (4 if cfg.remat == "full" else 3)
        w_bytes += p_total * (2 * WF + 2 * WF + WB)  # adam m/v rw + p write
    else:
        w_width = 1 if (cfg.weight_quant == "int8" and decode) else WB
        w_bytes = p_total * w_width

    # --- activations: per token per layer, ~10 d-sized + mlp f-sized streams
    act_per_tok_layer = (10 * d + 4 * f) * WB
    for spec in cfg.layer_pattern:
        if spec.mixer in ("mamba",):
            act_per_tok_layer += 6 * cfg.ssm_expand * d * WB / len(cfg.layer_pattern)
    act = t * cfg.num_layers * act_per_tok_layer

    # --- attention kv streaming (flash model); MLA streams the COMPRESSED
    # latent cache (kv_lora + rope) — that is the mechanism's entire point.
    for mx, count in (("attn", sum(1 for x in cfg.layer_pattern if x.mixer == "attn")),
                      ("mla", sum(1 for x in cfg.layer_pattern if x.mixer == "mla"))):
        n_layers = count * cfg.num_groups
        if not n_layers:
            continue
        if mx == "mla":
            per_tok = cfg.kv_lora_rank + cfg.qk_rope_dim
        else:
            per_tok = cfg.num_kv_heads * cfg.head_dim * 2  # k + v
        if decode:
            kv_stream = b * s * per_tok * WB                 # read whole cache
        else:
            c = min(cfg.attn_chunk, s)
            n_q = max(s // c, 1)
            if mx == "mla":  # prefill expands k/v per q-block from the latent
                per_tok = cfg.num_heads * (cfg.qk_nope_dim + cfg.qk_rope_dim
                                           + cfg.v_head_dim)
            kv_stream = b * n_q * s * per_tok * WB
        act += n_layers * kv_stream * (3 if shape.kind == "train" else 1)

    # --- logits + softmax (f32)
    logits = t * v * (WB + 2 * WF if shape.kind == "train" else WB)

    if shape.kind == "train":
        act *= 3.0  # fwd + bwd streams + remat re-streams (coarse)
    return w_bytes + act + logits


def report(cfg, shape) -> Dict[str, float]:
    return {"flops": flops(cfg, shape), "hbm_bytes": hbm_bytes(cfg, shape)}
