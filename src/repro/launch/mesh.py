"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).

  single-pod: (16, 16)    axes ("data", "model")     = 256 chips (one v5e pod)
  multi-pod:  (2, 16, 16) axes ("pod", "data", "model") = 512 chips

"model" maps to the TP/EP/SP group (intra-pod ICI ring), "data" to the DP/
FSDP group, "pod" to pure DP across the DCN link between pods.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
