"""Roofline accounting from the compiled dry-run artifact.

TPU v5e hardware model (per chip):
  peak bf16 compute   197 TFLOP/s
  HBM bandwidth       819 GB/s
  ICI                 ~50 GB/s per link (we charge ONE link — conservative;
                      v5e has 4 usable links, so a perfect schedule could be
                      ~4x better; stated in EXPERIMENTS.md)

Collective bytes are parsed from the *optimized* HLO of the compiled module:
operands are not typed inline in current HLO dumps, so per-op ICI traffic is
derived from the RESULT shape with standard ring-algorithm multipliers and
the parsed replica-group size g:

  all-gather          result x (g-1)/g        (per-device recv bytes)
  all-reduce          result x 2(g-1)/g       (reduce-scatter + all-gather)
  reduce-scatter      result x (g-1)          (operand = result x g)
  all-to-all          result x (g-1)/g
  collective-permute  result x 1              (one hop send/recv)

cost_analysis() counts while-loop bodies ONCE (not x trip count), so the
dry-run measures collectives with two unrolled reduced-depth probe compiles
(G=1, G=2 layer groups) and extrapolates: per_group = m(2) - m(1);
total(G) = m(1) - per_group + G*per_group. Probes compile in f32 (XLA CPU
upcasts bf16 dots, which would inflate weight-collective bytes); float
collective results are therefore counted at bf16 width (ints at native
width) to model the TPU execution. FLOPs/HBM bytes for train/prefill cells
come from the analytic model in launch/analytic.py (inner attention/ssm
chunk loops are also while loops, invisible to cost_analysis); decode cells
have no inner loops, so extrapolated measurements are used and the analytic
model is cross-checked against them.
"""

from __future__ import annotations

import re
from typing import Any, Dict

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip


def cost_dict(compiled) -> Dict[str, Any]:
    """``compiled.cost_analysis()`` compat: newer jax returns a dict, older
    a [per-device dict] list. Single shared shim — dryrun and the tests
    must parse the artifact identically."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    return cost
ICI_BW = 50e9           # bytes/s / link, 1 link charged

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}
_FLOAT_TYPES = {"f16", "bf16", "f32", "f64"}

_COLL_TYPES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# `%name = TYPE[dims]{layout} op-name(...`  (also tuple-result async starts)
_INSTR_RE = re.compile(
    r"=\s*(?:\(?)\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str, *, clamp_float_to_bf16: bool = True,
                     default_group: int = 16) -> Dict[str, float]:
    """Per-partition ICI traffic (bytes) by collective type, + op counts."""
    out: Dict[str, float] = {t: 0.0 for t in _COLL_TYPES}
    counts: Dict[str, int] = {t: 0 for t in _COLL_TYPES}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        dtype, dims, op, _start = m.group(1), m.group(2), m.group(3), m.group(4)
        if dtype not in _DTYPE_BYTES:
            continue
        if f"{op}-done" in line:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        width = _DTYPE_BYTES[dtype]
        if clamp_float_to_bf16 and dtype in _FLOAT_TYPES:
            width = min(width, 2)
        bytes_result = n * width
        g = _group_size(line, default_group)
        if op == "all-gather":
            traffic = bytes_result * (g - 1) / g
        elif op == "all-reduce":
            traffic = bytes_result * 2 * (g - 1) / g
        elif op == "reduce-scatter":
            traffic = bytes_result * (g - 1)
        elif op == "all-to-all":
            traffic = bytes_result * (g - 1) / g
        else:  # collective-permute
            traffic = bytes_result
        out[op] += traffic
        counts[op] += 1
    out["total"] = sum(out[t] for t in _COLL_TYPES)
    for t in _COLL_TYPES:
        out["_count_" + t] = counts[t]
    return out


def extrapolate(m1: Dict[str, float], m2: Dict[str, float], g: int
                ) -> Dict[str, float]:
    """Linear trip-count correction from G=1 / G=2 unrolled probes."""
    out = {}
    for k in m1:
        per_group = m2.get(k, 0.0) - m1.get(k, 0.0)
        base = m1.get(k, 0.0) - per_group
        out[k] = base + g * per_group
    return out


def terms(
    *,
    flops_global: float,
    bytes_global: float,
    coll_bytes_per_partition: float,
    n_partitions: int,
) -> Dict[str, float]:
    chips = n_partitions
    cg = coll_bytes_per_partition * n_partitions
    return {
        "flops_global": flops_global,
        "bytes_global": bytes_global,
        "coll_bytes_global": cg,
        "compute_s": flops_global / (chips * PEAK_FLOPS),
        "memory_s": bytes_global / (chips * HBM_BW),
        "collective_s": cg / (chips * ICI_BW),
    }


def model_flops(cfg, shape) -> float:
    """6*N_eff*D (train) / 2*N_eff*D (prefill/decode): the useful-work floor.

    N_eff = active params minus the embedding lookup table when untied
    (lookup is a gather, not a matmul; a tied table doubles as the lm_head
    matmul so it stays).
    """
    from repro.models import active_params

    n = active_params(cfg)
    if not cfg.tie_embeddings:
        n -= cfg.vocab_size * cfg.d_model
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token


def dominant(t: Dict[str, float]) -> str:
    vals = {k: t[k] for k in ("compute_s", "memory_s", "collective_s")}
    return max(vals, key=vals.get)
