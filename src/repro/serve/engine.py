"""Batched serving engine: prefill + decode with a fixed-slot KV cache.

Static-batch engine (all slots share a step index — the dry-run's
decode_32k/long_500k cells lower exactly this step). Requests shorter than
the batch's prompt window are left-padded so every slot decodes from the
same cur_index; sampled tokens for already-finished slots are masked. A
production continuous-batching scheduler slots in above this engine — its
step function is unchanged, which is the part that must compile/shard.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import forward, init_cache
from repro.models.layers import Sharder
from repro.train.step import make_serve_step


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray        # (B, max_new)
    n_generated: int


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_len: int, mesh=None, rules=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.shd = Sharder(mesh, rules)
        self._serve = jax.jit(make_serve_step(cfg, mesh, rules))
        self._prefill = jax.jit(self._prefill_impl)

    def _prefill_impl(self, params, tokens):
        logits, _, cache = forward(
            params, self.cfg, tokens, self.shd, return_cache=True
        )
        return logits[:, -1, :], cache

    def _pad_cache(self, cache, cur_len: int):
        """Grow prefill cache entries along the kv-seq axis to max_len."""

        def pad(path, leaf):
            name = jax.tree_util.keystr(path)
            if any(k in name for k in ("'k'", "'v'", "'ckv'", "'k_rope'")):
                pads = [(0, 0)] * leaf.ndim
                pads[2] = (0, self.max_len - leaf.shape[2])  # (G,B,S,...)
                return jnp.pad(leaf, pads)
            return leaf

        return jax.tree_util.tree_map_with_path(pad, cache)

    def generate(
        self,
        prompts: np.ndarray,          # (B, prompt_len) int32
        max_new: int,
        temperature: float = 0.0,
        seed: int = 0,
        eos_id: Optional[int] = None,
    ) -> GenerationResult:
        b, plen = prompts.shape
        assert plen + max_new <= self.max_len
        logits, cache = self._prefill(self.params, jnp.asarray(prompts))
        cache = self._pad_cache(cache, plen)
        key = jax.random.PRNGKey(seed)
        out = []
        done = np.zeros(b, bool)
        tok = self._sample(logits, temperature, key)
        for i in range(max_new):
            out.append(np.asarray(tok))
            if eos_id is not None:
                done |= out[-1][:, 0] == eos_id
                if done.all():
                    break
            logits, cache = self._serve(
                self.params, cache, tok, jnp.int32(plen + i)
            )
            key, sub = jax.random.split(key)
            tok = self._sample(logits[:, None, :] if logits.ndim == 2 else logits,
                               temperature, sub)
        tokens = np.concatenate(out, axis=1) if out else np.zeros((b, 0), np.int32)
        return GenerationResult(tokens=tokens, n_generated=len(out))

    @staticmethod
    def _sample(logits, temperature: float, key):
        if logits.ndim == 3:
            logits = logits[:, -1, :]
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1)[
            :, None
        ].astype(jnp.int32)
