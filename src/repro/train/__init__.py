from repro.train.step import make_train_step, make_prefill_step
from repro.train.loop import TrainLoop, TrainLoopConfig

__all__ = ["make_train_step", "make_prefill_step", "TrainLoop", "TrainLoopConfig"]
