"""Fault-tolerant training loop.

Production behaviours implemented (and exercised by tests on CPU):

  * checkpoint cadence + atomic save (see checkpoint/) and auto-resume from
    the newest complete step, so a killed job restarts losslessly — data is
    a pure function of (seed, step), so the token stream resumes exactly;
  * step deadline (straggler mitigation): a step exceeding ``step_timeout_s``
    is recorded and — after ``max_step_retries`` consecutive budget misses —
    the loop checkpoints and exits nonzero so the scheduler can reschedule
    (on TPU pods the usual cause is a degraded host; self-eviction beats
    hanging the whole ring);
  * NaN handling: skip-and-count (grad spikes on bad batches); the step is
    retried with the next batch rather than poisoning params;
  * elastic restart: save/restore re-shards across different meshes (see
    Checkpointer.restore(shardings=...)).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep: int = 3
    log_every: int = 10
    step_timeout_s: float = math.inf
    max_step_retries: int = 3
    async_ckpt: bool = False


class TrainLoop:
    def __init__(
        self,
        step_fn: Callable,
        loop_cfg: TrainLoopConfig,
        log_fn: Callable[[int, Dict[str, float]], None] | None = None,
    ):
        self.step_fn = step_fn
        self.cfg = loop_cfg
        self.log_fn = log_fn or (lambda s, m: print(
            f"step {s}: " + " ".join(f"{k}={v:.4g}" for k, v in m.items())
        ))
        self.ckpt = (
            Checkpointer(loop_cfg.ckpt_dir, keep=loop_cfg.keep,
                         async_save=loop_cfg.async_ckpt)
            if loop_cfg.ckpt_dir else None
        )
        self.nan_skips = 0
        self.deadline_misses = 0

    def resume_or_init(self, params, opt_state):
        """If a complete checkpoint exists, restore; else return inputs."""
        start = 0
        if self.ckpt is not None:
            latest = self.ckpt.latest_step()
            if latest is not None:
                tree = self.ckpt.restore(latest, {"params": params, "opt": opt_state})
                params, opt_state = tree["params"], tree["opt"]
                start = latest
        return params, opt_state, start

    def run(self, params, opt_state, batches: Iterator[Dict[str, np.ndarray]],
            start_step: int = 0):
        cfg = self.cfg
        step = start_step
        consecutive_misses = 0
        for batch in batches:
            if step >= cfg.total_steps:
                break
            t0 = time.monotonic()
            new_params, new_opt, metrics = self.step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            if not math.isfinite(loss):
                self.nan_skips += 1  # skip the update, keep old state
                step += 1
                continue
            params, opt_state = new_params, new_opt
            if dt > cfg.step_timeout_s:
                self.deadline_misses += 1
                consecutive_misses += 1
                if consecutive_misses > cfg.max_step_retries:
                    if self.ckpt:
                        self.ckpt.save(step + 1, {"params": params, "opt": opt_state})
                        self.ckpt.wait()
                    raise TimeoutError(
                        f"{consecutive_misses} consecutive steps over "
                        f"{cfg.step_timeout_s}s deadline — self-evicting for reschedule"
                    )
            else:
                consecutive_misses = 0
            step += 1
            if step % cfg.log_every == 0:
                self.log_fn(step, {k: float(v) for k, v in metrics.items()})
            if self.ckpt and step % cfg.ckpt_every == 0:
                self.ckpt.save(step, {"params": params, "opt": opt_state})
        if self.ckpt:
            self.ckpt.save(step, {"params": params, "opt": opt_state})
            self.ckpt.wait()
        return params, opt_state, step
