"""Step builders: train_step (grads + AdamW update, optional microbatch
accumulation) and prefill_step (forward + cache materialisation).

Gradient accumulation is a ``lax.scan`` over microbatches; the single
parameter update at the end means XLA sees exactly one gradient all-reduce
per step, which its latency-hiding scheduler overlaps with the last
microbatch's backward pass on TPU (the dry-run verifies the collective
count/schedule, not the overlap — CPU has no LHS).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import loss_fn, forward
from repro.models.layers import Sharder
from repro.optim import adamw_update, clip_by_global_norm, warmup_cosine


def make_train_step(
    cfg: ModelConfig,
    mesh=None,
    rules=None,
    *,
    grad_accum: int = 1,
    peak_lr: float = 3e-4,
    warmup_steps: int = 100,
    total_steps: int = 10_000,
    max_grad_norm: float = 1.0,
    weight_decay: float = 0.1,
) -> Callable:
    shd = Sharder(mesh, rules)

    def compute_loss(params, tokens, labels, frontend_embeds):
        return loss_fn(params, cfg, tokens, labels, shd, frontend_embeds)

    grad_fn = jax.value_and_grad(compute_loss, has_aux=True)

    def train_step(params, opt_state, batch: Dict[str, Any]):
        tokens, labels = batch["tokens"], batch["labels"]
        fe = batch.get("frontend_embeds")

        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, tokens, labels, fe)
        else:
            b = tokens.shape[0]
            assert b % grad_accum == 0
            mb = b // grad_accum

            def micro(carry, xs):
                g_acc, l_acc = carry
                t, y = xs
                (l, _), g = grad_fn(params, t, y, None)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            xs = (
                tokens.reshape(grad_accum, mb, -1),
                labels.reshape(grad_accum, mb, -1),
            )
            (grads, loss), _ = jax.lax.scan(micro, (g0, jnp.float32(0.0)), xs)
            loss = loss / grad_accum
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            metrics = {"ce": loss, "aux": jnp.float32(0.0), "ntokens": jnp.int32(0)}

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = warmup_cosine(
            opt_state.step, peak_lr=peak_lr, warmup_steps=warmup_steps,
            total_steps=total_steps,
        )
        params, opt_state = adamw_update(
            params, grads, opt_state, lr=lr, weight_decay=weight_decay
        )
        out_metrics = {
            "loss": loss,
            "ce": metrics["ce"],
            "aux": metrics["aux"],
            "grad_norm": gnorm,
            "lr": lr,
        }
        return params, opt_state, out_metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh=None, rules=None) -> Callable:
    """Returns prefill(params, batch) -> (last-position logits, cache)."""
    shd = Sharder(mesh, rules)

    def prefill_step(params, batch: Dict[str, Any]):
        logits, _, cache = forward(
            params, cfg, batch["tokens"], shd,
            batch.get("frontend_embeds"), return_cache=True,
        )
        return logits[:, -1, :], cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, mesh=None, rules=None) -> Callable:
    """Returns serve(params, cache, tokens, cur_index) -> (logits, cache')."""
    from repro.models import decode_step

    shd = Sharder(mesh, rules)

    def serve_step(params, cache, tokens, cur_index):
        return decode_step(params, cfg, cache, tokens, cur_index, shd)

    return serve_step
