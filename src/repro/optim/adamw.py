"""AdamW + global-norm clipping + warmup-cosine schedule, pure pytree JAX.

No optax on this box; the implementation follows Loshchilov & Hutter
(decoupled weight decay) with bias-corrected moments. Moments are kept in
float32 regardless of param dtype (mixed-precision training keeps bf16
params + f32 state; the sharding rules shard moments exactly like their
params, so ZeRO-style 2-D sharded optimizer state falls out for free).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    step: Array     # () int32
    mu: Any         # f32 pytree like params
    nu: Any         # f32 pytree like params


def adamw_init(params: Any) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    zeros2 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros2)


def abstract_opt_state(params: Any) -> OptState:
    z = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params
    )
    z2 = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params
    )
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=z, nu=z2)


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), gn


def warmup_cosine(step: Array, *, peak_lr: float, warmup_steps: int,
                  total_steps: int, min_ratio: float = 0.1) -> Array:
    step = step.astype(jnp.float32)
    warm = peak_lr * step / max(warmup_steps, 1)
    frac = jnp.clip(
        (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
    )
    cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup_steps, warm, cos)


def adamw_update(
    params: Any,
    grads: Any,
    state: OptState,
    *,
    lr: Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, OptState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1**t
    c2 = 1.0 - b2**t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mh = m / c1
        vh = v / c2
        # decoupled weight decay on matrices only would need shape dispatch;
        # apply uniformly (norm scales are near 1, decay is mild) — standard
        # for this scale of reproduction.
        delta = lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32))
        return (p.astype(jnp.float32) - delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, mu=new_m, nu=new_v)
