from repro.optim.adamw import (
    OptState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    warmup_cosine,
)

__all__ = [
    "OptState",
    "adamw_init",
    "adamw_update",
    "clip_by_global_norm",
    "warmup_cosine",
]
