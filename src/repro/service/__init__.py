"""`repro.service` — the batching, caching multi-op image service.

`repro.engine.Engine` answers "how do I run operator X on this array";
this package answers "how do I serve it": single-mask requests (for any
registered op — yCHG first, plus ``ccl``, ``denoise``, and ordered
``submit_pipeline`` chains) coalesce through a micro-batching scheduler
into ``(op, side, dtype)``-bucketed stacks padded to a power-of-two
**sub-batch ladder** (a lone request pays for one image, not
``max_batch``; compiled shapes stay bounded at ``len(bucket_sides) *
(log2(max_batch) + 1)`` per (op, dtype)), behind a content-addressed LRU
result cache whose keys carry the op (a hit never invokes a backend, and
two ops never alias on one mask), over a double-buffered dispatch loop
(ingest of bucket n+1 overlaps device compute of bucket n).
``max_queue_depth`` + ``overload_policy`` add admission control: past the
bound, ``submit`` blocks (backpressure) or raises
:class:`ServiceOverloaded` (shed), with shed/blocked counters in
:class:`ServiceMetrics`. Admission and dispatch are bucket-FAIR:
``bucket_queue_depth`` bounds each ``(op, side, dtype)`` bucket separately
(per-bucket shed counters in ``ServiceMetrics.shed_by_bucket``) and ready
buckets flush deficit-round-robin with per-op quanta
(``ServiceConfig.op_max_batch``), so one hot resolution — or one hot
operator — can neither starve nor shed everyone else's traffic. The
network edge over this package lives in :mod:`repro.frontend`.

    from repro.service import ServiceConfig, YCHGService

    with YCHGService(config=ServiceConfig(bucket_sides=(256,))) as svc:
        fut = svc.submit(mask)          # Future[YCHGResult], non-blocking
        result = fut.result()           # ready, device-resident, B=1 view
        result2 = svc.analyze(mask)     # cache hit: same object back
        print(svc.metrics())            # queue depth, p50/p95, hit rate, ...

Results are bit-identical to ``engine.analyze(mask)`` for every request —
through padding, bucketing, arrival order, duplicates, and caching
(``tests/test_service.py`` holds the whole pipeline to that bar; the
scheduler's policy logic is additionally unit-tested engine-free in
``tests/test_scheduler.py``).
"""

from repro.service.batching import (
    crop_for,
    crop_result,
    pad_stack,
    pick_bucket_side,
)
from repro.service.cache import ResultCache, make_key
from repro.service.metrics import MetricsRecorder, ServiceMetrics
from repro.service.scheduler import (
    DeadlineExceeded,
    DrainRate,
    Scheduler,
    SchedulerConfig,
    ServiceOverloaded,
    TenantQuotaExceeded,
    TokenBucket,
    pick_sub_batch,
    sub_batch_ladder,
)
from repro.service.service import Service, ServiceConfig, YCHGService

__all__ = [
    "DeadlineExceeded",
    "DrainRate",
    "MetricsRecorder",
    "ResultCache",
    "Scheduler",
    "SchedulerConfig",
    "TenantQuotaExceeded",
    "TokenBucket",
    "Service",
    "ServiceConfig",
    "ServiceMetrics",
    "ServiceOverloaded",
    "YCHGService",
    "crop_for",
    "crop_result",
    "make_key",
    "pad_stack",
    "pick_bucket_side",
    "pick_sub_batch",
    "sub_batch_ladder",
]
