"""`repro.service` — the batching, caching yCHG ROI service.

`repro.engine.YCHGEngine` answers "how do I run the two-step algorithm on
this array"; this package answers "how do I serve it": single-mask requests
coalesce through a micro-batching scheduler into shape-bucketed, pad-to-
bucket `(max_batch, side, side)` stacks (bounded compiled shapes), behind a
content-addressed LRU result cache (a hit never invokes a backend), over a
double-buffered dispatch loop (ingest of bucket n+1 overlaps device compute
of bucket n).

    from repro.service import ServiceConfig, YCHGService

    with YCHGService(config=ServiceConfig(bucket_sides=(256,))) as svc:
        fut = svc.submit(mask)          # Future[YCHGResult], non-blocking
        result = fut.result()           # ready, device-resident, B=1 view
        result2 = svc.analyze(mask)     # cache hit: same object back
        print(svc.metrics())            # queue depth, p50/p95, hit rate, ...

Results are bit-identical to ``engine.analyze(mask)`` for every request —
through padding, bucketing, arrival order, duplicates, and caching
(``tests/test_service.py`` holds the whole pipeline to that bar).
"""

from repro.service.batching import crop_result, pad_stack, pick_bucket_side
from repro.service.cache import ResultCache, make_key
from repro.service.metrics import MetricsRecorder, ServiceMetrics
from repro.service.service import ServiceConfig, YCHGService

__all__ = [
    "MetricsRecorder",
    "ResultCache",
    "ServiceConfig",
    "ServiceMetrics",
    "YCHGService",
    "crop_result",
    "make_key",
    "pad_stack",
    "pick_bucket_side",
]
