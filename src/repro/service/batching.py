"""Shape bucketing: bounded compiled shapes, bit-exact crop-back.

The service never dispatches a request's native shape. Every mask is padded
(bottom/right, with zeros) into a square bucket from a fixed ladder, and
every batch is padded (blank trailing images) to the power-of-two sub-batch
rung covering its occupancy (``scheduler.pick_sub_batch``, capped at
``max_batch``), so the set of shapes the backend ever compiles for is
``{(b, side, side) : b in sub_batch_ladder(max_batch), (side, dtype) seen}``
— traffic cannot trigger recompiles, only config can.

Why crop-back is bit-exact for yCHG (this is the invariant the parity
suite pins; ccl/denoise make their own padding-inertness arguments in
their kernel modules and get (H, W) crops below):
every yCHG output is per-*column* — ``runs[j]`` counts rising edges down
column j, and the step-2 signals at column j depend only on columns j-1 and
j. Zero rows appended below a column add no rising edge, so padded rows
change nothing; zero columns appended to the right leave every original
column's runs/births/deaths/transitions untouched (the first pad column may
itself register a death, but it is cropped away). Cropping the per-column
arrays back to the request's width and recomputing the two reductions over
the cropped arrays therefore reproduces ``engine.analyze(mask)`` exactly,
dtypes included.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.engine.engine import YCHGResult, _from_summary
from repro.engine.ops import CCLResult, DenoiseResult, split_pipeline_key
from repro.core.ychg import YCHGSummary

# A bucket is (op key, side, dtype name): masks only stack with their own
# dtype AND their own operator (a pipeline spec like "denoise+ychg" is its
# own op key), so each (op, dtype) seen in traffic gets its own ladder of
# sides.
Bucket = Tuple[str, int, str]


def pick_bucket_side(shape: Tuple[int, int], sides: Sequence[int]) -> int:
    """Smallest ladder side that holds an (H, W) mask; raises past the top."""
    h, w = shape
    need = max(h, w)
    for side in sides:
        if side >= need:
            return side
    raise ValueError(
        f"mask {shape} exceeds the largest service bucket "
        f"({sides[-1]}x{sides[-1]}); configure larger bucket_sides"
    )


def pad_stack(masks: Sequence[np.ndarray], side: int, batch: int,
              dtype: np.dtype) -> np.ndarray:
    """Stack masks into a zero-padded (batch, side, side) host array."""
    stack = np.zeros((batch, side, side), dtype)
    for i, m in enumerate(masks):
        stack[i, : m.shape[0], : m.shape[1]] = m
    return stack


@functools.partial(jax.jit, static_argnames=("width",))
def _crop_row(runs, cut_vertices, transitions, births, deaths, row, *,
              width: int):
    """One fused device call for the whole per-request fan-out.

    Fan-out is the service's per-request hot path: done as eager jnp ops it
    costs ~9 dispatches per request, an order of magnitude more wall time
    than the batch computation itself. Here it is a single jit'd call whose
    compile cache is deliberately small: ``row`` is a *traced* scalar (any
    row reuses one executable) and only ``width`` is static — one compile
    per (bucket shape, request width), i.e. bounded by the width variety of
    the traffic, not its volume.
    """
    sl = lambda a: jax.lax.dynamic_slice_in_dim(a, row, 1, axis=0)[:, :width]
    births_c = sl(births)
    transitions_c = sl(transitions)
    return (
        sl(runs),
        sl(cut_vertices),
        transitions_c,
        births_c,
        sl(deaths),
        jnp.sum(births_c, axis=-1),
        jnp.sum(transitions_c, axis=-1, dtype=jnp.int32),
    )


def crop_result(batched: YCHGResult, row: int, width: int) -> YCHGResult:
    """Request ``row`` of a bucket result, cropped to its native width.

    Returns the B=1 ``batched=False`` view ``engine.analyze`` would have
    produced for the unpadded mask. The per-column arrays are plain slices;
    the two scalar reductions are recomputed over the cropped columns with
    the same dtypes ``core.ychg.analyze`` uses (births already int32, the
    transition count summed as int32).
    """
    out = _crop_row(batched.runs, batched.cut_vertices, batched.transitions,
                    batched.births, batched.deaths, row, width=width)
    return _from_summary(YCHGSummary(*out), batched=False)


# ------------------------------------------------------- per-op crop-back
#
# yCHG's outputs are per-column, so its crop only needs the native width.
# ccl/denoise return full (H, W) canvases, so their crops slice both axes.
# Both are pad-invariant by construction (kernels.ccl / kernels.denoise
# document the argument), so slicing IS the exact single-image answer —
# for ccl that includes n_components, because zero padding never starts a
# component and canonical re-ranking follows native row-major order.


@functools.partial(jax.jit, static_argnames=("h", "w"))
def _crop_ccl(labels, n_components, row, *, h: int, w: int):
    lab = jax.lax.dynamic_slice_in_dim(labels, row, 1, axis=0)[:, :h, :w]
    n = jax.lax.dynamic_slice_in_dim(n_components, row, 1, axis=0)
    return lab, n


@functools.partial(jax.jit, static_argnames=("h", "w"))
def _crop_image(image, row, *, h: int, w: int):
    return jax.lax.dynamic_slice_in_dim(image, row, 1, axis=0)[:, :h, :w]


def _crop_ychg_op(batched: YCHGResult, row: int,
                  shape: Tuple[int, int]) -> YCHGResult:
    return crop_result(batched, row, shape[1])


def _crop_ccl_op(batched: CCLResult, row: int,
                 shape: Tuple[int, int]) -> CCLResult:
    lab, n = _crop_ccl(batched.labels, batched.n_components, row,
                       h=shape[0], w=shape[1])
    return CCLResult(labels=lab, n_components=n, batched=False)


def _crop_denoise_op(batched: DenoiseResult, row: int,
                     shape: Tuple[int, int]) -> DenoiseResult:
    img = _crop_image(batched.image, row, h=shape[0], w=shape[1])
    return DenoiseResult(image=img, batched=False)


_CROPS = {
    "ychg": _crop_ychg_op,
    "ccl": _crop_ccl_op,
    "denoise": _crop_denoise_op,
}


def crop_for(op_key: str):
    """The crop-back for an op (or pipeline key — its terminal stage).

    Returns ``(batched_result, row, (h, w)) -> B=1 unbatched result``.
    Raises ``KeyError`` for an op without a registered crop — adding one
    is part of the new-op checklist in ``docs/ops.md``.
    """
    return _CROPS[split_pipeline_key(op_key)[-1]]
