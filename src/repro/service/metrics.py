"""Service observability: a thread-safe recorder + a frozen snapshot.

The recorder is written from two threads (submit side and the scheduler
loop) under one lock; ``snapshot()`` is the only read surface and returns
an immutable :class:`ServiceMetrics`, so callers never see half-updated
counters.

Latency is held in fixed-boundary log-spaced histograms (one per request
bucket, see :mod:`repro.obs.histogram`) rather than a bounded deque: the
histograms render as real Prometheus ``_bucket``/``_sum``/``_count``
series, and because the boundaries are process-independent constants, a
fleet router can roll worker pages up by plain summation. They hold
*compute* completions only — cache hits are counted in
``completed_from_cache`` but never observed, so p50/p95 describe what a
miss actually costs instead of averaging in the hit rate. Per-stage
timings (cache probe, admission wait, queue wait, flush assembly, device
compute, crop) land in a parallel family of stage histograms.

Mpx/s is real request pixels served over *active* time: each completion
contributes the gap since the previous completion, capped at its own
latency — so idle gaps between bursts no longer deflate throughput (two
bursts separated by a sleep report the same rate as one burst).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, Optional, Tuple

from repro.obs.histogram import (
    DEFAULT_LATENCY_BOUNDS,
    Histogram,
    HistogramSnapshot,
    empty_snapshot,
)

LabelPairs = Tuple[Tuple[str, str], ...]
HistSeries = Tuple[Tuple[LabelPairs, HistogramSnapshot], ...]

# Stage taxonomy (docs/observability.md is the contract): every stage
# histogram key must come from this set so dashboards and the fleet
# rollup never meet a surprise label.
STAGES = (
    "cache_probe",   # content-key hash + local cache lookup
    "peer_probe",    # sibling cache RPC on a local miss (peered only)
    "admission",     # admission-gate wait (block policy backpressure)
    "queue_wait",    # admitted -> batch assembly started
    "flush",         # pad_stack + device transfer + dispatch issue
    "compute",       # device execution (dispatch -> block_until_ready)
    "crop",          # per-request result slicing off the padded batch
)

# Smallest latency credited to a completion when accounting active time:
# guards div-by-zero on sub-clock-resolution cache-adjacent completions.
_MIN_ACTIVE_S = 1e-3


def bucket_labels(bucket: Any) -> LabelPairs:
    """Service bucket key -> Prometheus label pairs. Buckets are
    (op, side, dtype) tuples everywhere in the multi-op service (the
    2-tuple (side, dtype) form predates the op dimension and still renders
    for older recordings); anything else gets a single opaque ``bucket``
    label so the renderer never crashes."""
    if isinstance(bucket, tuple) and len(bucket) == 3:
        return (("op", str(bucket[0])), ("side", str(bucket[1])),
                ("dtype", str(bucket[2])))
    if isinstance(bucket, tuple) and len(bucket) == 2:
        return (("side", str(bucket[0])), ("dtype", str(bucket[1])))
    if bucket is None:
        return ()
    return (("bucket", str(bucket)),)


@dataclasses.dataclass(frozen=True)
class ServiceMetrics:
    """One consistent point-in-time view of the service."""

    submitted: int            # requests accepted by submit()
    completed: int            # futures fulfilled (hits + computed)
    completed_from_cache: int  # of those, served straight from the cache
    cache_hits: int
    cache_misses: int
    coalesced: int            # duplicate-in-flight requests joined to a leader
    batches: int              # bucket stacks dispatched to the engine
    queue_depth: int          # waiting + pending-in-bucket at snapshot time
    shed: int                 # submits rejected with ServiceOverloaded
    blocked: int              # submits that waited at the admission gate
    compiled_shapes: Tuple[Tuple[int, int, int], ...]  # distinct dispatched
    hit_rate: float
    p50_latency_ms: float     # submit -> result ready, compute misses only
    p95_latency_ms: float
    mpx_per_s: float          # real (unpadded) request pixels served
    pad_fraction: float       # dispatched pixels that were padding
    backend: str              # engine's resolved backend at snapshot time
    # sheds attributed to the rejected request's (side, dtype) bucket —
    # sorted ((bucket, count), ...) pairs, so fairness regressions (one hot
    # bucket shedding everyone) are visible per bucket, not just in total
    shed_by_bucket: Tuple[Tuple[Any, int], ...] = ()
    peer_hits: int = 0        # local misses served by a sibling's cache
    peer_misses: int = 0      # outbound probes no sibling could answer
    # traffic-class/tenant attribution (docs/traffic.md): every shed also
    # lands in shed_by_class; quota sheds additionally in shed_by_tenant;
    # shed_deadline/shed_quota split the total by the check that tripped
    shed_by_class: Tuple[Tuple[str, int], ...] = ()
    shed_by_tenant: Tuple[Tuple[str, int], ...] = ()
    shed_deadline: int = 0    # DeadlineExceeded sheds at admission
    shed_quota: int = 0       # TenantQuotaExceeded sheds at admission
    # scene/bulk workload attached via service.attach_scene_progress():
    # granule-scale streaming progress (repro.scene), all zero when no
    # scene job is publishing through this service
    scene_tiles_done: int = 0
    scene_tiles_total: int = 0
    scene_resumes: int = 0          # checkpoint restores across the job
    scene_stitch_time_s: float = 0.0  # host-side seam/stitch accumulation
    # end-to-end latency histograms, one series per request bucket
    # (labels like (("side","64"),("dtype","uint8"))); the sum of every
    # series' count equals completed - completed_from_cache
    latency_hists: HistSeries = ()
    # per-stage timing histograms, labels (("stage",...), + bucket labels)
    stage_hists: HistSeries = ()

    @property
    def n_compiled_shapes(self) -> int:
        return len(self.compiled_shapes)

    def latency_hist(self) -> HistogramSnapshot:
        """All request buckets merged into one end-to-end histogram."""
        merged = empty_snapshot(DEFAULT_LATENCY_BOUNDS)
        for _labels, snap in self.latency_hists:
            merged = merged.merge(snap)
        return merged


class MetricsRecorder:
    def __init__(self, latency_window: int = 4096):
        # latency_window is accepted for API compatibility but unused:
        # fixed-boundary histograms are unbounded-in-time by design (the
        # windowing that made percentiles "recent" now belongs to the
        # scrape interval of whatever reads /metrics)
        del latency_window
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.completed_from_cache = 0
        self.coalesced = 0
        self.batches = 0
        self._latency_hists: Dict[Any, Histogram] = {}
        self._stage_hists: Dict[Tuple[str, Any, Optional[str]],
                                Histogram] = {}
        self._shapes: set = set()
        self._real_px = 0
        self._dispatched_px = 0
        self._served_px = 0
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        self._active_s = 0.0

    def _note_active(self, latency_s: float, now: float) -> None:
        """Credit active time for one completion: the gap since the last
        completion, capped at this request's own latency (so a burst of
        overlapping requests is not double-counted and an idle gap before
        a burst contributes at most one request's latency)."""
        credit = max(latency_s, _MIN_ACTIVE_S)
        anchor = self._t_last if self._t_last is not None else self._t_first
        if anchor is not None:
            credit = min(credit, max(0.0, now - anchor))
        self._active_s += credit

    def record_submit(self) -> None:
        with self._lock:
            self.submitted += 1
            if self._t_first is None:
                self._t_first = time.monotonic()

    def record_coalesced(self) -> None:
        with self._lock:
            self.coalesced += 1

    def record_coalesced_rejected(self, n: int) -> None:
        """Riders that coalesced onto a leader which was then shed (or hit
        close()) were never accepted: back their submit/coalesce counts
        out, so submitted - completed keeps tracking real outstanding
        work."""
        with self._lock:
            self.submitted -= n
            self.coalesced -= n

    def record_cache_hit(self, pixels: int,
                         now: Optional[float] = None) -> None:
        """A request served from the cache: counts toward completions and
        served pixels, but stays OUT of the latency histograms — a flood
        of ~0 ms hits would otherwise deflate p50/p95 for compute
        traffic. Contributes (at most) the minimum active-time quantum."""
        if now is None:
            now = time.monotonic()
        with self._lock:
            self.completed += 1
            self.completed_from_cache += 1
            self._served_px += pixels
            self._note_active(0.0, now)
            self._t_last = now

    def record_batch(self, shape: Tuple[int, int, int], real_px: int) -> None:
        with self._lock:
            self.batches += 1
            self._shapes.add(shape)
            self._real_px += real_px
            self._dispatched_px += shape[0] * shape[1] * shape[2]

    def record_complete(self, latency_s: float, pixels: int,
                        n_requests: int = 1, bucket: Any = None,
                        now: Optional[float] = None) -> None:
        """A computed batch's requests finished. The latency histogram is
        observed once per request (not per batch) so the histogram count
        stays equal to ``completed - completed_from_cache``."""
        if now is None:
            now = time.monotonic()
        with self._lock:
            self.completed += n_requests
            self._served_px += pixels * n_requests
            hist = self._latency_hists.get(bucket)
            if hist is None:
                hist = self._latency_hists[bucket] = Histogram(
                    DEFAULT_LATENCY_BOUNDS)
            for _ in range(n_requests):
                hist.observe(latency_s)
            self._note_active(latency_s, now)
            self._t_last = now

    def observe_stage(self, stage: str, bucket: Any,
                      seconds: float, klass: Optional[str] = None) -> None:
        """One stage timing sample (see STAGES for the taxonomy).

        ``klass`` adds a ``class`` label to the series — the service
        passes it for the class-differentiated stages (``queue_wait``:
        the one a lower priority class actually pays) so an SLO dashboard
        reads per-class wait straight off ``ychg_stage_seconds``.
        Tenants deliberately get NO histogram label (unbounded
        cardinality); per-tenant visibility is the shed counters."""
        key = (stage, bucket, klass)
        with self._lock:
            hist = self._stage_hists.get(key)
            if hist is None:
                hist = self._stage_hists[key] = Histogram(
                    DEFAULT_LATENCY_BOUNDS)
        hist.observe(max(0.0, seconds))

    def snapshot(self, *, queue_depth: int, cache_hits: int,
                 cache_misses: int, backend: str, shed: int = 0,
                 blocked: int = 0,
                 shed_by_bucket: Tuple[Tuple[Any, int], ...] = (),
                 shed_by_class: Tuple[Tuple[str, int], ...] = (),
                 shed_by_tenant: Tuple[Tuple[str, int], ...] = (),
                 shed_deadline: int = 0, shed_quota: int = 0,
                 peer_hits: int = 0, peer_misses: int = 0,
                 scene_tiles_done: int = 0, scene_tiles_total: int = 0,
                 scene_resumes: int = 0, scene_stitch_time_s: float = 0.0,
                 ) -> ServiceMetrics:
        with self._lock:
            latency_hists = tuple(
                (bucket_labels(bucket), hist.snapshot())
                for bucket, hist in sorted(
                    self._latency_hists.items(), key=lambda kv: str(kv[0])))
            stage_hists = tuple(
                ((("stage", stage),) + bucket_labels(bucket)
                 + ((("class", klass),) if klass is not None else ()),
                 hist.snapshot())
                for (stage, bucket, klass), hist in sorted(
                    self._stage_hists.items(), key=lambda kv: str(kv[0])))
            merged = empty_snapshot(DEFAULT_LATENCY_BOUNDS)
            for _labels, snap in latency_hists:
                merged = merged.merge(snap)
            total = cache_hits + cache_misses
            return ServiceMetrics(
                submitted=self.submitted,
                completed=self.completed,
                completed_from_cache=self.completed_from_cache,
                cache_hits=cache_hits,
                cache_misses=cache_misses,
                coalesced=self.coalesced,
                batches=self.batches,
                queue_depth=queue_depth,
                shed=shed,
                blocked=blocked,
                compiled_shapes=tuple(sorted(self._shapes)),
                hit_rate=cache_hits / total if total else 0.0,
                p50_latency_ms=merged.quantile(0.50) * 1e3,
                p95_latency_ms=merged.quantile(0.95) * 1e3,
                mpx_per_s=(
                    self._served_px / self._active_s / 1e6
                    if self._active_s > 0 else 0.0
                ),
                pad_fraction=(
                    1.0 - self._real_px / self._dispatched_px
                    if self._dispatched_px else 0.0
                ),
                backend=backend,
                shed_by_bucket=shed_by_bucket,
                shed_by_class=shed_by_class,
                shed_by_tenant=shed_by_tenant,
                shed_deadline=shed_deadline,
                shed_quota=shed_quota,
                peer_hits=peer_hits,
                peer_misses=peer_misses,
                scene_tiles_done=scene_tiles_done,
                scene_tiles_total=scene_tiles_total,
                scene_resumes=scene_resumes,
                scene_stitch_time_s=scene_stitch_time_s,
                latency_hists=latency_hists,
                stage_hists=stage_hists,
            )
