"""Service observability: a thread-safe recorder + a frozen snapshot.

The recorder is written from two threads (submit side and the scheduler
loop) under one lock; ``snapshot()`` is the only read surface and returns
an immutable :class:`ServiceMetrics`, so callers never see half-updated
counters. Latencies keep a bounded window (recent-traffic percentiles, not
lifetime averages) and hold *compute* completions only — cache hits are
counted in ``completed_from_cache`` but never push their ~0 ms samples
into the window, so p50/p95 describe what a miss actually costs instead of
averaging in the hit rate. Mpx/s is real request pixels served over the
first-submit -> last-completion window, so idle time before traffic does
not dilute it.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ServiceMetrics:
    """One consistent point-in-time view of the service."""

    submitted: int            # requests accepted by submit()
    completed: int            # futures fulfilled (hits + computed)
    completed_from_cache: int  # of those, served straight from the cache
    cache_hits: int
    cache_misses: int
    coalesced: int            # duplicate-in-flight requests joined to a leader
    batches: int              # bucket stacks dispatched to the engine
    queue_depth: int          # waiting + pending-in-bucket at snapshot time
    shed: int                 # submits rejected with ServiceOverloaded
    blocked: int              # submits that waited at the admission gate
    compiled_shapes: Tuple[Tuple[int, int, int], ...]  # distinct dispatched
    hit_rate: float
    p50_latency_ms: float     # submit -> result ready, compute misses only
    p95_latency_ms: float
    mpx_per_s: float          # real (unpadded) request pixels served
    pad_fraction: float       # dispatched pixels that were padding
    backend: str              # engine's resolved backend at snapshot time
    # sheds attributed to the rejected request's (side, dtype) bucket —
    # sorted ((bucket, count), ...) pairs, so fairness regressions (one hot
    # bucket shedding everyone) are visible per bucket, not just in total
    shed_by_bucket: Tuple[Tuple[Any, int], ...] = ()
    peer_hits: int = 0        # local misses served by a sibling's cache
    peer_misses: int = 0      # outbound probes no sibling could answer
    # scene/bulk workload attached via service.attach_scene_progress():
    # granule-scale streaming progress (repro.scene), all zero when no
    # scene job is publishing through this service
    scene_tiles_done: int = 0
    scene_tiles_total: int = 0
    scene_resumes: int = 0          # checkpoint restores across the job
    scene_stitch_time_s: float = 0.0  # host-side seam/stitch accumulation

    @property
    def n_compiled_shapes(self) -> int:
        return len(self.compiled_shapes)


class MetricsRecorder:
    def __init__(self, latency_window: int = 4096):
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.completed_from_cache = 0
        self.coalesced = 0
        self.batches = 0
        self._latencies: "deque[float]" = deque(maxlen=latency_window)
        self._shapes: set = set()
        self._real_px = 0
        self._dispatched_px = 0
        self._served_px = 0
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    def record_submit(self) -> None:
        with self._lock:
            self.submitted += 1
            if self._t_first is None:
                self._t_first = time.monotonic()

    def record_coalesced(self) -> None:
        with self._lock:
            self.coalesced += 1

    def record_coalesced_rejected(self, n: int) -> None:
        """Riders that coalesced onto a leader which was then shed (or hit
        close()) were never accepted: back their submit/coalesce counts
        out, so submitted - completed keeps tracking real outstanding
        work."""
        with self._lock:
            self.submitted -= n
            self.coalesced -= n

    def record_cache_hit(self, pixels: int) -> None:
        """A request served from the cache: counts toward completions and
        served pixels, but stays OUT of the latency window — a flood of
        ~0 ms hits would otherwise deflate p50/p95 for compute traffic."""
        with self._lock:
            self.completed += 1
            self.completed_from_cache += 1
            self._served_px += pixels
            self._t_last = time.monotonic()

    def record_batch(self, shape: Tuple[int, int, int], real_px: int) -> None:
        with self._lock:
            self.batches += 1
            self._shapes.add(shape)
            self._real_px += real_px
            self._dispatched_px += shape[0] * shape[1] * shape[2]

    def record_complete(self, latency_s: float, pixels: int,
                        n_requests: int = 1) -> None:
        with self._lock:
            self.completed += n_requests
            self._served_px += pixels * n_requests
            self._latencies.append(latency_s)
            self._t_last = time.monotonic()

    def snapshot(self, *, queue_depth: int, cache_hits: int,
                 cache_misses: int, backend: str, shed: int = 0,
                 blocked: int = 0,
                 shed_by_bucket: Tuple[Tuple[Any, int], ...] = (),
                 peer_hits: int = 0, peer_misses: int = 0,
                 scene_tiles_done: int = 0, scene_tiles_total: int = 0,
                 scene_resumes: int = 0, scene_stitch_time_s: float = 0.0,
                 ) -> ServiceMetrics:
        with self._lock:
            lat = np.asarray(self._latencies, np.float64) * 1e3
            span = (
                self._t_last - self._t_first
                if self._t_first is not None and self._t_last is not None
                else 0.0
            )
            total = cache_hits + cache_misses
            return ServiceMetrics(
                submitted=self.submitted,
                completed=self.completed,
                completed_from_cache=self.completed_from_cache,
                cache_hits=cache_hits,
                cache_misses=cache_misses,
                coalesced=self.coalesced,
                batches=self.batches,
                queue_depth=queue_depth,
                shed=shed,
                blocked=blocked,
                compiled_shapes=tuple(sorted(self._shapes)),
                hit_rate=cache_hits / total if total else 0.0,
                p50_latency_ms=float(np.percentile(lat, 50)) if lat.size else 0.0,
                p95_latency_ms=float(np.percentile(lat, 95)) if lat.size else 0.0,
                mpx_per_s=self._served_px / span / 1e6 if span > 0 else 0.0,
                pad_fraction=(
                    1.0 - self._real_px / self._dispatched_px
                    if self._dispatched_px else 0.0
                ),
                backend=backend,
                shed_by_bucket=shed_by_bucket,
                peer_hits=peer_hits,
                peer_misses=peer_misses,
                scene_tiles_done=scene_tiles_done,
                scene_tiles_total=scene_tiles_total,
                scene_resumes=scene_resumes,
                scene_stitch_time_s=scene_stitch_time_s,
            )
