"""`YCHGService` — the batching, caching ROI service on top of `YCHGEngine`.

Between "a request arrives" and "the engine runs" sit three layers, each
independently testable:

  1. a content-addressed LRU **result cache** (``service.cache``): a hit
     fulfils the future immediately and never touches the backend;
     duplicate masks *in flight* coalesce onto one leader request, so a
     burst of identical masks costs one bucket slot;
  2. a **micro-batching scheduler**: misses queue into per-``(side, dtype)``
     shape buckets and flush when a bucket reaches ``max_batch`` or its
     oldest request ages past ``max_delay_ms``; stacks are padded to the
     bucket side AND to ``max_batch``, so the backend only ever compiles
     one shape per bucket — traffic cannot trigger recompiles;
  3. a **double-buffered dispatch loop**: up to ``inflight_buckets`` bucket
     computations are outstanding at once, so the host->device transfer and
     batching work for bucket n+1 overlap the device compute of bucket n
     (the same discipline ``YCHGEngine.analyze_stream`` now applies per
     item). Completion blocks on readiness, fans per-request cropped
     results out to futures, and records true submit->ready latency.

One scheduler thread owns layers 2-3; ``submit`` only hashes, checks the
cache, and enqueues, so the caller's thread never blocks on device work.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax

from repro.engine import YCHGEngine, YCHGResult
from repro.service.batching import (
    Bucket,
    crop_result,
    pad_stack,
    pick_bucket_side,
)
from repro.service.cache import CacheKey, ResultCache, make_key
from repro.service.metrics import MetricsRecorder, ServiceMetrics


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Frozen service policy knobs.

    bucket_sides      ascending ladder of square bucket sides; a mask maps
                      to the smallest side holding it and anything past the
                      top is rejected, so compiled shapes stay bounded at
                      one (max_batch, side, side) per (side, dtype) seen.
    max_batch         bucket flush size; batches are padded (blank images)
                      to exactly this, trading pad compute for a fixed
                      compiled shape per bucket.
    max_delay_ms      micro-batching window: the longest a queued request
                      waits for batch-mates before a partial flush.
    cache_entries     LRU capacity (0 disables caching).
    inflight_buckets  max outstanding bucket computations (2 = classic
                      double buffering: ingest n+1 overlaps compute n).
    latency_window    number of recent latencies kept for p50/p95.
    """

    bucket_sides: Tuple[int, ...] = (128, 256, 512, 1024)
    max_batch: int = 8
    max_delay_ms: float = 2.0
    cache_entries: int = 1024
    inflight_buckets: int = 2
    latency_window: int = 4096

    def __post_init__(self):
        if not self.bucket_sides or list(self.bucket_sides) != sorted(
            set(self.bucket_sides)
        ):
            raise ValueError(
                f"bucket_sides must be a non-empty ascending ladder, "
                f"got {self.bucket_sides}"
            )
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.inflight_buckets < 1:
            raise ValueError(
                f"inflight_buckets must be >= 1, got {self.inflight_buckets}"
            )


@dataclasses.dataclass
class _Request:
    mask: np.ndarray          # C-contiguous host mask, native shape
    key: CacheKey
    bucket: Bucket
    t_submit: float
    futures: List[Future]     # leader's future + any coalesced duplicates


@dataclasses.dataclass
class _Job:
    requests: List[_Request]
    result: YCHGResult        # dispatched, possibly not yet ready


_SHUTDOWN = object()


class YCHGService:
    """Single-mask request front end over a shared :class:`YCHGEngine`.

    ``submit(mask)`` returns a ``concurrent.futures.Future`` resolving to
    the B=1 device-resident ``YCHGResult`` that ``engine.analyze(mask)``
    would produce — bit-identical, including through bucket padding and
    result caching. ``analyze(mask)`` is the blocking convenience form.
    Use as a context manager, or call ``close()`` to drain and stop.

    Pass ``cache`` to share one :class:`ResultCache` between services;
    keys include each engine's resolved backend and config, so sharing is
    always safe (policies never serve each other's entries).
    """

    def __init__(self, engine: Optional[YCHGEngine] = None,
                 config: ServiceConfig = ServiceConfig(), *,
                 cache: Optional[ResultCache] = None):
        self.engine = engine if engine is not None else YCHGEngine()
        self.config = config
        self.cache = cache if cache is not None else ResultCache(
            config.cache_entries)
        self._recorder = MetricsRecorder(config.latency_window)
        self._q: "queue.Queue" = queue.Queue()
        self._pending: Dict[Bucket, List[_Request]] = {}
        self._inflight: "deque[_Job]" = deque()
        self._leaders: Dict[CacheKey, _Request] = {}
        self._lock = threading.Lock()
        self._closed = False
        self._thread = threading.Thread(
            target=self._loop, name="ychg-service", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------ requests

    def submit(self, mask: Any) -> "Future[YCHGResult]":
        """Enqueue one (H, W) mask; the future resolves to a ready result."""
        if self._closed:
            raise RuntimeError("service is closed")
        a = np.ascontiguousarray(np.asarray(mask))
        if a.ndim != 2:
            raise ValueError(f"submit expects an (H, W) mask, got {a.shape}")
        side = pick_bucket_side(a.shape, self.config.bucket_sides)
        key = make_key(a, self.engine.resolve_backend(), self.engine.config,
                       self.engine.mesh)
        self._recorder.record_submit()
        fut: "Future[YCHGResult]" = Future()
        cached = self.cache.get(key)
        if cached is not None:
            self._recorder.record_complete(0.0, a.size)
            fut.set_result(cached)
            return fut
        # registration and enqueue share the close() lock: once close() has
        # put the shutdown sentinel (under this lock), no request can land
        # behind it in the queue and silently never resolve
        with self._lock:
            if self._closed:
                raise RuntimeError("service is closed")
            leader = self._leaders.get(key)
            if leader is not None:
                leader.futures.append(fut)
                self._recorder.record_coalesced()
                return fut
            req = _Request(mask=a, key=key, bucket=(side, str(a.dtype)),
                           t_submit=time.monotonic(), futures=[fut])
            self._leaders[key] = req
            self._q.put(req)
        return fut

    def analyze(self, mask: Any, timeout: Optional[float] = None) -> YCHGResult:
        """Blocking convenience: ``submit(mask).result(timeout)``."""
        return self.submit(mask).result(timeout)

    def metrics(self) -> ServiceMetrics:
        # _pending insert/pop happen on the scheduler thread under the same
        # lock, so this iteration cannot see the dict resize mid-walk
        with self._lock:
            pending = sum(len(v) for v in self._pending.values())
        depth = self._q.qsize() + pending
        return self._recorder.snapshot(
            queue_depth=depth,
            cache_hits=self.cache.hits,
            cache_misses=self.cache.misses,
            backend=self.engine.resolve_backend(),
        )

    # ----------------------------------------------------------- lifecycle

    def close(self, timeout: float = 60.0) -> None:
        """Drain queued work, stop the scheduler. Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._q.put(_SHUTDOWN)
        self._thread.join(timeout)

    def __enter__(self) -> "YCHGService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------ scheduler loop

    def _loop(self) -> None:
        delay = self.config.max_delay_ms / 1e3
        while True:
            # fully idle: retire outstanding computations before sleeping so
            # trailing requests are not held hostage to the next arrival
            if self._inflight and not self._pending and self._q.empty():
                while self._inflight:
                    self._complete(self._inflight.popleft())
            timeout = 0.1
            if self._pending:
                oldest = min(r[0].t_submit for r in self._pending.values())
                timeout = max(0.0, oldest + delay - time.monotonic())
            try:
                item = self._q.get(timeout=timeout)
            except queue.Empty:
                item = None
            # drain the whole backlog before any age-based flush: under a
            # burst, queued requests are older than max_delay_ms by the time
            # they are seen, and flushing per item would degenerate to one
            # batch per request exactly when batching matters most
            shutdown = False
            while item is not None:
                if item is _SHUTDOWN:
                    shutdown = True
                    break
                with self._lock:
                    reqs = self._pending.setdefault(item.bucket, [])
                reqs.append(item)
                if len(reqs) >= self.config.max_batch:
                    self._flush(item.bucket)
                try:
                    item = self._q.get_nowait()
                except queue.Empty:
                    item = None
            if shutdown:
                break
            now = time.monotonic()
            for bucket in [
                b for b, rs in self._pending.items()
                if now - rs[0].t_submit >= delay
            ]:
                self._flush(bucket)
        # drain on shutdown: flush every partial bucket, retire every job
        for bucket in list(self._pending):
            self._flush(bucket)
        while self._inflight:
            self._complete(self._inflight.popleft())

    def _flush(self, bucket: Bucket) -> None:
        """Dispatch one bucket; keep at most ``inflight_buckets`` outstanding."""
        with self._lock:
            requests = self._pending.pop(bucket)
        side, dtype = bucket
        try:
            stack = pad_stack([r.mask for r in requests], side,
                              self.config.max_batch, np.dtype(dtype))
            # the host->device transfer of THIS bucket starts here, while
            # the previous bucket's computation is still in flight
            x = jax.device_put(stack)
            result = self.engine.analyze_batch(x)  # async dispatch
        except Exception as e:  # config/backend errors -> fail these futures
            self._fail(requests, e)
            return
        self._recorder.record_batch(
            stack.shape, sum(r.mask.size for r in requests))
        self._inflight.append(_Job(requests, result))
        while len(self._inflight) >= self.config.inflight_buckets:
            self._complete(self._inflight.popleft())

    def _complete(self, job: _Job) -> None:
        # any escape here would kill the scheduler thread and hang every
        # outstanding future, so the whole fan-out (not just the device
        # wait) routes failures to _fail — which skips already-fulfilled
        # futures, so a partial fan-out fails only the requests it missed
        try:
            job.result.block_until_ready()
            now = time.monotonic()
            for row, req in enumerate(job.requests):
                out = crop_result(job.result, row, req.mask.shape[1])
                with self._lock:
                    self._leaders.pop(req.key, None)
                self.cache.put(req.key, out)
                self._recorder.record_complete(
                    now - req.t_submit, req.mask.size, len(req.futures))
                for fut in req.futures:
                    _fulfil(fut, out)
        except Exception as e:
            self._fail(job.requests, e)

    def _fail(self, requests: List[_Request], exc: Exception) -> None:
        for req in requests:
            with self._lock:
                self._leaders.pop(req.key, None)
            for fut in req.futures:
                if not fut.done() and fut.set_running_or_notify_cancel():
                    fut.set_exception(exc)


def _fulfil(fut: Future, value: Any) -> None:
    """Resolve a future the client may have cancelled in the meantime.

    ``submit`` hands out plain ``Future``s that are never marked running,
    so a client-side ``cancel()`` always succeeds; an unguarded
    ``set_result`` would then raise ``InvalidStateError`` inside the
    scheduler thread and kill it — hanging every other outstanding request.
    """
    if fut.set_running_or_notify_cancel():
        fut.set_result(value)
