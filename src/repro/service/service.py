"""`YCHGService` — the batching, caching multi-op service over `Engine`.

Between "a request arrives" and "the engine runs" sit three layers, each
independently testable:

  1. a content-addressed LRU **result cache** (``service.cache``): a hit
     fulfils the future immediately and never touches the backend;
     duplicate masks *in flight* coalesce onto one leader request, so a
     burst of identical masks costs one bucket slot. The cache check, the
     coalesce, and the completion-side ``cache.put`` + leader retirement
     all run under one lock, so a duplicate either joins the leader or
     hits the cache — there is no window where it can re-dispatch;
  2. a **micro-batching scheduler** (:mod:`repro.service.scheduler`):
     misses queue into per-``(op, side, dtype)`` shape buckets (an op only
     ever batches with itself) and flush when a bucket reaches its op's
     ``max_batch`` or its oldest request ages past
     ``max_delay_ms``; stacks are padded to the bucket side AND to the
     power-of-two **sub-batch ladder** rung covering the flush occupancy,
     so a lone request pays for one image, not ``max_batch``, while the
     compiled-shape budget stays ``len(bucket_sides) * (log2(max_batch)
     + 1)`` per dtype. ``max_queue_depth`` + ``overload_policy`` add
     admission control: past the bound, ``submit`` blocks (backpressure)
     or raises :class:`ServiceOverloaded` (shed);
  3. a **double-buffered dispatch loop**: up to ``inflight_buckets`` bucket
     computations are outstanding at once, so the host->device transfer and
     batching work for bucket n+1 overlap the device compute of bucket n
     (the same discipline ``Engine.analyze_stream`` now applies per
     item). Completion blocks on readiness, fans per-request cropped
     results out to futures, and records true submit->ready latency —
     cache hits are counted separately and never enter the latency window.

The scheduler thread owns layers 2-3; ``submit`` only hashes, checks the
cache, and enqueues, so the caller's thread never blocks on device work
(unless backpressure deliberately blocks it at ``max_queue_depth``).
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax

from repro.engine import Engine, YCHGResult
from repro.engine.ops import PIPELINE_SEP, pipeline_op_key, split_pipeline_key, validate_pipeline
from repro.obs import NULL_TRACE, maybe_trace
from repro.service.batching import (
    Bucket,
    crop_for,
    pad_stack,
    pick_bucket_side,
)
from repro.service.cache import CacheKey, ResultCache, make_key
from repro.service.metrics import MetricsRecorder, ServiceMetrics
from repro.service.scheduler import (
    Scheduler,
    SchedulerConfig,
    ServiceOverloaded,
)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Frozen service policy knobs.

    bucket_sides      ascending ladder of square bucket sides; a mask maps
                      to the smallest side holding it and anything past the
                      top is rejected, so compiled shapes stay bounded.
    max_batch         bucket flush size; a flush is padded (blank images)
                      to the smallest power-of-two sub-batch >= its
                      occupancy, capped here — pad compute scales with
                      traffic while compiled shapes stay bounded at
                      ``len(bucket_sides) * (log2(max_batch) + 1)`` per
                      dtype seen.
    max_delay_ms      micro-batching window: the longest a queued request
                      waits for batch-mates before a partial flush.
    cache_entries     LRU capacity (0 disables caching).
    inflight_buckets  bucket computations kept outstanding after a flush
                      (2 = classic double buffering: ingest n+1 overlaps
                      compute n). A flush dispatches before trimming, so
                      one extra job is briefly in flight while the oldest
                      retires.
    latency_window    number of recent latencies kept for p50/p95.
    max_queue_depth   admission bound on accepted-but-unfinished requests;
                      None disables the global bound.
    bucket_queue_depth  the same admission bound applied PER BUCKET (None
                      = off): a flood of one resolution sheds/blocks
                      against its own allowance while every other bucket
                      keeps admitting — the global bound alone is
                      bucket-blind and sheds minority traffic with the
                      flood. Per-bucket shed counts are in
                      ``ServiceMetrics.shed_by_bucket``.
    fair              serve ready buckets deficit-round-robin (True, the
                      default) instead of strictly in arrival order
                      (False) — a hot bucket's backlog dispatches one
                      batch per round, interleaved with other buckets.
    overload_policy   at the bound, ``submit`` either blocks until a slot
                      frees ("block", backpressure) or raises
                      :class:`ServiceOverloaded` ("shed", fail fast).
                      Cache hits and coalesces onto an admitted leader
                      consume no queue slot and are never rejected; a
                      duplicate that joins a leader still waiting at the
                      admission gate shares the leader's fate — if that
                      leader is shed, the duplicate's future fails with
                      the same ServiceOverloaded.
    sub_batches       pad flushes to the power-of-two ladder (True) or
                      always to ``max_batch`` (False; kept so benchmarks
                      can compare the two policies on one schedule).
    op_bucket_sides   per-op overrides of ``bucket_sides``: a mapping (or
                      sorted pair tuple) ``op key -> ladder``. An op (or
                      exact pipeline key like "denoise+ychg") without an
                      entry uses the default ladder. Canonicalised to a
                      sorted tuple of pairs so two configs with the same
                      content always compare equal.
    op_max_batch      per-op overrides of ``max_batch``, same key rules;
                      drives both the flush size and that op's DRR
                      quantum, so a small-batch op earns proportionally
                      small rounds.
    classes           traffic classes in strict priority order, highest
                      first; ``submit(..., klass=...)`` selects one.
                      Strict priority across classes, DRR within
                      (docs/traffic.md).
    default_class     the class of a request submitted without ``klass``.
    tenant_rate       per-tenant token-bucket refill (requests/s);
                      0.0 disables tenant quotas.
    tenant_burst      per-tenant banked-token cap; 0.0 means
                      ``max(1, tenant_rate)``.
    """

    bucket_sides: Tuple[int, ...] = (128, 256, 512, 1024)
    max_batch: int = 8
    max_delay_ms: float = 2.0
    cache_entries: int = 1024
    inflight_buckets: int = 2
    latency_window: int = 4096
    max_queue_depth: Optional[int] = None
    bucket_queue_depth: Optional[int] = None
    overload_policy: str = "block"
    sub_batches: bool = True
    fair: bool = True
    op_bucket_sides: Any = ()
    op_max_batch: Any = ()
    classes: Tuple[str, ...] = ("interactive", "standard", "batch")
    default_class: str = "standard"
    tenant_rate: float = 0.0
    tenant_burst: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "classes", tuple(self.classes))
        self._check_ladder(self.bucket_sides)
        object.__setattr__(self, "op_bucket_sides", tuple(
            sorted((str(op), tuple(sides))
                   for op, sides in dict(self.op_bucket_sides).items())))
        object.__setattr__(self, "op_max_batch", tuple(
            sorted((str(op), int(mb))
                   for op, mb in dict(self.op_max_batch).items())))
        for op, sides in self.op_bucket_sides:
            self._check_ladder(sides, f"op_bucket_sides[{op!r}]")
        for op, mb in self.op_max_batch:
            if mb < 1:
                raise ValueError(
                    f"op_max_batch[{op!r}] must be >= 1, got {mb}")
        if self.inflight_buckets < 1:
            raise ValueError(
                f"inflight_buckets must be >= 1, got {self.inflight_buckets}")
        # the remaining knobs share their names with SchedulerConfig, so
        # constructing it here surfaces bad values at ServiceConfig() time
        # with messages that name the right knob
        self.scheduler_config()

    @staticmethod
    def _check_ladder(sides, name: str = "bucket_sides") -> None:
        if not sides or list(sides) != sorted(set(sides)):
            raise ValueError(
                f"{name} must be a non-empty ascending ladder, got {sides}")

    def bucket_sides_for(self, op_key: str) -> Tuple[int, ...]:
        """The bucket ladder for an op (or exact pipeline key)."""
        return dict(self.op_bucket_sides).get(op_key, self.bucket_sides)

    def max_batch_for(self, op_key: str) -> int:
        """The flush size (and DRR quantum) for an op key."""
        return dict(self.op_max_batch).get(op_key, self.max_batch)

    def scheduler_config(self) -> SchedulerConfig:
        return SchedulerConfig(
            max_batch=self.max_batch,
            max_delay_ms=self.max_delay_ms,
            inflight_jobs=self.inflight_buckets,
            max_queue_depth=self.max_queue_depth,
            bucket_queue_depth=self.bucket_queue_depth,
            overload_policy=self.overload_policy,
            sub_batches=self.sub_batches,
            fair=self.fair,
            classes=self.classes,
            default_class=self.default_class,
            tenant_rate=self.tenant_rate,
            tenant_burst=self.tenant_burst,
        )


@dataclasses.dataclass
class _Request:
    mask: np.ndarray          # C-contiguous host mask, native shape
    key: CacheKey
    bucket: Bucket
    t_submit: float
    futures: List[Future]     # leader's future + any coalesced duplicates
    trace: Any = NULL_TRACE   # request trace the stage spans land in
    own_trace: bool = False   # True: the service created it and finishes it
    # stage-edge timestamps (monotonic). t_gate is stamped by the submitter
    # just before the admission gate; t_admitted just after submit returns
    # (the scheduler thread may dispatch before that write lands, so
    # consumers fall back t_admitted -> t_gate -> t_submit); t_dispatch is
    # stamped by the scheduler thread when the batch is issued.
    t_gate: float = 0.0
    t_admitted: float = 0.0
    t_dispatch: float = 0.0
    # traffic shaping (docs/traffic.md): the scheduler reads these three
    # at admission. None klass means config.default_class; none of them
    # ever enters the cache key, the bucket, or the payload — identical
    # masks are one cache entry whatever class/tenant asked
    klass: Optional[str] = None
    deadline_ms: Optional[float] = None
    tenant: Optional[str] = None


class YCHGService:
    """Single-mask request front end over a shared op-dispatching
    :class:`Engine`.

    ``submit(mask)`` returns a ``concurrent.futures.Future`` resolving to
    the B=1 device-resident ``YCHGResult`` that ``engine.analyze(mask)``
    would produce — bit-identical, including through bucket padding and
    result caching; ``submit(mask, op="ccl")`` serves any registered op
    the same way, and ``submit_pipeline(mask, ["denoise", "ychg"])`` runs
    an ordered op chain device-resident end to end (no host round trip
    between stages), bit-identical to issuing the stages as separate
    requests. ``analyze(mask)`` is the blocking convenience form. Use as
    a context manager, or call ``close()`` to drain and stop.

    Pass ``cache`` to share one :class:`ResultCache` between services;
    keys include each engine's resolved backend, config, and the op key,
    so sharing is always safe (policies never serve each other's entries,
    and neither do different ops on the same mask).
    """

    def __init__(self, engine: Optional[Engine] = None,
                 config: ServiceConfig = ServiceConfig(), *,
                 cache: Optional[ResultCache] = None):
        self.engine = engine if engine is not None else Engine()
        self.config = config
        self.cache = cache if cache is not None else ResultCache(
            config.cache_entries)
        self._recorder = MetricsRecorder(config.latency_window)
        self._leaders: Dict[CacheKey, _Request] = {}
        self._lock = threading.Lock()
        self._closed = False
        self._scene_progress: Optional[Any] = None
        self._scheduler = Scheduler(
            config.scheduler_config(),
            dispatch=self._dispatch,
            complete=self._complete,
            fail=self._fail,
            max_batch_for=lambda bucket: config.max_batch_for(bucket[0]),
        )

    # ------------------------------------------------------------ requests

    def submit(self, mask: Any, *, op: Optional[str] = None,
               trace: Optional[Any] = None, klass: Optional[str] = None,
               deadline_ms: Optional[float] = None,
               tenant: Optional[str] = None) -> "Future[YCHGResult]":
        """Enqueue one (H, W) mask; the future resolves to a ready result.

        ``op`` selects the operator (default: the engine's own, normally
        ``"ychg"``); the future resolves to that op's B=1 device-resident
        result pytree. Raises :class:`ServiceOverloaded` when the queue is
        at ``max_queue_depth`` under ``overload_policy="shed"``; blocks
        here (not on device work) under ``"block"``.

        Traffic shaping (docs/traffic.md): ``klass`` picks a priority
        class from ``config.classes`` (default ``config.default_class``;
        unknown classes raise ``ValueError``); ``deadline_ms`` is a
        completion budget — admission sheds with
        :class:`repro.service.scheduler.DeadlineExceeded` when the
        predicted queue delay already exceeds it; ``tenant`` is the quota
        identity — an over-quota tenant sheds with
        :class:`repro.service.scheduler.TenantQuotaExceeded`. All three
        ride OUTSIDE the cache key and payload, so results stay
        bit-identical whatever class asked, and a cache hit or a
        coalesce onto an in-flight leader is served without consuming
        quota or deadline checks (a hit costs ~nothing to serve; only
        admission to compute is shaped).

        ``trace`` joins this request's stage spans to an existing
        :class:`repro.obs.Trace` (the frontend passes the one it opened
        from the ``X-YCHG-Trace`` header, and stays responsible for
        finishing it). Without one, the service opens its own trace and
        finishes it when the request resolves.
        """
        op_key = op if op is not None else self.engine.op
        if PIPELINE_SEP in op_key:
            raise ValueError(
                f"op {op_key!r} looks like a pipeline spec; use "
                f"submit_pipeline for ordered op chains")
        backend = self.engine.resolve_backend(op=op_key)
        return self._submit_keyed(mask, op_key, backend, trace,
                                  klass=klass, deadline_ms=deadline_ms,
                                  tenant=tenant)

    def submit_pipeline(self, mask: Any, stages, *,
                        trace: Optional[Any] = None,
                        klass: Optional[str] = None,
                        deadline_ms: Optional[float] = None,
                        tenant: Optional[str] = None) -> "Future":
        """Enqueue one mask through an ordered op chain (device-resident).

        ``stages`` is a sequence of op names, e.g. ``["denoise", "ychg"]``;
        every stage but the last must be chainable (its result has an
        image-shaped field the next stage ingests). The future resolves to
        the LAST stage's B=1 result, bit-identical to submitting each
        stage separately and feeding the cropped output forward — the
        pipeline just never leaves the device between stages. Cache
        entries are keyed by the full ``"+"``-joined pipeline key, so a
        pipeline never aliases its prefix ops.
        """
        stages = validate_pipeline(stages)
        op_key = pipeline_op_key(stages)
        backend = PIPELINE_SEP.join(
            self.engine.resolve_backend(op=s) for s in stages)
        return self._submit_keyed(mask, op_key, backend, trace,
                                  klass=klass, deadline_ms=deadline_ms,
                                  tenant=tenant)

    def _submit_keyed(self, mask: Any, op_key: str, backend: str,
                      trace: Optional[Any], *,
                      klass: Optional[str] = None,
                      deadline_ms: Optional[float] = None,
                      tenant: Optional[str] = None) -> "Future":
        if klass is not None and klass not in self.config.classes:
            raise ValueError(
                f"unknown traffic class {klass!r} "
                f"(classes: {self.config.classes!r})")
        if self._closed:
            raise RuntimeError("service is closed")
        tr = trace if trace is not None else maybe_trace()
        own = trace is None
        t_probe0 = time.monotonic()
        a = np.ascontiguousarray(np.asarray(mask))
        if a.ndim != 2:
            raise ValueError(f"submit expects an (H, W) mask, got {a.shape}")
        side = pick_bucket_side(a.shape, self.config.bucket_sides_for(op_key))
        bucket = (op_key, side, str(a.dtype))
        key = make_key(a, backend, self.engine.config,
                       self.engine.mesh, op=op_key)
        fut: "Future[YCHGResult]" = Future()
        cached = None
        outcome = "miss"
        # cache check, coalesce, and leader registration are ONE critical
        # section, shared with the completion side's cache.put + leader
        # retirement: a duplicate always sees the leader or the cached
        # result, never the gap between them
        with self._lock:
            if self._closed:
                raise RuntimeError("service is closed")
            cached = self.cache.get(key)
            if cached is not None:
                self._recorder.record_submit()
                self._recorder.record_cache_hit(a.size)
                outcome = "hit"
            else:
                leader = self._leaders.get(key)
                if leader is not None:
                    leader.futures.append(fut)
                    self._recorder.record_submit()
                    self._recorder.record_coalesced()
                    outcome = "coalesced"
                else:
                    req = _Request(mask=a, key=key, bucket=bucket,
                                   t_submit=time.monotonic(), futures=[fut],
                                   trace=tr, own_trace=own, klass=klass,
                                   deadline_ms=deadline_ms, tenant=tenant)
                    self._leaders[key] = req
        t_probe1 = time.monotonic()
        self._recorder.observe_stage("cache_probe", bucket,
                                     t_probe1 - t_probe0)
        tr.add("cache.probe", t_probe0, t_probe1, outcome=outcome)
        if outcome == "hit":
            fut.set_result(cached)
            if own:
                tr.finish()
            return fut
        if outcome == "coalesced":
            # the rider's spans end here; the leader's trace carries the
            # compute stages for the shared result
            if own:
                tr.finish()
            return fut
        # peer probe OUTSIDE the lock (it is a blocking network call in a
        # fleet): the leader is already registered, so duplicates arriving
        # mid-probe coalesce onto it and share the peered result below.
        # Base caches answer None and cost nothing.
        t_peer0 = time.monotonic()
        peered = self.cache.peer_probe(key)
        t_peer1 = time.monotonic()
        if hasattr(self.cache, "set_peers"):
            # only peer-capable caches get a peer_probe stage sample: the
            # base ResultCache answers None in ~0 time and a flood of those
            # samples would bury the real probe distribution
            self._recorder.observe_stage("peer_probe", bucket,
                                         t_peer1 - t_peer0)
            tr.add("cache.peer_probe", t_peer0, t_peer1,
                   outcome="hit" if peered is not None else "miss")
        if peered is not None:
            with self._lock:
                self.cache.put(key, peered)
                self._leaders.pop(key, None)
            # the leader + every rider that joined during the probe: all
            # served without consuming an admission slot (same rule as a
            # local cache hit); riders recorded their submits when they
            # coalesced, so completions are recorded per future
            self._recorder.record_submit()
            for f in req.futures:
                self._recorder.record_cache_hit(a.size)
                _fulfil(f, peered)
            if own:
                tr.finish()
            return fut
        # admission happens OUTSIDE the service lock: a blocked submitter
        # must not hold the lock the completion path needs to free a slot.
        # The leader is registered first so duplicates coalesce (for free)
        # even while their leader waits at the admission gate.
        req.t_gate = time.monotonic()
        try:
            self._scheduler.submit(req)
        except BaseException as e:
            with self._lock:
                self._leaders.pop(key, None)
            # once the leader is popped no more riders can join, so
            # req.futures is stable: fail fut + anyone who coalesced while
            # the leader waited at the gate, and back their submits out of
            # the counters — they were never accepted either
            if len(req.futures) > 1:
                self._recorder.record_coalesced_rejected(
                    len(req.futures) - 1)
            for f in req.futures:
                if not f.done() and f.set_running_or_notify_cancel():
                    f.set_exception(e)
            tr.add("scheduler.admission", req.t_gate, time.monotonic(),
                   outcome=type(e).__name__)
            if own:
                tr.finish()
            raise
        req.t_admitted = time.monotonic()
        self._recorder.observe_stage("admission", bucket,
                                     req.t_admitted - req.t_gate)
        tr.add("scheduler.admission", req.t_gate, req.t_admitted)
        # counted only once actually admitted: a shed submit is not
        # "accepted", so submitted - completed tracks real outstanding work
        self._recorder.record_submit()
        return fut

    def analyze(self, mask: Any, timeout: Optional[float] = None, *,
                op: Optional[str] = None) -> YCHGResult:
        """Blocking convenience: ``submit(mask, op=op).result(timeout)``."""
        return self.submit(mask, op=op).result(timeout)

    def pipeline(self, mask: Any, stages,
                 timeout: Optional[float] = None):
        """Blocking convenience: ``submit_pipeline(...).result(timeout)``."""
        return self.submit_pipeline(mask, stages).result(timeout)

    def attach_scene_progress(self, progress: Any) -> None:
        """Publish a scene/bulk job's progress through ``metrics()``.

        ``progress`` is duck-typed (so this layer never imports
        ``repro.scene``): anything whose ``snapshot()`` exposes
        ``tiles_done`` / ``tiles_total`` / ``resumes`` / ``stitch_time_s``
        — in practice a :class:`repro.scene.SceneProgress`. Pass ``None``
        to detach.
        """
        self._scene_progress = progress

    def metrics(self) -> ServiceMetrics:
        scene = (self._scene_progress.snapshot()
                 if self._scene_progress is not None else None)
        return self._recorder.snapshot(
            queue_depth=self._scheduler.backlog(),
            cache_hits=self.cache.hits,
            cache_misses=self.cache.misses,
            shed=self._scheduler.shed,
            blocked=self._scheduler.blocked,
            shed_by_bucket=tuple(
                sorted(self._scheduler.shed_by_bucket.items())),
            shed_by_class=tuple(
                sorted(self._scheduler.shed_by_class.items())),
            shed_by_tenant=tuple(
                sorted(self._scheduler.shed_by_tenant.items())),
            shed_deadline=self._scheduler.shed_deadline,
            shed_quota=self._scheduler.shed_quota,
            backend=self.engine.resolve_backend(),
            peer_hits=self.cache.peer_hits,
            peer_misses=self.cache.peer_misses,
            scene_tiles_done=scene.tiles_done if scene else 0,
            scene_tiles_total=scene.tiles_total if scene else 0,
            scene_resumes=scene.resumes if scene else 0,
            scene_stitch_time_s=scene.stitch_time_s if scene else 0.0,
        )

    # ----------------------------------------------------------- lifecycle

    def close(self, timeout: float = 60.0) -> None:
        """Drain queued work, stop the scheduler. Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._scheduler.close(timeout)

    def __enter__(self) -> "YCHGService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------- scheduler callbacks

    def _dispatch(self, bucket: Bucket, requests: List[_Request],
                  batch_size: int) -> YCHGResult:
        t0 = time.monotonic()
        op_key, side, dtype = bucket
        for r in requests:
            # queue wait: admitted -> this flush started assembling. The
            # submitter's t_admitted write may not have landed yet (the
            # scheduler can flush before submit() returns), so fall back
            # through the race-free stamps
            start = r.t_admitted or r.t_gate or r.t_submit
            klass = r.klass or self.config.default_class
            self._recorder.observe_stage("queue_wait", bucket,
                                         max(0.0, t0 - start), klass=klass)
            # class/tenant ride the queue-wait span as metadata: the wait
            # is the one number traffic shaping changes per class
            meta = {"klass": klass}
            if r.tenant is not None:
                meta["tenant"] = r.tenant
            r.trace.add("scheduler.queue_wait", start, t0, **meta)
        stack = pad_stack([r.mask for r in requests], side, batch_size,
                          np.dtype(dtype))
        # the host->device transfer of THIS bucket starts here, while the
        # previous bucket's computation is still in flight
        x = jax.device_put(stack)
        if PIPELINE_SEP in op_key:
            # per-request native (h, w) so each stage's output is re-zeroed
            # outside the request's canvas — exactly what a fresh pad of
            # the cropped intermediate would look like, which is what makes
            # pipeline == sequential bit-exact. Blank pad rows get (0, 0).
            hw = np.zeros((batch_size, 2), np.int32)
            for i, r in enumerate(requests):
                hw[i] = r.mask.shape

            def _stage_span(name: str, s0: float, s1: float) -> None:
                # per-stage pipeline spans (docs/observability.md): one
                # ``pipeline.<op>`` span per stage on every rider's trace,
                # plus a stage histogram keyed by the compound bucket
                self._recorder.observe_stage(f"pipeline.{name}", bucket,
                                             max(0.0, s1 - s0))
                for r in requests:
                    r.trace.add(f"pipeline.{name}", s0, s1)

            result = self.engine.run_pipeline(
                x, split_pipeline_key(op_key), valid_hw=hw,
                on_stage=_stage_span)
        elif op_key == self.engine.op:
            result = self.engine.analyze_batch(x)  # async dispatch
        else:
            result = self.engine.analyze_batch(x, op=op_key)
        t1 = time.monotonic()
        self._recorder.observe_stage("flush", bucket, t1 - t0)
        for r in requests:
            r.t_dispatch = t1
            r.trace.add("scheduler.flush", t0, t1,
                        batch=batch_size, occupancy=len(requests))
        self._recorder.record_batch(
            stack.shape, sum(r.mask.size for r in requests))
        return result

    def _complete(self, result: YCHGResult, requests: List[_Request]) -> None:
        # any escape here would fail the whole slice via the scheduler's
        # retire guard, so the fan-out routes its own failures to _fail —
        # which skips already-fulfilled futures, so a partial fan-out fails
        # only the requests it missed
        try:
            result.block_until_ready()
            now = time.monotonic()
            if requests:
                t_disp = requests[0].t_dispatch or now
                self._recorder.observe_stage(
                    "compute", requests[0].bucket, max(0.0, now - t_disp))
            crop = crop_for(requests[0].bucket[0]) if requests else None
            for row, req in enumerate(requests):
                tc0 = time.monotonic()
                out = crop(result, row, req.mask.shape)
                # atomic with submit's cache-check/coalesce: insert before
                # retiring the leader, so a duplicate in this instant hits
                # the cache instead of re-dispatching the computation
                with self._lock:
                    self.cache.put(req.key, out)
                    self._leaders.pop(req.key, None)
                tc1 = time.monotonic()
                self._recorder.observe_stage("crop", req.bucket, tc1 - tc0)
                self._recorder.record_complete(
                    now - req.t_submit, req.mask.size, len(req.futures),
                    bucket=req.bucket)
                # spans go on BEFORE the futures resolve: a waiter that
                # owns this trace finishes it the moment its future fires
                tr = req.trace
                tr.add("engine.compute", req.t_dispatch or now, now,
                       rows=len(requests))
                tr.add("engine.crop", tc0, tc1, row=row)
                for fut in req.futures:
                    _fulfil(fut, out)
                if req.own_trace:
                    tr.finish()
        except Exception as e:
            self._fail(requests, e)

    def _fail(self, requests: List[_Request], exc: Exception) -> None:
        now = time.monotonic()
        for req in requests:
            with self._lock:
                self._leaders.pop(req.key, None)
            # span before the futures fire, same as _complete: a waiter
            # that owns this trace finishes it as soon as it unblocks
            req.trace.add("service.fail", now, now,
                          error=type(exc).__name__)
            for fut in req.futures:
                if not fut.done() and fut.set_running_or_notify_cancel():
                    fut.set_exception(exc)
            if req.own_trace:
                req.trace.finish()


def _fulfil(fut: Future, value: Any) -> None:
    """Resolve a future the client may have cancelled in the meantime.

    ``submit`` hands out plain ``Future``s that are never marked running,
    so a client-side ``cancel()`` always succeeds; an unguarded
    ``set_result`` would then raise ``InvalidStateError`` inside the
    scheduler thread and kill it — hanging every other outstanding request.
    """
    if fut.set_running_or_notify_cancel():
        fut.set_result(value)


# the canonical name for the multi-op service; YCHGService remains the
# historical (and still accurate: yCHG-first) spelling of the same class
Service = YCHGService

# re-exported here so service-level callers see the error next to the knob
# that produces it
__all__ = ["Service", "ServiceConfig", "ServiceOverloaded", "YCHGService"]
