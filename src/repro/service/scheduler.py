"""The service's dispatch scheduler, extracted and engine-free.

One :class:`Scheduler` thread owns the whole path between "a request was
admitted" and "its job retired": per-bucket pending queues, age/size flush,
the bounded in-flight window, and admission control. It knows nothing about
masks, engines, caches, or futures — callers hand it request objects (any
object with ``.bucket`` and ``.t_submit``) plus three callbacks:

  dispatch(bucket, requests, batch_size) -> handle
      start the batch computation (asynchronously if possible) and return
      an opaque job handle; raising fails exactly those requests;
  complete(handle, requests)
      block until the job is ready and fan results out; called on the
      scheduler thread, never with the scheduler's lock held;
  fail(requests, exc)
      route an error to every request in the slice.

which is what makes the policy logic unit-testable with a fake dispatch
function (`tests/test_scheduler.py`) — no device, no engine, no cache.

Two policies live here:

**Batch-size sub-buckets.** A flush is padded to the smallest power of two
>= its occupancy, capped at ``max_batch`` (:func:`pick_sub_batch`), instead
of always to ``max_batch``: a lone request at low traffic no longer pays
for ``max_batch - 1`` blank images (~8x less pad compute at B=1 with the
default ladder), while compiled shapes stay bounded — the batch dimension
only ever takes the :func:`sub_batch_ladder` values, so the shape budget is
``len(bucket_sides) * (log2(max_batch) + 1)`` per dtype.

**Admission control.** ``max_queue_depth`` bounds admitted-but-unretired
requests. At the bound, ``submit`` either blocks until a retirement frees a
slot (``overload_policy="block"``: backpressure, the producer slows to the
service's pace) or raises :class:`ServiceOverloaded` immediately
(``"shed"``: fail fast, the producer handles the rejection). Shed and
blocked counts are exposed for the service's metrics.

**Per-bucket fairness.** A single global bound is bucket-blind: a flood of
one hot bucket fills the queue and the bound sheds *everyone*, including
the trickle of another bucket that the service could easily serve. Two
mechanisms fix that:

  * ``bucket_queue_depth`` bounds admitted-but-unretired requests *per
    bucket*, with per-bucket shed counters — a flooded bucket sheds
    against its own bound while every other bucket admits freely;
  * ``fair=True`` (the default) serves ready buckets **deficit round
    robin**: the ingest drain banks arrivals first, then each active
    bucket is visited in turn with a quantum of ``max_batch`` request
    credits per round, flushing while its deficit covers the next flush's
    occupancy. A hot bucket with a deep backlog dispatches one batch per
    round, interleaved with everyone else, instead of flushing its whole
    backlog in arrival order ahead of an aged minority request. Banked
    deficit is capped at one quantum beyond the largest flush, so credit
    accrued across rounds can never pay for a peer-starving mega-burst.
    ``fair=False`` keeps the legacy arrival-order flushes so benchmarks
    can measure exactly what fairness buys (``benchmarks/bench_frontend``).

**Traffic classes.** Real traffic is not one crowd: an interactive caller
and an overnight backfill should not compete as equals. ``classes`` names
the priority classes in strict order (first = highest); a request opts in
with a ``.klass`` attribute (default ``default_class``). Scheduling is
**strict priority across classes, DRR within a class**: the dispatch flows
are ``(class, bucket)`` pairs, and ``_serve_ready`` only serves the
highest class that has a ready flow — a lower class dispatches exactly
when no higher class could. Within one class the per-bucket DRR above is
unchanged, so the fairness work of PR 5/6 composes instead of being
replaced. Admission bounds stay class-blind (depth is depth), but every
shed is attributed to its class for the metrics.

**Deadlines.** A request may carry ``.deadline_ms`` — a completion budget,
not a hint. At admission the scheduler predicts this request's completion
delay from the :class:`DrainRate` estimator (the same rolling
completions-per-second window behind the frontend's 429 ``Retry-After``)
as ``(depth + 1) / rate`` and shes with the typed
:class:`DeadlineExceeded` — carrying an honest ``retry_after_s`` — when
the prediction already exceeds the budget. Work that is already dead is
never enqueued; the queue never carries a corpse. A cold estimator (no
completions observed yet) admits: shedding needs evidence.

**Tenant quotas.** A request may carry ``.tenant`` — an identity string.
With ``tenant_rate > 0``, each tenant draws from its own
:class:`TokenBucket` (``tenant_rate`` tokens/s, ``tenant_burst`` burst);
an empty bucket sheds with :class:`TenantQuotaExceeded` and the exact
time until the next token as ``retry_after_s``. Quota and deadline sheds
are **always** sheds, even under ``overload_policy="block"`` — parking a
request that is over quota (or already dead) would grant it the very
capacity the policy denies it.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, Hashable, List, Optional, Tuple

from repro.obs import auto_dump


class ServiceOverloaded(RuntimeError):
    """Submit rejected: the queue is at ``max_queue_depth`` under
    ``overload_policy="shed"``. Typed so producers can catch exactly the
    overload case (retry later, degrade, load-shed upstream) without
    swallowing real errors."""


class DeadlineExceeded(ServiceOverloaded):
    """Submit shed at admission: the drain-rate estimator predicts this
    request would complete after its ``deadline_ms`` budget, so enqueueing
    it would only burn capacity on work that is already dead. Subclasses
    :class:`ServiceOverloaded` so every existing 429 mapping applies;
    ``retry_after_s`` is the honest wait for the backlog the prediction
    blamed."""

    def __init__(self, msg: str, retry_after_s: float):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


class TenantQuotaExceeded(ServiceOverloaded):
    """Submit shed at admission: the request's tenant token bucket is
    empty. ``retry_after_s`` is the exact time until the bucket refills
    one token at ``tenant_rate`` — not an estimate."""

    def __init__(self, msg: str, retry_after_s: float):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


class DrainRate:
    """Rolling completions-per-second estimator with injectable clocks.

    The scheduler feeds it one sample per retirement
    (``observe(completed_total)``); ``rate()`` is the slope across the
    window, ``None`` until two samples with forward progress exist — a
    cold estimator must never justify a shed. Tests pass explicit ``now``
    values so the arithmetic is pinned with synthetic timestamps, never
    wall clocks (the tests/README.md timing policy)."""

    def __init__(self, window: int = 32):
        self._samples: "Deque[Tuple[float, int]]" = deque(maxlen=window)

    def observe(self, completed_total: int,
                now: Optional[float] = None) -> None:
        self._samples.append(
            (time.monotonic() if now is None else now, completed_total))

    def rate(self) -> Optional[float]:
        if len(self._samples) < 2:
            return None
        t0, c0 = self._samples[0]
        t1, c1 = self._samples[-1]
        if t1 <= t0 or c1 <= c0:
            return None
        return (c1 - c0) / (t1 - t0)


class TokenBucket:
    """Per-tenant rate limiter: ``rate`` tokens/s up to ``burst`` banked.

    ``take(now)`` refills by elapsed time, then either spends one token
    (returns ``0.0``: admitted) or returns the seconds until one token
    exists (shed, and the honest ``Retry-After``). The clock is an
    argument, not ``time.monotonic()``, so the refill algebra is testable
    with exact synthetic timestamps."""

    def __init__(self, rate: float, burst: float):
        if rate <= 0:
            raise ValueError(f"rate must be > 0, got {rate}")
        if burst < 1:
            raise ValueError(f"burst must be >= 1, got {burst}")
        self.rate = rate
        self.burst = burst
        self._tokens = burst
        self._last: Optional[float] = None

    def take(self, now: float) -> float:
        if self._last is not None:
            self._tokens = min(
                self.burst, self._tokens + (now - self._last) * self.rate)
        self._last = now
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return 0.0
        return (1.0 - self._tokens) / self.rate


def _clamp_retry(seconds: float) -> float:
    """An honest but bounded Retry-After: never 0 (a tight retry loop),
    never absurd (same clamp as the frontend's 429 estimator)."""
    return min(30.0, max(0.05, seconds))


def pick_sub_batch(occupancy: int, max_batch: int) -> int:
    """Batch size for a flush: smallest power of two >= ``occupancy``,
    capped at ``max_batch`` (so a non-power-of-two ``max_batch`` is itself
    the top rung)."""
    if occupancy < 1:
        raise ValueError(f"occupancy must be >= 1, got {occupancy}")
    b = 1
    while b < occupancy:
        b *= 2
    return min(b, max_batch)


def sub_batch_ladder(max_batch: int) -> Tuple[int, ...]:
    """Every batch size :func:`pick_sub_batch` can return: the powers of
    two below ``max_batch``, then ``max_batch`` — ``log2(max_batch) + 1``
    rungs, the per-(side, dtype) compiled-shape budget."""
    rungs: List[int] = []
    b = 1
    while b < max_batch:
        rungs.append(b)
        b *= 2
    rungs.append(max_batch)
    return tuple(rungs)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Scheduler policy knobs (the service derives this from ServiceConfig).

    max_batch        bucket flush size and the sub-batch ladder's cap.
    max_delay_ms     micro-batching window before a partial flush.
    inflight_jobs    dispatched jobs kept outstanding after a flush — N
                     means N (a >= retire bound made it behave as N-1, so
                     double buffering never overlapped two computations).
                     A flush dispatches before trimming, so N+1 jobs are
                     briefly in flight while the oldest retires: a ready
                     batch is never blocked behind an old computation.
    max_queue_depth  bound on admitted-but-unretired requests; None = no
                     global admission control.
    bucket_queue_depth  the same bound applied PER BUCKET (None = off):
                     a hot bucket sheds/blocks against its own allowance
                     while other buckets keep admitting — the fairness
                     complement to the bucket-blind global bound. Both
                     bounds may be active at once; the bucket bound is
                     checked first and attributed per bucket.
    overload_policy  what submit does at a bound: "block" (wait for a
                     slot) or "shed" (raise ServiceOverloaded).
    sub_batches      pad flushes to the power-of-two ladder (True) or
                     always to max_batch (False, the pre-ladder behaviour,
                     kept for apples-to-apples benchmarking).
    fair             serve ready buckets deficit-round-robin (True, the
                     default: one max_batch-worth of requests per bucket
                     per round) or in arrival order (False, the legacy
                     policy, kept for apples-to-apples benchmarking).
    classes          priority classes in STRICT order, highest first. A
                     request selects one with ``.klass``; dispatch flows
                     are (class, bucket) pairs — strict priority across
                     classes, DRR fairness within one. A single-class
                     config is exactly the pre-class scheduler.
    default_class    the class of a request with no ``.klass`` (must be
                     a member of ``classes``).
    tenant_rate      per-tenant token-bucket refill, requests/second;
                     0.0 disables quotas entirely.
    tenant_burst     per-tenant banked-token cap; 0.0 means
                     ``max(1, tenant_rate)``.
    """

    max_batch: int = 8
    max_delay_ms: float = 2.0
    inflight_jobs: int = 2
    max_queue_depth: Optional[int] = None
    bucket_queue_depth: Optional[int] = None
    overload_policy: str = "block"
    sub_batches: bool = True
    fair: bool = True
    classes: Tuple[str, ...] = ("interactive", "standard", "batch")
    default_class: str = "standard"
    tenant_rate: float = 0.0
    tenant_burst: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "classes", tuple(self.classes))
        if not self.classes or len(set(self.classes)) != len(self.classes):
            raise ValueError(
                f"classes must be a non-empty tuple of unique names, "
                f"got {self.classes!r}")
        if self.default_class not in self.classes:
            raise ValueError(
                f"default_class {self.default_class!r} not in "
                f"classes {self.classes!r}")
        if self.tenant_rate < 0:
            raise ValueError(
                f"tenant_rate must be >= 0, got {self.tenant_rate}")
        if self.tenant_burst < 0:
            raise ValueError(
                f"tenant_burst must be >= 0, got {self.tenant_burst}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.inflight_jobs < 1:
            raise ValueError(
                f"inflight_jobs must be >= 1, got {self.inflight_jobs}")
        if self.max_queue_depth is not None and self.max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1 or None, "
                f"got {self.max_queue_depth}")
        if self.bucket_queue_depth is not None and self.bucket_queue_depth < 1:
            raise ValueError(
                f"bucket_queue_depth must be >= 1 or None, "
                f"got {self.bucket_queue_depth}")
        if self.overload_policy not in ("block", "shed"):
            raise ValueError(
                f"overload_policy must be 'block' or 'shed', "
                f"got {self.overload_policy!r}")


@dataclasses.dataclass
class _Job:
    requests: List[Any]
    handle: Any               # whatever dispatch() returned


_SHUTDOWN = object()


class Scheduler:
    """Bucketed micro-batching dispatch loop with admission control.

    ``submit(request)`` admits (or blocks/sheds) and enqueues; one daemon
    thread drains the queue into per-bucket pending lists, flushes on size
    or age, keeps at most ``inflight_jobs`` dispatched jobs outstanding,
    and retires jobs through the ``complete`` callback. ``close()`` drains
    everything already admitted, then stops the thread.

    Pass ``autostart=False`` to enqueue before the loop runs — tests use
    this to pin exact ingest orderings without sleeps.
    """

    def __init__(self, config: SchedulerConfig,
                 dispatch: Callable[[Hashable, List[Any], int], Any],
                 complete: Callable[[Any, List[Any]], None],
                 fail: Callable[[List[Any], Exception], None],
                 *, autostart: bool = True,
                 max_batch_for: Optional[Callable[[Hashable], int]] = None):
        self.config = config
        # per-bucket flush-size override (the multi-op service derives a
        # bucket's cap from its operator); None = config.max_batch for all.
        # The DRR quantum and deficit cap follow the same per-bucket value,
        # so a small-batch op earns proportionally small rounds.
        self._max_batch_for = max_batch_for
        self._dispatch = dispatch
        self._complete = complete
        self._fail = fail
        self._q: "queue.Queue" = queue.Queue()
        # dispatch flows are (class_index, bucket) pairs: strict priority
        # across the first element, DRR across the second
        self._pending: Dict[Tuple[int, Hashable], List[Any]] = {}
        self._inflight: "Deque[_Job]" = deque()   # scheduler thread only
        # DRR state, scheduler thread only: _rr is the ring of flows with
        # pending requests (activation order), _deficit the per-flow
        # request credits banked across rounds
        self._rr: "Deque[Tuple[int, Hashable]]" = deque()
        self._deficit: Dict[Tuple[int, Hashable], int] = {}
        self._class_index = {k: i for i, k in enumerate(config.classes)}
        self._cond = threading.Condition()
        self._depth = 0       # admitted and not yet retired
        self._depth_by_bucket: Dict[Hashable, int] = {}
        self._shed = 0
        self._shed_by_bucket: Dict[Hashable, int] = {}
        self._shed_by_class: Dict[str, int] = {}
        self._shed_by_tenant: Dict[str, int] = {}
        self._shed_deadline = 0
        self._shed_quota = 0
        self._blocked = 0
        self._completed = 0   # retired requests, feeds the drain estimator
        self._drain_rate = DrainRate()
        self._tenants: Dict[str, TokenBucket] = {}
        self._closed = False
        self._started = False
        self._thread = threading.Thread(
            target=self._loop, name="ychg-scheduler", daemon=True)
        if autostart:
            self.start()

    # ------------------------------------------------------------ admission

    def submit(self, request: Any) -> None:
        """Admit and enqueue one request; called from any thread.

        At ``max_queue_depth`` (global) or ``bucket_queue_depth`` (this
        request's bucket): blocks until a retirement frees a slot (policy
        "block") or raises :class:`ServiceOverloaded` (policy "shed").
        Raises ``RuntimeError`` once closed — including for a blocked
        submitter woken by ``close()``. The blocking park happens inside
        ``Condition.wait``, which RELEASES the lock, so a parked producer
        never deadlocks a concurrent ``close()`` or the completion path
        that must take the lock to free its slot
        (``tests/test_scheduler.py::test_blocked_producers_never_deadlock_close``).
        """
        bucket = getattr(request, "bucket", None)
        klass = self.class_of(request)
        if klass not in self._class_index:
            raise ValueError(
                f"unknown traffic class {klass!r} "
                f"(classes: {self.config.classes!r})")
        tenant = getattr(request, "tenant", None)
        deadline_ms = getattr(request, "deadline_ms", None)
        with self._cond:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            # quota and deadline are ALWAYS shed-at-admission (never
            # block): parking an over-quota or already-dead request
            # would grant it the capacity the check denies it
            if tenant is not None and self.config.tenant_rate > 0:
                wait_s = self._tenant_bucket(tenant).take(time.monotonic())
                if wait_s > 0.0:
                    self._count_shed(bucket, klass, tenant=tenant)
                    self._shed_quota += 1
                    raise TenantQuotaExceeded(
                        f"tenant {tenant!r} over quota "
                        f"(tenant_rate={self.config.tenant_rate}/s): next "
                        f"token in {wait_s:.3f}s",
                        retry_after_s=_clamp_retry(wait_s))
            if deadline_ms is not None:
                predicted_s = self.predicted_wait_s()
                dead = deadline_ms <= 0 or (
                    predicted_s is not None
                    and predicted_s * 1e3 > deadline_ms)
                if dead:
                    late_s = (predicted_s if predicted_s is not None
                              else 0.0) - max(deadline_ms, 0.0) / 1e3
                    self._count_shed(bucket, klass)
                    self._shed_deadline += 1
                    raise DeadlineExceeded(
                        f"deadline {deadline_ms}ms unmeetable: predicted "
                        f"completion delay "
                        f"{0.0 if predicted_s is None else predicted_s:.3f}s "
                        f"behind {self._depth} admitted request(s)",
                        retry_after_s=_clamp_retry(late_s))
            over = self._over_bound(bucket)
            if over is not None:
                if self.config.overload_policy == "shed":
                    self._count_shed(bucket, klass)
                    raise ServiceOverloaded(over)
                self._blocked += 1
                while (self._over_bound(bucket) is not None
                       and not self._closed):
                    self._cond.wait()
                if self._closed:
                    raise RuntimeError("scheduler is closed")
            self._depth += 1
            self._depth_by_bucket[bucket] = (
                self._depth_by_bucket.get(bucket, 0) + 1)
            # enqueue under the lock: close() also puts its sentinel under
            # the lock, so an admitted request can never land behind the
            # sentinel and silently never resolve
            self._q.put(request)

    def _over_bound(self, bucket: Hashable) -> Optional[str]:
        """The admission-rejection message, or None when a slot is free.
        Caller holds the lock. The per-bucket bound is checked first so a
        flooded bucket's rejection is attributed to ITS allowance even
        when the global bound is also at capacity."""
        bbound = self.config.bucket_queue_depth
        if bbound is not None:
            depth = self._depth_by_bucket.get(bucket, 0)
            if depth >= bbound:
                return (f"bucket {bucket!r} depth {depth} at "
                        f"bucket_queue_depth={bbound} "
                        f"(overload_policy='{self.config.overload_policy}')")
        bound = self.config.max_queue_depth
        if bound is not None and self._depth >= bound:
            return (f"queue depth {self._depth} at max_queue_depth="
                    f"{bound} (overload_policy="
                    f"'{self.config.overload_policy}')")
        return None

    def class_of(self, request: Any) -> str:
        """The request's traffic class (``default_class`` when unset)."""
        k = getattr(request, "klass", None)
        return self.config.default_class if k is None else k

    def _flow_of(self, request: Any) -> Tuple[int, Hashable]:
        """The dispatch flow a request belongs to: (class rank, bucket).
        Class validated at submit; an unknown class here (a request that
        bypassed submit) falls back to the default class rather than
        wedging the loop."""
        ci = self._class_index.get(
            self.class_of(request),
            self._class_index[self.config.default_class])
        return (ci, getattr(request, "bucket", None))

    def _tenant_bucket(self, tenant: str) -> TokenBucket:
        """This tenant's token bucket, created on first sight. Caller
        holds the lock."""
        tb = self._tenants.get(tenant)
        if tb is None:
            burst = self.config.tenant_burst or max(
                1.0, self.config.tenant_rate)
            tb = TokenBucket(self.config.tenant_rate, burst)
            self._tenants[tenant] = tb
        return tb

    def _count_shed(self, bucket: Hashable, klass: str,
                    tenant: Optional[str] = None) -> None:
        """Attribute one shed to its bucket, class, and (when the quota
        tripped) tenant. Caller holds the lock."""
        self._shed += 1
        self._shed_by_bucket[bucket] = self._shed_by_bucket.get(bucket, 0) + 1
        self._shed_by_class[klass] = self._shed_by_class.get(klass, 0) + 1
        if tenant is not None:
            self._shed_by_tenant[tenant] = (
                self._shed_by_tenant.get(tenant, 0) + 1)

    def predicted_wait_s(self) -> Optional[float]:
        """Predicted completion delay for a request admitted NOW — the
        admitted-but-unretired depth (plus this request) over the drain
        rate. ``None`` while the estimator is cold (no shed without
        evidence). Caller may hold the lock (reads one int + the
        estimator, which only the completion path mutates)."""
        rate = self._drain_rate.rate()
        if rate is None or rate <= 0:
            return None
        return (self._depth + 1) / rate

    # ------------------------------------------------------------- introspection

    @property
    def shed(self) -> int:
        """Submits rejected with ServiceOverloaded (policy "shed")."""
        with self._cond:
            return self._shed

    @property
    def shed_by_bucket(self) -> Dict[Hashable, int]:
        """Sheds attributed to the rejected request's bucket (all sheds
        carry a bucket, whichever bound tripped)."""
        with self._cond:
            return dict(self._shed_by_bucket)

    @property
    def depth_by_bucket(self) -> Dict[Hashable, int]:
        """Admitted-but-unretired requests per bucket (what
        bucket_queue_depth bounds)."""
        with self._cond:
            return dict(self._depth_by_bucket)

    @property
    def shed_by_class(self) -> Dict[str, int]:
        """Sheds attributed to the rejected request's traffic class
        (every shed carries a class, whichever check tripped)."""
        with self._cond:
            return dict(self._shed_by_class)

    @property
    def shed_by_tenant(self) -> Dict[str, int]:
        """Quota sheds attributed to the over-quota tenant."""
        with self._cond:
            return dict(self._shed_by_tenant)

    @property
    def shed_deadline(self) -> int:
        """Submits shed because the predicted delay exceeded their
        deadline (DeadlineExceeded)."""
        with self._cond:
            return self._shed_deadline

    @property
    def shed_quota(self) -> int:
        """Submits shed by a tenant token bucket (TenantQuotaExceeded)."""
        with self._cond:
            return self._shed_quota

    @property
    def completed_total(self) -> int:
        """Requests retired (completed or failed after dispatch)."""
        with self._cond:
            return self._completed

    @property
    def blocked(self) -> int:
        """Submits that had to wait for a slot (policy "block")."""
        with self._cond:
            return self._blocked

    @property
    def depth(self) -> int:
        """Admitted-but-unretired requests (what max_queue_depth bounds)."""
        with self._cond:
            return self._depth

    def backlog(self) -> int:
        """Requests waiting to be dispatched: queued + pending-in-bucket
        (excludes in-flight jobs, which are already on the device)."""
        with self._cond:
            return self._q.qsize() + sum(
                len(v) for v in self._pending.values())

    # ------------------------------------------------------------- lifecycle

    def start(self) -> None:
        with self._cond:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            self._started = True
        self._thread.start()

    def close(self, timeout: float = 60.0) -> None:
        """Drain admitted work, stop the loop, wake blocked submitters.

        If the loop thread was never started (``autostart=False``), the
        drain runs inline on the caller — an admitted request is never
        silently dropped."""
        with self._cond:
            first = not self._closed
            if first:
                self._closed = True
                self._q.put(_SHUTDOWN)
            self._cond.notify_all()
            started = self._started
        if started:
            self._thread.join(timeout)
        elif first:
            self._drain()

    # --------------------------------------------------------------- the loop

    def _loop(self) -> None:
        # an unhandled escape from the dispatch loop kills the thread and
        # hangs every outstanding future — the least this process can do
        # on the way down is leave the flight recorder's evidence behind
        try:
            self._run_loop()
        except BaseException:
            auto_dump("scheduler-loop-error")
            raise

    def _run_loop(self) -> None:
        served_last = False
        while True:
            with self._cond:
                oldest = (min(rs[0].t_submit for rs in self._pending.values())
                          if self._pending else None)
            if served_last:
                # the last round flushed something, so more flows may be
                # ready NOW (full, or aged): poll the queue without
                # sleeping — this poll between rounds is what lets a
                # higher-class arrival preempt a lower class's backlog at
                # flush granularity
                timeout = 0.0
            elif oldest is not None:
                timeout = max(0.0, oldest + self._delay() - time.monotonic())
            elif self._inflight:
                timeout = 0.0   # work outstanding: poll, don't sleep
            else:
                timeout = 0.1
            try:
                item = self._q.get(timeout=timeout)
            except queue.Empty:
                item = None
            # drain the whole backlog before any age-based flush: under a
            # burst, queued requests are older than max_delay_ms by the
            # time they are seen, and flushing per item would degenerate to
            # one batch per request exactly when batching matters most
            shutdown = False
            ingested = item is not None
            while item is not None:
                if item is _SHUTDOWN:
                    shutdown = True
                    break
                full_flow = self._enqueue_pending(item)
                # legacy (fair=False) flushes a flow the moment it fills,
                # i.e. strictly in arrival order; fair mode banks the whole
                # drain first so _serve_ready can interleave buckets
                if full_flow is not None and not self.config.fair:
                    self._flush(full_flow)
                try:
                    item = self._q.get_nowait()
                except queue.Empty:
                    item = None
            if shutdown:
                break
            served = self._serve_ready()
            served_last = served > 0
            # idle: retire ONE job, then loop back to poll the queue, so a
            # request arriving mid-drain is bucketed after at most one
            # completion instead of waiting behind every outstanding job
            if (not ingested and oldest is None and not served
                    and self._inflight):
                self._retire_one()
        self._drain()

    def _delay(self) -> float:
        return self.config.max_delay_ms / 1e3

    def _max_batch(self, bucket: Hashable) -> int:
        if self._max_batch_for is None:
            return self.config.max_batch
        return self._max_batch_for(bucket)

    def _enqueue_pending(
            self, item: Any) -> Optional[Tuple[int, Hashable]]:
        """Bank one ingested request in its (class, bucket) flow
        (activating the flow in the DRR ring if new); returns the flow
        when it is now full, else None."""
        flow = self._flow_of(item)
        with self._cond:
            reqs = self._pending.get(flow)
            if reqs is None:
                self._pending[flow] = reqs = []
                if flow not in self._rr:
                    self._rr.append(flow)
            reqs.append(item)
            if len(reqs) >= self._max_batch(flow[1]):
                return flow
            return None

    def _ready_flows(self, now: float) -> List[Tuple[int, Hashable]]:
        """Flows due for a flush — full, or oldest request aged past the
        delay window — restricted to the HIGHEST priority class with any
        ready flow (strict priority), in ring (activation) order within
        it. A lower class is served exactly when no higher class is
        ready."""
        delay = self._delay()
        with self._cond:
            ready = {f for f, rs in self._pending.items()
                     if len(rs) >= self._max_batch(f[1])
                     or now - rs[0].t_submit >= delay}
        if not ready:
            return []
        for f in ready:
            if f not in self._rr:   # ring self-repair: a bookkeeping bug
                self._rr.append(f)  # may cost fairness, never liveness
        best = min(ci for ci, _ in ready)
        return [f for f in self._rr if f in ready and f[0] == best]

    def _serve_ready(self) -> int:
        """Serve ONE round over the ready flows; returns flushes made.

        ``_ready_flows`` restricts the round to the highest priority
        class with work ready, and the run loop polls the ingest queue
        between rounds — so an arrival in a higher class preempts a
        lower class's NEXT flush (never an in-progress batch: preemption
        granularity is one flush), even mid-backlog.

        Fair mode is textbook deficit round robin in request units: each
        round visits every ready flow of the serving class once in ring
        order, banks a quantum of ``max_batch`` credits, and flushes
        while the deficit covers the next flush's occupancy — so a bucket
        with a deep backlog dispatches ~one full batch per round,
        interleaved with every other bucket of its class, and an emptied
        flow forfeits its credit (no hoarding). Legacy mode flushes ready
        flows in ring order with no quantum, which together with the
        ingest-time flush-on-full reproduces the old arrival-order
        policy.
        """
        served = 0
        now = time.monotonic()
        ready = self._ready_flows(now)
        if not ready:
            return served
        if not self.config.fair:
            for b in ready:
                self._flush(b)
                served += 1
            return served
        for b in ready:
            # per-bucket quantum: each bucket's round is worth its own
            # max_batch in request credits, and the banked deficit is
            # CAPPED at one quantum beyond the largest possible flush
            # (= that same max_batch): DRR's fairness guarantee is only
            # as good as the bank stays bounded — credit accrued while
            # a bucket sits pending-but-unready must never later pay
            # for a mega-burst that flushes its whole backlog ahead of
            # every other bucket (tests/test_scheduler.py pins the
            # no-mega-burst behavior)
            quantum = self._max_batch(b[1])
            deficit_cap = quantum + quantum
            self._deficit[b] = min(
                self._deficit.get(b, 0) + quantum, deficit_cap)
            while True:
                with self._cond:
                    rs = self._pending.get(b)
                    occ = min(len(rs), quantum) if rs else 0
                    is_ready = rs is not None and (
                        len(rs) >= quantum
                        or now - rs[0].t_submit >= self._delay())
                if not is_ready or self._deficit.get(b, 0) < occ:
                    break
                self._deficit[b] -= occ
                self._flush(b)
                served += 1
        return served

    def _drain(self) -> None:
        """Shutdown drain: ingest everything still admitted, then flush
        flow by flow — class priority first, ring order within a class,
        each flush capped at ``max_batch`` — until nothing is pending,
        and retire every in-flight job."""
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                break
            if item is _SHUTDOWN:
                continue
            self._enqueue_pending(item)
        while True:
            with self._cond:
                # class priority, then ring order, with a direct-listing
                # fallback so a ring bookkeeping bug could only ever cost
                # fairness, not the drain's termination
                ring = {f: i for i, f in enumerate(self._rr)}
                flows = sorted(self._pending,
                               key=lambda f: (f[0], ring.get(f, len(ring))))
            if not flows:
                break
            for flow in flows:
                self._flush(flow)
        while self._inflight:
            self._retire_one()

    def _flush(self, flow: Tuple[int, Hashable]) -> None:
        """Dispatch one batch from a flow at its sub-batch size; keep at
        most ``inflight_jobs`` outstanding. A flush takes at most
        ``max_batch`` requests — anything beyond stays pending (and keeps
        its age), so no flush ever exceeds the compiled-shape ladder.
        The dispatch callback still receives the plain bucket: the class
        is a scheduling concern, not a batching one, and a flush is
        always single-class (flows never mix classes) so padding and
        compiled shapes are untouched."""
        bucket = flow[1]
        max_batch = self._max_batch(bucket)
        with self._cond:
            reqs = self._pending[flow]
            requests = reqs[:max_batch]
            rest = reqs[max_batch:]
            if rest:
                self._pending[flow] = rest
            else:
                del self._pending[flow]
                self._deficit.pop(flow, None)
                try:
                    self._rr.remove(flow)
                except ValueError:
                    pass
        batch = (pick_sub_batch(len(requests), max_batch)
                 if self.config.sub_batches else max_batch)
        try:
            handle = self._dispatch(bucket, requests, batch)
        except Exception as e:   # config/backend errors -> fail this slice
            self._fail(requests, e)
            self._release(requests)
            return
        self._inflight.append(_Job(requests, handle))
        # strictly past the bound: inflight_jobs means N outstanding, not
        # N-1 (a >= here silently halved the double-buffering window)
        while len(self._inflight) > self.config.inflight_jobs:
            self._retire_one()

    def _retire_one(self) -> None:
        job = self._inflight.popleft()
        try:
            self._complete(job.handle, job.requests)
        except Exception as e:   # a raising complete() must not kill the loop
            self._fail(job.requests, e)
        finally:
            self._release(job.requests)

    def _release(self, requests: List[Any]) -> None:
        """Free the admission slots of a retired/failed slice (one bucket
        per slice) and wake any producers parked at a bound."""
        with self._cond:
            self._depth -= len(requests)
            self._completed += len(requests)
            # one drain-rate sample per retirement: the rolling slope of
            # (monotonic, completed_total) is what deadline admission
            # divides depth by
            self._drain_rate.observe(self._completed)
            if requests:
                b = getattr(requests[0], "bucket", None)
                left = self._depth_by_bucket.get(b, 0) - len(requests)
                if left > 0:
                    self._depth_by_bucket[b] = left
                else:
                    self._depth_by_bucket.pop(b, None)
            self._cond.notify_all()
