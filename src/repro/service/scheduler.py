"""The service's dispatch scheduler, extracted and engine-free.

One :class:`Scheduler` thread owns the whole path between "a request was
admitted" and "its job retired": per-bucket pending queues, age/size flush,
the bounded in-flight window, and admission control. It knows nothing about
masks, engines, caches, or futures — callers hand it request objects (any
object with ``.bucket`` and ``.t_submit``) plus three callbacks:

  dispatch(bucket, requests, batch_size) -> handle
      start the batch computation (asynchronously if possible) and return
      an opaque job handle; raising fails exactly those requests;
  complete(handle, requests)
      block until the job is ready and fan results out; called on the
      scheduler thread, never with the scheduler's lock held;
  fail(requests, exc)
      route an error to every request in the slice.

which is what makes the policy logic unit-testable with a fake dispatch
function (`tests/test_scheduler.py`) — no device, no engine, no cache.

Two policies live here:

**Batch-size sub-buckets.** A flush is padded to the smallest power of two
>= its occupancy, capped at ``max_batch`` (:func:`pick_sub_batch`), instead
of always to ``max_batch``: a lone request at low traffic no longer pays
for ``max_batch - 1`` blank images (~8x less pad compute at B=1 with the
default ladder), while compiled shapes stay bounded — the batch dimension
only ever takes the :func:`sub_batch_ladder` values, so the shape budget is
``len(bucket_sides) * (log2(max_batch) + 1)`` per dtype.

**Admission control.** ``max_queue_depth`` bounds admitted-but-unretired
requests. At the bound, ``submit`` either blocks until a retirement frees a
slot (``overload_policy="block"``: backpressure, the producer slows to the
service's pace) or raises :class:`ServiceOverloaded` immediately
(``"shed"``: fail fast, the producer handles the rejection). Shed and
blocked counts are exposed for the service's metrics.

**Per-bucket fairness.** A single global bound is bucket-blind: a flood of
one hot bucket fills the queue and the bound sheds *everyone*, including
the trickle of another bucket that the service could easily serve. Two
mechanisms fix that:

  * ``bucket_queue_depth`` bounds admitted-but-unretired requests *per
    bucket*, with per-bucket shed counters — a flooded bucket sheds
    against its own bound while every other bucket admits freely;
  * ``fair=True`` (the default) serves ready buckets **deficit round
    robin**: the ingest drain banks arrivals first, then each active
    bucket is visited in turn with a quantum of ``max_batch`` request
    credits per round, flushing while its deficit covers the next flush's
    occupancy. A hot bucket with a deep backlog dispatches one batch per
    round, interleaved with everyone else, instead of flushing its whole
    backlog in arrival order ahead of an aged minority request. Banked
    deficit is capped at one quantum beyond the largest flush, so credit
    accrued across rounds can never pay for a peer-starving mega-burst.
    ``fair=False`` keeps the legacy arrival-order flushes so benchmarks
    can measure exactly what fairness buys (``benchmarks/bench_frontend``).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, Hashable, List, Optional, Tuple

from repro.obs import auto_dump


class ServiceOverloaded(RuntimeError):
    """Submit rejected: the queue is at ``max_queue_depth`` under
    ``overload_policy="shed"``. Typed so producers can catch exactly the
    overload case (retry later, degrade, load-shed upstream) without
    swallowing real errors."""


def pick_sub_batch(occupancy: int, max_batch: int) -> int:
    """Batch size for a flush: smallest power of two >= ``occupancy``,
    capped at ``max_batch`` (so a non-power-of-two ``max_batch`` is itself
    the top rung)."""
    if occupancy < 1:
        raise ValueError(f"occupancy must be >= 1, got {occupancy}")
    b = 1
    while b < occupancy:
        b *= 2
    return min(b, max_batch)


def sub_batch_ladder(max_batch: int) -> Tuple[int, ...]:
    """Every batch size :func:`pick_sub_batch` can return: the powers of
    two below ``max_batch``, then ``max_batch`` — ``log2(max_batch) + 1``
    rungs, the per-(side, dtype) compiled-shape budget."""
    rungs: List[int] = []
    b = 1
    while b < max_batch:
        rungs.append(b)
        b *= 2
    rungs.append(max_batch)
    return tuple(rungs)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Scheduler policy knobs (the service derives this from ServiceConfig).

    max_batch        bucket flush size and the sub-batch ladder's cap.
    max_delay_ms     micro-batching window before a partial flush.
    inflight_jobs    dispatched jobs kept outstanding after a flush — N
                     means N (a >= retire bound made it behave as N-1, so
                     double buffering never overlapped two computations).
                     A flush dispatches before trimming, so N+1 jobs are
                     briefly in flight while the oldest retires: a ready
                     batch is never blocked behind an old computation.
    max_queue_depth  bound on admitted-but-unretired requests; None = no
                     global admission control.
    bucket_queue_depth  the same bound applied PER BUCKET (None = off):
                     a hot bucket sheds/blocks against its own allowance
                     while other buckets keep admitting — the fairness
                     complement to the bucket-blind global bound. Both
                     bounds may be active at once; the bucket bound is
                     checked first and attributed per bucket.
    overload_policy  what submit does at a bound: "block" (wait for a
                     slot) or "shed" (raise ServiceOverloaded).
    sub_batches      pad flushes to the power-of-two ladder (True) or
                     always to max_batch (False, the pre-ladder behaviour,
                     kept for apples-to-apples benchmarking).
    fair             serve ready buckets deficit-round-robin (True, the
                     default: one max_batch-worth of requests per bucket
                     per round) or in arrival order (False, the legacy
                     policy, kept for apples-to-apples benchmarking).
    """

    max_batch: int = 8
    max_delay_ms: float = 2.0
    inflight_jobs: int = 2
    max_queue_depth: Optional[int] = None
    bucket_queue_depth: Optional[int] = None
    overload_policy: str = "block"
    sub_batches: bool = True
    fair: bool = True

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.inflight_jobs < 1:
            raise ValueError(
                f"inflight_jobs must be >= 1, got {self.inflight_jobs}")
        if self.max_queue_depth is not None and self.max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1 or None, "
                f"got {self.max_queue_depth}")
        if self.bucket_queue_depth is not None and self.bucket_queue_depth < 1:
            raise ValueError(
                f"bucket_queue_depth must be >= 1 or None, "
                f"got {self.bucket_queue_depth}")
        if self.overload_policy not in ("block", "shed"):
            raise ValueError(
                f"overload_policy must be 'block' or 'shed', "
                f"got {self.overload_policy!r}")


@dataclasses.dataclass
class _Job:
    requests: List[Any]
    handle: Any               # whatever dispatch() returned


_SHUTDOWN = object()


class Scheduler:
    """Bucketed micro-batching dispatch loop with admission control.

    ``submit(request)`` admits (or blocks/sheds) and enqueues; one daemon
    thread drains the queue into per-bucket pending lists, flushes on size
    or age, keeps at most ``inflight_jobs`` dispatched jobs outstanding,
    and retires jobs through the ``complete`` callback. ``close()`` drains
    everything already admitted, then stops the thread.

    Pass ``autostart=False`` to enqueue before the loop runs — tests use
    this to pin exact ingest orderings without sleeps.
    """

    def __init__(self, config: SchedulerConfig,
                 dispatch: Callable[[Hashable, List[Any], int], Any],
                 complete: Callable[[Any, List[Any]], None],
                 fail: Callable[[List[Any], Exception], None],
                 *, autostart: bool = True,
                 max_batch_for: Optional[Callable[[Hashable], int]] = None):
        self.config = config
        # per-bucket flush-size override (the multi-op service derives a
        # bucket's cap from its operator); None = config.max_batch for all.
        # The DRR quantum and deficit cap follow the same per-bucket value,
        # so a small-batch op earns proportionally small rounds.
        self._max_batch_for = max_batch_for
        self._dispatch = dispatch
        self._complete = complete
        self._fail = fail
        self._q: "queue.Queue" = queue.Queue()
        self._pending: Dict[Hashable, List[Any]] = {}
        self._inflight: "Deque[_Job]" = deque()   # scheduler thread only
        # DRR state, scheduler thread only: _rr is the ring of buckets with
        # pending requests (activation order), _deficit the per-bucket
        # request credits banked across rounds
        self._rr: "Deque[Hashable]" = deque()
        self._deficit: Dict[Hashable, int] = {}
        self._cond = threading.Condition()
        self._depth = 0       # admitted and not yet retired
        self._depth_by_bucket: Dict[Hashable, int] = {}
        self._shed = 0
        self._shed_by_bucket: Dict[Hashable, int] = {}
        self._blocked = 0
        self._closed = False
        self._started = False
        self._thread = threading.Thread(
            target=self._loop, name="ychg-scheduler", daemon=True)
        if autostart:
            self.start()

    # ------------------------------------------------------------ admission

    def submit(self, request: Any) -> None:
        """Admit and enqueue one request; called from any thread.

        At ``max_queue_depth`` (global) or ``bucket_queue_depth`` (this
        request's bucket): blocks until a retirement frees a slot (policy
        "block") or raises :class:`ServiceOverloaded` (policy "shed").
        Raises ``RuntimeError`` once closed — including for a blocked
        submitter woken by ``close()``. The blocking park happens inside
        ``Condition.wait``, which RELEASES the lock, so a parked producer
        never deadlocks a concurrent ``close()`` or the completion path
        that must take the lock to free its slot
        (``tests/test_scheduler.py::test_blocked_producers_never_deadlock_close``).
        """
        bucket = getattr(request, "bucket", None)
        with self._cond:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            over = self._over_bound(bucket)
            if over is not None:
                if self.config.overload_policy == "shed":
                    self._shed += 1
                    self._shed_by_bucket[bucket] = (
                        self._shed_by_bucket.get(bucket, 0) + 1)
                    raise ServiceOverloaded(over)
                self._blocked += 1
                while (self._over_bound(bucket) is not None
                       and not self._closed):
                    self._cond.wait()
                if self._closed:
                    raise RuntimeError("scheduler is closed")
            self._depth += 1
            self._depth_by_bucket[bucket] = (
                self._depth_by_bucket.get(bucket, 0) + 1)
            # enqueue under the lock: close() also puts its sentinel under
            # the lock, so an admitted request can never land behind the
            # sentinel and silently never resolve
            self._q.put(request)

    def _over_bound(self, bucket: Hashable) -> Optional[str]:
        """The admission-rejection message, or None when a slot is free.
        Caller holds the lock. The per-bucket bound is checked first so a
        flooded bucket's rejection is attributed to ITS allowance even
        when the global bound is also at capacity."""
        bbound = self.config.bucket_queue_depth
        if bbound is not None:
            depth = self._depth_by_bucket.get(bucket, 0)
            if depth >= bbound:
                return (f"bucket {bucket!r} depth {depth} at "
                        f"bucket_queue_depth={bbound} "
                        f"(overload_policy='{self.config.overload_policy}')")
        bound = self.config.max_queue_depth
        if bound is not None and self._depth >= bound:
            return (f"queue depth {self._depth} at max_queue_depth="
                    f"{bound} (overload_policy="
                    f"'{self.config.overload_policy}')")
        return None

    # ------------------------------------------------------------- introspection

    @property
    def shed(self) -> int:
        """Submits rejected with ServiceOverloaded (policy "shed")."""
        with self._cond:
            return self._shed

    @property
    def shed_by_bucket(self) -> Dict[Hashable, int]:
        """Sheds attributed to the rejected request's bucket (all sheds
        carry a bucket, whichever bound tripped)."""
        with self._cond:
            return dict(self._shed_by_bucket)

    @property
    def depth_by_bucket(self) -> Dict[Hashable, int]:
        """Admitted-but-unretired requests per bucket (what
        bucket_queue_depth bounds)."""
        with self._cond:
            return dict(self._depth_by_bucket)

    @property
    def blocked(self) -> int:
        """Submits that had to wait for a slot (policy "block")."""
        with self._cond:
            return self._blocked

    @property
    def depth(self) -> int:
        """Admitted-but-unretired requests (what max_queue_depth bounds)."""
        with self._cond:
            return self._depth

    def backlog(self) -> int:
        """Requests waiting to be dispatched: queued + pending-in-bucket
        (excludes in-flight jobs, which are already on the device)."""
        with self._cond:
            return self._q.qsize() + sum(
                len(v) for v in self._pending.values())

    # ------------------------------------------------------------- lifecycle

    def start(self) -> None:
        with self._cond:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            self._started = True
        self._thread.start()

    def close(self, timeout: float = 60.0) -> None:
        """Drain admitted work, stop the loop, wake blocked submitters.

        If the loop thread was never started (``autostart=False``), the
        drain runs inline on the caller — an admitted request is never
        silently dropped."""
        with self._cond:
            first = not self._closed
            if first:
                self._closed = True
                self._q.put(_SHUTDOWN)
            self._cond.notify_all()
            started = self._started
        if started:
            self._thread.join(timeout)
        elif first:
            self._drain()

    # --------------------------------------------------------------- the loop

    def _loop(self) -> None:
        # an unhandled escape from the dispatch loop kills the thread and
        # hangs every outstanding future — the least this process can do
        # on the way down is leave the flight recorder's evidence behind
        try:
            self._run_loop()
        except BaseException:
            auto_dump("scheduler-loop-error")
            raise

    def _run_loop(self) -> None:
        while True:
            with self._cond:
                oldest = (min(rs[0].t_submit for rs in self._pending.values())
                          if self._pending else None)
            if oldest is not None:
                timeout = max(0.0, oldest + self._delay() - time.monotonic())
            elif self._inflight:
                timeout = 0.0   # work outstanding: poll, don't sleep
            else:
                timeout = 0.1
            try:
                item = self._q.get(timeout=timeout)
            except queue.Empty:
                item = None
            # drain the whole backlog before any age-based flush: under a
            # burst, queued requests are older than max_delay_ms by the
            # time they are seen, and flushing per item would degenerate to
            # one batch per request exactly when batching matters most
            shutdown = False
            ingested = item is not None
            while item is not None:
                if item is _SHUTDOWN:
                    shutdown = True
                    break
                full = self._enqueue_pending(item)
                # legacy (fair=False) flushes a bucket the moment it fills,
                # i.e. strictly in arrival order; fair mode banks the whole
                # drain first so _serve_ready can interleave buckets
                if full and not self.config.fair:
                    self._flush(item.bucket)
                try:
                    item = self._q.get_nowait()
                except queue.Empty:
                    item = None
            if shutdown:
                break
            served = self._serve_ready()
            # idle: retire ONE job, then loop back to poll the queue, so a
            # request arriving mid-drain is bucketed after at most one
            # completion instead of waiting behind every outstanding job
            if (not ingested and oldest is None and not served
                    and self._inflight):
                self._retire_one()
        self._drain()

    def _delay(self) -> float:
        return self.config.max_delay_ms / 1e3

    def _max_batch(self, bucket: Hashable) -> int:
        if self._max_batch_for is None:
            return self.config.max_batch
        return self._max_batch_for(bucket)

    def _enqueue_pending(self, item: Any) -> bool:
        """Bank one ingested request in its bucket (activating the bucket
        in the DRR ring if new); True when the bucket is now full."""
        with self._cond:
            reqs = self._pending.get(item.bucket)
            if reqs is None:
                self._pending[item.bucket] = reqs = []
                if item.bucket not in self._rr:
                    self._rr.append(item.bucket)
            reqs.append(item)
            return len(reqs) >= self._max_batch(item.bucket)

    def _ready_buckets(self, now: float) -> List[Hashable]:
        """Buckets due for a flush — full, or oldest request aged past the
        delay window — in ring (activation) order."""
        delay = self._delay()
        with self._cond:
            ready = {b for b, rs in self._pending.items()
                     if len(rs) >= self._max_batch(b)
                     or now - rs[0].t_submit >= delay}
        for b in ready:
            if b not in self._rr:   # ring self-repair: a bookkeeping bug
                self._rr.append(b)  # may cost fairness, never liveness
        return [b for b in self._rr if b in ready]

    def _serve_ready(self) -> int:
        """Flush every ready bucket; returns the number of flushes.

        Fair mode is textbook deficit round robin in request units: each
        outer round visits every ready bucket once in ring order, banks a
        quantum of ``max_batch`` credits, and flushes while the deficit
        covers the next flush's occupancy — so a bucket with a deep
        backlog dispatches ~one full batch per round, interleaved with
        every other bucket, and an emptied bucket forfeits its credit
        (no hoarding). Legacy mode flushes ready buckets in ring order
        with no quantum, which together with the ingest-time
        flush-on-full reproduces the old arrival-order policy.
        """
        served = 0
        while True:
            now = time.monotonic()
            ready = self._ready_buckets(now)
            if not ready:
                return served
            if not self.config.fair:
                for b in ready:
                    self._flush(b)
                    served += 1
                continue
            for b in ready:
                # per-bucket quantum: each bucket's round is worth its own
                # max_batch in request credits, and the banked deficit is
                # CAPPED at one quantum beyond the largest possible flush
                # (= that same max_batch): DRR's fairness guarantee is only
                # as good as the bank stays bounded — credit accrued while
                # a bucket sits pending-but-unready must never later pay
                # for a mega-burst that flushes its whole backlog ahead of
                # every other bucket (tests/test_scheduler.py pins the
                # no-mega-burst behavior)
                quantum = self._max_batch(b)
                deficit_cap = quantum + quantum
                self._deficit[b] = min(
                    self._deficit.get(b, 0) + quantum, deficit_cap)
                while True:
                    with self._cond:
                        rs = self._pending.get(b)
                        occ = min(len(rs), quantum) if rs else 0
                        is_ready = rs is not None and (
                            len(rs) >= quantum
                            or now - rs[0].t_submit >= self._delay())
                    if not is_ready or self._deficit.get(b, 0) < occ:
                        break
                    self._deficit[b] -= occ
                    self._flush(b)
                    served += 1

    def _drain(self) -> None:
        """Shutdown drain: ingest everything still admitted, then flush
        bucket by bucket in ring order (each flush capped at ``max_batch``)
        until nothing is pending, and retire every in-flight job."""
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                break
            if item is _SHUTDOWN:
                continue
            self._enqueue_pending(item)
        while True:
            with self._cond:
                # ring order, with a direct-listing fallback so a ring
                # bookkeeping bug could only ever cost fairness, not the
                # drain's termination
                buckets = ([b for b in self._rr if b in self._pending]
                           or list(self._pending))
            if not buckets:
                break
            for bucket in buckets:
                self._flush(bucket)
        while self._inflight:
            self._retire_one()

    def _flush(self, bucket: Hashable) -> None:
        """Dispatch one batch from a bucket at its sub-batch size; keep at
        most ``inflight_jobs`` outstanding. A flush takes at most
        ``max_batch`` requests — anything beyond stays pending (and keeps
        its age), so no flush ever exceeds the compiled-shape ladder."""
        max_batch = self._max_batch(bucket)
        with self._cond:
            reqs = self._pending[bucket]
            requests = reqs[:max_batch]
            rest = reqs[max_batch:]
            if rest:
                self._pending[bucket] = rest
            else:
                del self._pending[bucket]
                self._deficit.pop(bucket, None)
                try:
                    self._rr.remove(bucket)
                except ValueError:
                    pass
        batch = (pick_sub_batch(len(requests), max_batch)
                 if self.config.sub_batches else max_batch)
        try:
            handle = self._dispatch(bucket, requests, batch)
        except Exception as e:   # config/backend errors -> fail this slice
            self._fail(requests, e)
            self._release(requests)
            return
        self._inflight.append(_Job(requests, handle))
        # strictly past the bound: inflight_jobs means N outstanding, not
        # N-1 (a >= here silently halved the double-buffering window)
        while len(self._inflight) > self.config.inflight_jobs:
            self._retire_one()

    def _retire_one(self) -> None:
        job = self._inflight.popleft()
        try:
            self._complete(job.handle, job.requests)
        except Exception as e:   # a raising complete() must not kill the loop
            self._fail(job.requests, e)
        finally:
            self._release(job.requests)

    def _release(self, requests: List[Any]) -> None:
        """Free the admission slots of a retired/failed slice (one bucket
        per slice) and wake any producers parked at a bound."""
        with self._cond:
            self._depth -= len(requests)
            if requests:
                b = getattr(requests[0], "bucket", None)
                left = self._depth_by_bucket.get(b, 0) - len(requests)
                if left > 0:
                    self._depth_by_bucket[b] = left
                else:
                    self._depth_by_bucket.pop(b, None)
            self._cond.notify_all()
