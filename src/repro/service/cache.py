"""Content-addressed LRU result cache for the yCHG service.

The key is a pure function of everything that determines the answer:

  (blake2b(mask bytes), shape, dtype, resolved backend name, engine config)

Shape and dtype are part of the key because the raw byte string does not
determine them — the same 32 bytes are a (4, 8) or an (8, 4) mask, and an
int8 view of a uint8 buffer is a different request even though the bytes
match. Backend and config are part of the key because the service promises
results identical to ``engine.analyze`` under *that* engine's policy; two
services with different policies may share one cache without ever serving
each other's entries.

Values are device-resident ``YCHGResult`` objects (immutable pytrees), so a
hit returns the exact cached object — no copy, no host round-trip, and
crucially no backend invocation (``tests/test_service.py`` asserts this via
the registry call counters).
"""

from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from typing import Any, Hashable, Optional, Tuple

import numpy as np

CacheKey = Tuple[bytes, tuple, str, str, Any, Any]


def make_key(mask: np.ndarray, backend: str, config: Hashable,
             mesh: Optional[Hashable] = None) -> CacheKey:
    """Content-address a host mask under a resolved (backend, config) policy.

    ``mask`` must be C-contiguous (the service canonicalises on submit);
    ``config`` any hashable policy object (``YCHGConfig`` is frozen);
    ``mesh`` the engine's attached device mesh, if any — a meshed engine's
    results carry a different device layout than an unmeshed one, so the
    two must never serve each other's entries through a shared cache.
    """
    digest = hashlib.blake2b(mask.tobytes(), digest_size=16).digest()
    return (digest, mask.shape, str(mask.dtype), backend, config, mesh)


class ResultCache:
    """Thread-safe LRU over :func:`make_key` keys with hit/miss counters.

    ``capacity`` is an entry count; 0 disables the cache entirely (every
    ``get`` is a miss, ``put`` is a no-op) so the service can run cacheless
    without branching at every call site.
    """

    def __init__(self, capacity: int = 1024):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self._entries: "OrderedDict[CacheKey, Any]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def get(self, key: CacheKey) -> Optional[Any]:
        with self._lock:
            try:
                value = self._entries[key]
            except KeyError:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return value

    def put(self, key: CacheKey, value: Any) -> None:
        if self.capacity == 0:
            return
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
