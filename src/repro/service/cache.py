"""Content-addressed LRU result cache for the image-operator service.

The key is a pure function of everything that determines the answer:

  (blake2b(mask bytes), shape, dtype, resolved backend name, engine config,
   mesh, op)

Shape and dtype are part of the key because the raw byte string does not
determine them — the same 32 bytes are a (4, 8) or an (8, 4) mask, and an
int8 view of a uint8 buffer is a different request even though the bytes
match. Backend and config are part of the key because the service promises
results identical to ``engine.analyze`` under *that* engine's policy; two
services with different policies may share one cache without ever serving
each other's entries. ``op`` is part of the key because the same mask
under a different operator (or an ordered pipeline of operators, keyed as
``"denoise+ychg"``) is a different answer entirely.

Values are device-resident result pytrees (``YCHGResult``, ``CCLResult``,
...), so a hit returns the exact cached object — no copy, no host
round-trip, and crucially no backend invocation (``tests/test_service.py``
asserts this via the registry call counters).
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import Any, Dict, Hashable, Optional, Tuple

import numpy as np

CacheKey = Tuple[bytes, tuple, str, str, Any, Any, str]


def make_key(mask: np.ndarray, backend: str, config: Hashable,
             mesh: Optional[Hashable] = None, *,
             op: str = "ychg") -> CacheKey:
    """Content-address a host mask under a resolved (backend, config) policy.

    ``mask`` must be C-contiguous (the service canonicalises on submit);
    ``config`` any hashable policy object (``YCHGConfig`` is frozen);
    ``mesh`` the engine's attached device mesh, if any — a meshed engine's
    results carry a different device layout than an unmeshed one, so the
    two must never serve each other's entries through a shared cache;
    ``op`` the operator (or ``"+"``-joined pipeline spec) the entry
    answers for — the same mask under a different op is a different key.
    """
    digest = hashlib.blake2b(mask.tobytes(), digest_size=16).digest()
    return (digest, mask.shape, str(mask.dtype), backend, config, mesh, op)


def _canon(obj: Any) -> bytes:
    """A process-stable byte rendering of one key component.

    Dataclass configs (``YCHGConfig``) render as class name + sorted
    ``field=repr(value)`` pairs — reprs of str/int/float/bool/None are
    deterministic across interpreters, unlike ``hash()``. Anything else
    falls back to ``repr`` (stable for the primitives that actually appear
    in keys; an attached device mesh has no stable rendering, which is why
    fleet workers run unmeshed engines).
    """
    if obj is None:
        return b"none"
    if isinstance(obj, bytes):
        return obj
    if isinstance(obj, str):
        return obj.encode()
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        fields = sorted(
            (f.name, repr(getattr(obj, f.name)))
            for f in dataclasses.fields(obj))
        return (type(obj).__name__ + ":" +
                ",".join(f"{n}={v}" for n, v in fields)).encode()
    return repr(obj).encode()


def serialize_key(key: CacheKey) -> bytes:
    """A canonical, PROCESS-STABLE byte string for a :func:`make_key` tuple.

    The in-process tuple key relies on per-process ``hash()`` (randomised
    by PYTHONHASHSEED), so it can never cross a process boundary; this
    rendering is what the fleet router consistent-hashes on and what
    sibling caches look each other's entries up by — identical
    (mask, backend, config, op) must produce identical bytes in every
    worker, across restarts (``tests/test_fleet.py`` pins this with a
    different-PYTHONHASHSEED subprocess). Components are length-prefixed
    so no two distinct keys can collide by concatenation, and the format
    is VERSIONED: v2 added the length-prefixed ``op`` component, and the
    bumped prefix means a v1 worker and a v2 worker in a mixed-version
    fleet can never alias each other's entries — every v2 key differs
    from every v1 key in its first component.
    """
    digest, shape, dtype, backend, config, mesh, op = key
    parts = (
        b"ychg-key-v2",
        _canon(op),
        digest,
        "x".join(str(int(s)) for s in shape).encode(),
        _canon(dtype),
        _canon(backend),
        _canon(config),
        _canon(mesh),
    )
    return b"".join(len(p).to_bytes(4, "big") + p for p in parts)


class ResultCache:
    """Thread-safe LRU over :func:`make_key` keys with hit/miss counters.

    ``capacity`` is an entry count; 0 disables the cache entirely (every
    ``get`` is a miss, ``put`` is a no-op) so the service can run cacheless
    without branching at every call site.

    ``index_serialized=True`` additionally indexes every entry by its
    :func:`serialize_key` bytes so a *sibling process* can look entries up
    over the RPC ``cache_probe`` verb (``probe_serialized``) — fleet
    workers run with it on; the single-process default stays off and pays
    nothing. ``peer_probe`` is the outbound half: the base class never
    peers (returns None); ``repro.fleet.peering.PeeredResultCache``
    overrides it to ask siblings before the service pays compute.
    ``peer_hits``/``peer_misses`` count those outbound probes.
    """

    def __init__(self, capacity: int = 1024, *,
                 index_serialized: bool = False):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self.index_serialized = index_serialized
        self._entries: "OrderedDict[CacheKey, Any]" = OrderedDict()
        self._by_serialized: Dict[bytes, CacheKey] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.peer_hits = 0
        self.peer_misses = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def get(self, key: CacheKey) -> Optional[Any]:
        with self._lock:
            try:
                value = self._entries[key]
            except KeyError:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return value

    def put(self, key: CacheKey, value: Any) -> None:
        if self.capacity == 0:
            return
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            if self.index_serialized:
                self._by_serialized[serialize_key(key)] = key
            while len(self._entries) > self.capacity:
                evicted, _ = self._entries.popitem(last=False)
                if self.index_serialized:
                    self._by_serialized.pop(serialize_key(evicted), None)

    def probe_serialized(self, skey: bytes) -> Optional[Any]:
        """Inbound sibling lookup by serialized key; purely local — a probe
        never recurses into ``peer_probe`` and never counts toward the
        local hit/miss rate (it is the *sibling's* miss, not ours)."""
        with self._lock:
            key = self._by_serialized.get(skey)
            if key is None:
                return None
            value = self._entries.get(key)
            if value is not None:
                self._entries.move_to_end(key)
            return value

    def peer_probe(self, key: CacheKey) -> Optional[Any]:
        """Outbound sibling probe on a local miss. Base: no peers."""
        return None

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._by_serialized.clear()
