"""Host data pipeline: background prefetch + the yCHG preprocessing operator.

The yCHG operator is where the paper's technique is a first-class framework
feature: mask tiles flow through ``ychg_stats`` (two-step algorithm on
device) and the resulting per-tile ROI statistics drive (a) filtering —
empty tiles are dropped before they reach a model, and (b) anyres tile
selection for the VLM frontend — tiles are ranked by hyperedge density
(boundary complexity), which is a cheap O(HW) proxy for "interesting
structure" that the llava-style frontend uses to pick which crops to encode.
"""

from __future__ import annotations

import functools
import queue
import threading
from typing import TYPE_CHECKING, Dict, Iterator, List, Optional

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from repro.engine import Engine


class Prefetcher:
    """Runs an iterator in a background thread with a bounded queue.

    Straggler note: on a real cluster the get() timeout is the per-step
    data deadline; a timeout surfaces as StopIteration + a counter that the
    training loop reports (see train/loop.py) rather than a hang.
    """

    def __init__(self, it: Iterator, depth: int = 2, timeout: float = 300.0):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.timeout = timeout
        self._done = object()
        self.timeouts = 0

        def run():
            try:
                for item in it:
                    self.q.put(item)
            finally:
                self.q.put(self._done)

        self.thread = threading.Thread(target=run, daemon=True)
        self.thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        try:
            item = self.q.get(timeout=self.timeout)
        except queue.Empty:
            self.timeouts += 1
            raise StopIteration
        if item is self._done:
            raise StopIteration
        return item


# legacy backend names accepted by ychg_stats, mapped to engine backends
_STATS_BACKENDS = {"auto": "auto", "fused": "fused", "jnp": "jax"}


@functools.lru_cache(maxsize=None)
def _default_engine(backend: str) -> "Engine":
    from repro.engine import Engine, YCHGConfig

    return Engine(YCHGConfig(backend=backend))


def ychg_stats(masks: np.ndarray, backend: str = "auto", *,
               engine: Optional["Engine"] = None) -> Dict[str, np.ndarray]:
    """(B,H,W) uint8 -> per-tile ROI statistics via the two-step algorithm.

    Pass ``engine`` (a ``repro.engine.Engine``) to control dispatch —
    the whole batch runs as one device computation under that engine's
    policy (fused = ONE Pallas kernel launch per batch, no per-image
    step-1/step-2 round-trip). Without an engine, the legacy ``backend``
    string picks a cached default engine: "auto" resolves per platform
    (fused on TPU, jit'd jnp elsewhere), "fused"/"jnp" force those paths.
    All are bit-identical.
    """
    if engine is None:
        try:
            engine = _default_engine(_STATS_BACKENDS[backend])
        except KeyError:
            raise ValueError(
                f"unknown backend {backend!r}; expected 'auto', 'fused', or 'jnp'"
            ) from None
    s = engine.analyze_batch(masks)
    return {
        "n_hyperedges": np.asarray(s.n_hyperedges),
        "n_transitions": np.asarray(s.n_transitions),
        "coverage": np.asarray(masks).mean(axis=(-1, -2)),
    }


def filter_empty_tiles(masks: np.ndarray, min_hyperedges: int = 1,
                       backend: str = "auto",
                       stats: Optional[Dict[str, np.ndarray]] = None,
                       engine: Optional["Engine"] = None
                       ) -> np.ndarray:
    """Drop tiles whose ROI has no hyperedges (paper's step 1+2 as a filter).

    Pass ``stats`` (a prior ``ychg_stats`` result for the same masks) to
    filter without recomputing — callers that already ran the operator for
    ranking should not pay a second kernel launch."""
    if stats is None:
        stats = ychg_stats(masks, backend=backend, engine=engine)
    keep = stats["n_hyperedges"] >= min_hyperedges
    return masks[keep]


def anyres_select(image: np.ndarray, tile: int, k: int) -> List[tuple]:
    """llava-next anyres: split image into (tile x tile) crops, return the k
    crop offsets with the highest yCHG hyperedge density (boundary-complexity
    ranking). Returns [(y, x), ...]."""
    h, w = image.shape
    ys = range(0, h - tile + 1, tile)
    xs = range(0, w - tile + 1, tile)
    crops, offs = [], []
    for y in ys:
        for x in xs:
            crops.append(image[y : y + tile, x : x + tile])
            offs.append((y, x))
    if not crops:
        return [(0, 0)]
    stats = ychg_stats(np.stack(crops))
    order = np.argsort(-stats["n_hyperedges"])
    return [offs[i] for i in order[:k]]
