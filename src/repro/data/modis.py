"""Synthetic MODIS-like binary masks with the paper's experimental knobs.

The paper evaluates on MODIS/Terra snow-cover L3 500m grids [ref 4], varying
(a) resolution at fixed hyperedge structure (cropping/scaling a 21000x21000
scene) and (b) hyperedge count at fixed resolution (147 -> 4,124,319). The
dataset is not redistributable offline, so this module synthesises masks
with exactly those controllables:

  * ``snowfield(res, seed)`` — smooth blobby coverage (low-frequency
    thresholded noise), hyperedge count roughly constant as resolution
    scales (structure scales with the image, like cropping a real scene);
  * ``striped(res, n_hyperedges)`` — deterministic vertical-run pattern
    hitting an exact target hyperedge count (the paper's knob (b)): stripes
    of alternating runs give one hyperedge per (row-band, col-band) cell.

Both return uint8 (H, W) masks. NumPy host-side; the pipeline ships them to
device as uint8 tiles.
"""

from __future__ import annotations

import numpy as np


def snowfield(res: int, seed: int = 0, coverage: float = 0.45,
              octaves: int = 4) -> np.ndarray:
    """Smooth multi-octave noise threshold -> blobby snow-cover-like mask."""
    rng = np.random.default_rng(seed)
    acc = np.zeros((res, res), np.float32)
    for o in range(octaves):
        n = max(2, res >> (octaves - o + 2))
        coarse = rng.standard_normal((n, n)).astype(np.float32)
        # bilinear upsample to res
        yi = np.linspace(0, n - 1, res)
        xi = np.linspace(0, n - 1, res)
        y0 = np.floor(yi).astype(int); y1 = np.minimum(y0 + 1, n - 1)
        x0 = np.floor(xi).astype(int); x1 = np.minimum(x0 + 1, n - 1)
        wy = (yi - y0)[:, None]; wx = (xi - x0)[None, :]
        up = (
            coarse[np.ix_(y0, x0)] * (1 - wy) * (1 - wx)
            + coarse[np.ix_(y1, x0)] * wy * (1 - wx)
            + coarse[np.ix_(y0, x1)] * (1 - wy) * wx
            + coarse[np.ix_(y1, x1)] * wy * wx
        )
        acc += up / (2.0**o)
    thr = np.quantile(acc, 1.0 - coverage)
    return (acc > thr).astype(np.uint8)


def striped(res: int, n_hyperedges: int) -> np.ndarray:
    """Deterministic mask with ~exactly ``n_hyperedges`` yConvex hyperedges.

    Grid of (rb x cb) cells, each cell a solid rectangle separated by blank
    rows/cols: each rectangle is one y-convex hyperedge (runs appear at its
    left edge and die at its right edge). rb*cb >= n_hyperedges; surplus
    cells are blanked to hit the target exactly.
    """
    assert n_hyperedges >= 0
    img = np.zeros((res, res), np.uint8)
    if n_hyperedges == 0:
        return img
    side = int(np.ceil(np.sqrt(n_hyperedges)))
    # cell size: at least 2 px (1 filled + 1 blank separator)
    if 2 * side > res:
        raise ValueError(
            f"resolution {res} too small for {n_hyperedges} hyperedges"
        )
    cell = res // side
    fill = max(1, cell - 1)
    placed = 0
    for r in range(side):
        for c in range(side):
            if placed >= n_hyperedges:
                break
            y0, x0 = r * cell, c * cell
            img[y0 : y0 + fill, x0 : x0 + fill] = 1
            placed += 1
    return img


def resolution_series(base: int = 1000, stop: int = 21000, num: int = 8):
    """The paper's knob (a): resolutions from small to the 21000 scene."""
    return [int(r) for r in np.linspace(base, stop, num)]


def hyperedge_series():
    """The paper's knob (b): 147 -> 4,124,319 hyperedges (geometric)."""
    return [147, 1_000, 10_000, 100_000, 1_000_000, 4_124_319]
