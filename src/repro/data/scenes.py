"""Arbitrarily large synthetic MODIS-like scenes, readable by row window.

``modis.snowfield`` materialises a whole ``res x res`` mask at once (its
threshold is a global quantile), which caps it at what fits in host RAM.
Scene-scale streaming needs the opposite contract: a granule that may be
tens of gigapixels, of which a reader only ever touches a few tile rows at
a time. So every pixel here is a **pure function of (seed, y, x)** — an
integer-hashed value lattice on a ``cell``-pitch grid, bilinearly
interpolated and thresholded — which gives the same blobby snow-cover-like
structure at cell scale while guaranteeing exact row-decomposability:

    scene_rows(h, w, 0, h, seed=s) == vstack(scene_rows(h, w, a, b, seed=s)
                                             for consecutive [a, b) windows)

bit for bit, whatever the windowing. That identity is what makes tiled
scene analysis (``repro.scene``) checkpointable and resumable: a restarted
job re-reads exactly the rows it needs and nothing else.
"""

from __future__ import annotations

import numpy as np

# splitmix64-style mixing constants (fixed forever: scene content is part
# of the resume contract — changing these changes every synthetic granule)
_C1 = np.uint64(0x9E3779B97F4A7C15)
_C2 = np.uint64(0xC2B2AE3D27D4EB4F)
_C3 = np.uint64(0xD6E8FEB86659FD93)
_M1 = np.uint64(0xFF51AFD7ED558CCD)
_M2 = np.uint64(0xC4CEB9FE1A85EC53)


def _lattice(seed: int, ys: np.ndarray, xs: np.ndarray) -> np.ndarray:
    """Deterministic uniform-ish values in [0, 1) on the (ys x xs) lattice."""
    with np.errstate(over="ignore"):
        y = ys.astype(np.uint64)[:, None]
        x = xs.astype(np.uint64)[None, :]
        h = y * _C1 + x * _C2 + np.uint64(seed & 0xFFFFFFFFFFFFFFFF) * _C3
        h ^= h >> np.uint64(33)
        h *= _M1
        h ^= h >> np.uint64(33)
        h *= _M2
        h ^= h >> np.uint64(33)
    return (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)


def scene_rows(height: int, width: int, row0: int, row1: int, *,
               seed: int = 0, cell: int = 64, coverage: float = 0.45,
               dtype=np.uint8) -> np.ndarray:
    """Rows ``[row0, row1)`` of the synthetic scene -> (row1-row0, width).

    Pure in (seed, cell, coverage, coordinates): windowed reads compose
    exactly, and ``height`` only bounds the valid row range (content does
    not depend on it, so cropping a scene is the same as reading less).
    """
    if not (0 <= row0 <= row1 <= height):
        raise ValueError(
            f"row window [{row0}, {row1}) outside scene height {height}")
    if cell < 1:
        raise ValueError(f"cell must be >= 1, got {cell}")
    if row0 == row1:
        return np.zeros((0, width), dtype)
    ys = np.arange(row0, row1)
    xs = np.arange(width)
    cy0, fy = ys // cell, ((ys % cell) / cell)[:, None]
    cx0, fx = xs // cell, ((xs % cell) / cell)[None, :]
    v = (_lattice(seed, cy0, cx0) * (1 - fy) * (1 - fx)
         + _lattice(seed, cy0, cx0 + 1) * (1 - fy) * fx
         + _lattice(seed, cy0 + 1, cx0) * fy * (1 - fx)
         + _lattice(seed, cy0 + 1, cx0 + 1) * fy * fx)
    return (v > (1.0 - coverage)).astype(dtype)


def scene(height: int, width: int, *, seed: int = 0, cell: int = 64,
          coverage: float = 0.45, dtype=np.uint8) -> np.ndarray:
    """Materialise the whole (height, width) scene (small scenes / tests)."""
    return scene_rows(height, width, 0, height, seed=seed, cell=cell,
                      coverage=coverage, dtype=dtype)
