from repro.data import modis, pipeline, synthetic

__all__ = ["modis", "pipeline", "synthetic"]
