from repro.data import modis, pipeline, scenes, synthetic

__all__ = ["modis", "pipeline", "scenes", "synthetic"]
