"""Deterministic synthetic LM token pipeline.

Sequences are generated from a seeded Markov-ish process so that (a) runs
are exactly reproducible across restarts — a step's batch is a pure function
of (seed, step) — which is what makes checkpoint-resume byte-identical, and
(b) there is real learnable structure (bigram preferences), so the ~100M
example run shows a falling loss rather than noise.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenDatasetConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # structure knobs
    n_patterns: int = 64
    pattern_len: int = 16


class TokenDataset:
    """Batch = f(seed, step): stateless, shardable by host."""

    def __init__(self, cfg: TokenDatasetConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.patterns = rng.integers(
            0, cfg.vocab_size, size=(cfg.n_patterns, cfg.pattern_len)
        ).astype(np.int32)

    def batch(self, step: int, host_id: int = 0, num_hosts: int = 1
              ) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % num_hosts == 0
        per_host = cfg.global_batch // num_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, host_id])
        )
        n_pat = cfg.seq_len // cfg.pattern_len + 2
        idx = rng.integers(0, cfg.n_patterns, size=(per_host, n_pat))
        seq = self.patterns[idx].reshape(per_host, -1)[:, : cfg.seq_len + 1]
        noise = rng.random((per_host, cfg.seq_len + 1)) < 0.05
        rand_tok = rng.integers(0, cfg.vocab_size, size=(per_host, cfg.seq_len + 1))
        seq = np.where(noise, rand_tok, seq).astype(np.int32)
        return {"tokens": seq[:, :-1], "labels": seq[:, 1:]}

    def iter(self, start_step: int = 0, host_id: int = 0, num_hosts: int = 1
             ) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, host_id, num_hosts)
            step += 1
