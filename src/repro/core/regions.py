"""Beyond-paper: materialise the yConvex hyperedges (not just count them).

The poster stops at the per-column counts and the transition signal; the
underlying yCHG model papers [1,3] need the actual hyperedges (maximal
y-convex sub-regions) for contour tracking and area estimation. This module
builds a y-convex decomposition by chaining column runs:

  * every column's foreground splits into maximal runs (intervals);
  * run A (column j) and run B (column j+1) are 4-connected iff their row
    intervals overlap;
  * a hyperedge is a maximal chain of one-to-one connected runs across
    consecutive columns. Chains break at branch points (a run with 2+ right
    neighbours) and merge points (2+ left neighbours) — exactly the columns
    the paper's step-2 transition signal flags, plus same-count reconnection
    events the count-based signal cannot see (documented limitation of the
    poster's simplification; tests cover both).

This is a greedy decomposition (splits at every branch/merge), valid but not
necessarily minimal. Host-side NumPy: this is a data-plane op on mask tiles,
not a device hot loop.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Run:
    col: int
    row_start: int  # inclusive
    row_end: int    # exclusive


@dataclasses.dataclass(frozen=True)
class Hyperedge:
    """A maximal y-convex chain of runs over consecutive columns."""

    runs: Tuple[Run, ...]

    @property
    def col_span(self) -> Tuple[int, int]:
        return self.runs[0].col, self.runs[-1].col + 1

    @property
    def area(self) -> int:
        return sum(r.row_end - r.row_start for r in self.runs)


def extract_runs(img: np.ndarray) -> List[List[Run]]:
    """Per-column maximal foreground runs. img: (H, W) mask."""
    x = np.asarray(img) != 0
    h, w = x.shape
    out: List[List[Run]] = []
    padded = np.zeros((h + 2,), dtype=bool)
    for j in range(w):
        padded[1:-1] = x[:, j]
        d = np.diff(padded.astype(np.int8))
        starts = np.nonzero(d == 1)[0]
        ends = np.nonzero(d == -1)[0]
        out.append([Run(j, int(s), int(e)) for s, e in zip(starts, ends)])
    return out


def _overlaps(a: Run, b: Run) -> bool:
    return a.row_start < b.row_end and b.row_start < a.row_end


def decompose(img: np.ndarray) -> List[Hyperedge]:
    """Greedy y-convex decomposition by chaining one-to-one connected runs."""
    cols = extract_runs(img)
    w = len(cols)
    # neighbour counts between column j and j+1
    edges: List[Hyperedge] = []
    # open chains: list of (list_of_runs) whose tail is in column j-1
    open_chains: List[List[Run]] = []
    for j in range(w):
        runs_here = cols[j]
        prev_runs = cols[j - 1] if j > 0 else []
        # adjacency between prev column runs and this column's runs
        right_nbrs = {i: [] for i in range(len(prev_runs))}
        left_nbrs = {k: [] for k in range(len(runs_here))}
        for i, a in enumerate(prev_runs):
            for k, b in enumerate(runs_here):
                if _overlaps(a, b):
                    right_nbrs[i].append(k)
                    left_nbrs[k].append(i)
        # map: open chain tail run -> index in prev_runs
        tail_index = {}
        for ci, chain in enumerate(open_chains):
            for i, a in enumerate(prev_runs):
                if chain[-1] is a:
                    tail_index[ci] = i
        next_open: List[List[Run]] = []
        consumed = set()
        for ci, chain in enumerate(open_chains):
            i = tail_index.get(ci)
            ext = None
            if i is not None and len(right_nbrs[i]) == 1:
                k = right_nbrs[i][0]
                if len(left_nbrs[k]) == 1:
                    ext = k
            if ext is not None and ext not in consumed:
                chain.append(runs_here[ext])
                consumed.add(ext)
                next_open.append(chain)
            else:
                edges.append(Hyperedge(tuple(chain)))
        for k, r in enumerate(runs_here):
            if k not in consumed:
                next_open.append([r])
        open_chains = next_open
    for chain in open_chains:
        edges.append(Hyperedge(tuple(chain)))
    return edges


def label_image(img: np.ndarray) -> Tuple[np.ndarray, int]:
    """(H, W) int32 label map (0 = background, k = hyperedge k) and the count."""
    x = np.asarray(img)
    labels = np.zeros(x.shape, dtype=np.int32)
    edges = decompose(x)
    for idx, e in enumerate(edges, start=1):
        for r in e.runs:
            labels[r.row_start : r.row_end, r.col] = idx
    return labels, len(edges)


def total_area(img: np.ndarray) -> int:
    """Area of the ROI via y-convex decomposition (ref [3]'s application)."""
    return sum(e.area for e in decompose(img))
