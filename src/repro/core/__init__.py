"""The paper's contribution: data-parallel yConvex Hypergraph construction.

Two-step structure, exactly as in the poster:
  step 1  column_runs / cut_vertices  — per-column maximal-run (cut-vertex) counts
  step 2  hyperedge_transitions       — neighbour-column diff -> births/deaths

`ychg` is the pure-JAX production implementation (CPU/TPU, vmap-able).
`serial` is the paper's CPU baseline (honest scalar loops).
`regions` materialises the hyperedges (beyond-paper; the poster only counts).
"""

from repro.core.ychg import (
    column_runs,
    cut_vertices,
    hyperedge_transitions,
    hyperedge_count,
    analyze,
    analyze_jit,
    check_conservation,
    YCHGSummary,
)
from repro.core import serial
from repro.core import regions

__all__ = [
    "column_runs",
    "cut_vertices",
    "hyperedge_transitions",
    "hyperedge_count",
    "analyze",
    "analyze_jit",
    "check_conservation",
    "YCHGSummary",
    "serial",
    "regions",
]
