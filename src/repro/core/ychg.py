"""Pure-JAX yConvex Hypergraph (yCHG) construction — the paper's algorithm.

The yCHG model (Kanna et al. [1,3]) represents a binary ROI as a hypergraph
whose hyperedges are y-convex sub-regions: every vertical line intersects a
y-convex region in at most one connected run. The ICS'13 poster parallelises
the construction in two steps:

  step 1: each column j independently counts its cut-vertices. A column's
          foreground decomposes into maximal vertical runs; each run has a
          top and a bottom cut-vertex, so ``cut_vertices[j] = 2*runs[j]``.
          ``runs[j]`` is the number of rising edges scanning down the column.

  step 2: compare ``runs[j]`` with ``runs[j-1]``. A change means the number
          of live yConvex hyperedges changes at column j: ``births[j] =
          max(runs[j]-runs[j-1], 0)`` hyperedges are born, ``deaths[j] =
          max(runs[j-1]-runs[j], 0)`` die. Column 0's predecessor count is 0.

Total hyperedge count = sum of births (each hyperedge is born exactly once).

Everything here is jit/vmap-friendly; images may be bool or any integer
dtype (nonzero = foreground). This module is the *production* implementation
used by the data pipeline; `repro.kernels` holds the Pallas TPU kernel for
the same computation and `repro.core.serial` the paper's CPU baseline.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


def _as_binary(img: Array) -> Array:
    """Nonzero -> True. Accepts bool/uint8/int/float masks, any leading batch dims."""
    if img.dtype == jnp.bool_:
        return img
    return img != 0


def column_runs(img: Array) -> Array:
    """Step 1 (paper §2): per-column count of maximal vertical foreground runs.

    Args:
      img: (..., H, W) binary mask; nonzero = foreground.
    Returns:
      (..., W) int32 — number of maximal runs per column.
    """
    x = _as_binary(img)
    # A run starts at row i where x[i] & ~x[i-1]; row 0 starts a run if set.
    prev = jnp.pad(x[..., :-1, :], [(0, 0)] * (x.ndim - 2) + [(1, 0), (0, 0)])
    rising = x & ~prev
    return jnp.sum(rising, axis=-2, dtype=jnp.int32)


def cut_vertices(img: Array) -> Array:
    """Per-column cut-vertex count: 2 per maximal run (top + bottom vertex)."""
    return 2 * column_runs(img)


def hyperedge_transitions(runs: Array) -> dict[str, Array]:
    """Step 2 (paper §2): neighbour-column comparison of run counts.

    Args:
      runs: (..., W) int32 per-column run counts (step-1 output).
    Returns:
      dict with
        'transitions': (..., W) bool — True where runs[j] != runs[j-1]
                       (runs[-1] defined as 0, so column 0 transitions iff
                       it has any run),
        'births':      (..., W) int32 — max(runs[j]-runs[j-1], 0),
        'deaths':      (..., W) int32 — max(runs[j-1]-runs[j], 0).
    """
    prev = jnp.pad(runs[..., :-1], [(0, 0)] * (runs.ndim - 1) + [(1, 0)])
    delta = runs - prev
    return {
        "transitions": delta != 0,
        "births": jnp.maximum(delta, 0),
        "deaths": jnp.maximum(-delta, 0),
    }


def hyperedge_count(img: Array) -> Array:
    """Number of yConvex hyperedges of the ROI (sum of births). (...,) int32."""
    runs = column_runs(img)
    return jnp.sum(hyperedge_transitions(runs)["births"], axis=-1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class YCHGSummary:
    """Full output of the two-step parallel algorithm for one (batch of) image(s)."""

    runs: Array           # (..., W) int32  step-1 per-column run counts
    cut_vertices: Array   # (..., W) int32  2*runs
    transitions: Array    # (..., W) bool   step-2 change signal
    births: Array         # (..., W) int32
    deaths: Array         # (..., W) int32
    n_hyperedges: Array   # (...,)   int32  total births
    n_transitions: Array  # (...,)   int32  number of transition columns

    def tree_flatten(self):  # pragma: no cover - convenience
        return dataclasses.astuple(self), None


def analyze(img: Array) -> YCHGSummary:
    """Run both steps; jit/vmap friendly. img: (..., H, W) mask."""
    runs = column_runs(img)
    t = hyperedge_transitions(runs)
    return YCHGSummary(
        runs=runs,
        cut_vertices=2 * runs,
        transitions=t["transitions"],
        births=t["births"],
        deaths=t["deaths"],
        n_hyperedges=jnp.sum(t["births"], axis=-1),
        n_transitions=jnp.sum(t["transitions"], axis=-1, dtype=jnp.int32),
    )


# jit'd entry point used by the data pipeline / serving path.
analyze_jit = jax.jit(analyze)


def analyze_batched(imgs: Array) -> YCHGSummary:
    """Explicit batched form for (B, H, W) stacks — identical math, one fused pass."""
    return analyze(imgs)


def check_conservation(summary: YCHGSummary) -> Any:
    """Invariant: births - deaths telescopes to the final column's run count.

    sum(births) - sum(deaths) == runs[..., -1]. Returns bool array (...,).
    Used by property tests and by the pipeline's self-check mode.
    """
    lhs = jnp.sum(summary.births, axis=-1) - jnp.sum(summary.deaths, axis=-1)
    return lhs == summary.runs[..., -1]
