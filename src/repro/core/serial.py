"""The paper's serial CPU baseline, kept deliberately faithful.

The ICS'13 poster compares against "the existing serial implementation" on a
2-core i5-480M: a scalar scan that walks every column top-to-bottom counting
cut-vertices, then a second scalar pass comparing neighbour columns. We keep
two baselines:

  * ``analyze_scalar``  — honest per-pixel Python loops (the shape of the
    original C/C++ serial code; dominated by interpreter overhead here, so
    benchmarks report it separately and never use it for large images).
  * ``analyze_numpy``   — the same serial algorithm expressed with NumPy
    column sweeps (a fair single-core CPU baseline for the speedup curves;
    this is what benchmarks/run.py's "serial" series means).

Both return plain Python/NumPy values and must agree exactly with
``repro.core.ychg.analyze`` — tests enforce this.
"""

from __future__ import annotations

from typing import Dict

import numpy as np


def column_runs_scalar(img: np.ndarray) -> np.ndarray:
    """Scalar step 1: loop columns, loop rows, count rising edges."""
    img = np.asarray(img)
    h, w = img.shape
    runs = np.zeros(w, dtype=np.int32)
    for j in range(w):
        prev = 0
        count = 0
        for i in range(h):
            cur = 1 if img[i, j] else 0
            if cur and not prev:
                count += 1
            prev = cur
        runs[j] = count
    return runs


def _transitions(runs: np.ndarray) -> Dict[str, np.ndarray]:
    """Scalar-equivalent step 2 (vectorised; O(W) either way)."""
    prev = np.concatenate([[0], runs[:-1]]).astype(np.int32)
    delta = runs.astype(np.int32) - prev
    return {
        "transitions": delta != 0,
        "births": np.maximum(delta, 0),
        "deaths": np.maximum(-delta, 0),
    }


def analyze_scalar(img: np.ndarray) -> Dict[str, np.ndarray]:
    runs = column_runs_scalar(img)
    t = _transitions(runs)
    return _pack(runs, t)


def column_runs_numpy(img: np.ndarray) -> np.ndarray:
    """Serial algorithm, NumPy-expressed (single core): one pass over the image."""
    x = np.asarray(img) != 0
    prev = np.zeros_like(x)
    prev[1:, :] = x[:-1, :]
    rising = x & ~prev
    return rising.sum(axis=0).astype(np.int32)


def analyze_numpy(img: np.ndarray) -> Dict[str, np.ndarray]:
    runs = column_runs_numpy(img)
    t = _transitions(runs)
    return _pack(runs, t)


def _pack(runs: np.ndarray, t: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    return {
        "runs": runs,
        "cut_vertices": 2 * runs,
        "transitions": t["transitions"],
        "births": t["births"],
        "deaths": t["deaths"],
        "n_hyperedges": np.int32(t["births"].sum()),
        "n_transitions": np.int32(t["transitions"].sum()),
    }
