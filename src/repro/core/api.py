"""DEPRECATED shim over :mod:`repro.engine` — use ``Engine`` instead.

``analyze_image`` was the original high-level entry point with string
backend selection. It survives only for backwards compatibility: every call
emits a ``DeprecationWarning`` and delegates to the engine, returning the
exact legacy host-NumPy dict. New code should construct the engine
directly::

    from repro.engine import Engine, YCHGConfig
    engine = Engine(YCHGConfig(backend="jax"))
    result = engine.analyze(img)          # device-resident YCHGResult
    legacy = result.to_host()             # the dict this shim returns

Backend names are unchanged ("jax", "fused", "pallas", "serial", "scalar");
see ``repro.engine.backends`` for their capability flags and
``repro.engine`` for the full migration table.
"""

from __future__ import annotations

import warnings
from typing import Any, Dict

import numpy as np

BACKENDS = ("jax", "fused", "pallas", "serial", "scalar")

_ENGINES: Dict[str, Any] = {}


def _engine(backend: str):
    if backend not in _ENGINES:
        from repro.engine import Engine, YCHGConfig

        _ENGINES[backend] = Engine(YCHGConfig(backend=backend))
    return _ENGINES[backend]


def analyze_image(img: Any, backend: str = "jax") -> Dict[str, np.ndarray]:
    """DEPRECATED: use ``repro.engine.Engine``. Returns host NumPy values."""
    warnings.warn(
        "repro.core.api.analyze_image is deprecated; use "
        "repro.engine.Engine(...).analyze(img) (and .to_host() for this "
        "dict form)",
        DeprecationWarning,
        stacklevel=2,
    )
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    engine = _engine(backend)
    if np.ndim(img) == 3:  # legacy jax/fused paths accepted (B, H, W) stacks
        return engine.analyze_batch(img).to_host()
    return engine.analyze(img).to_host()
