"""High-level yCHG entry point with backend selection.

Backends:
  "jax"    — repro.core.ychg (pure jnp, jit; default; runs anywhere)
  "fused"  — repro.kernels.ops.analyze_fused (single-launch fused batched
             Pallas kernel; interpret off-TPU; accepts (H, W) or (B, H, W))
  "pallas" — repro.kernels.ops (two-pass Pallas kernels; interpret off-TPU)
  "serial" — repro.core.serial NumPy single-core (the paper's CPU baseline)
  "scalar" — repro.core.serial per-pixel Python loops (the literal baseline;
             only sensible for tiny images)
"""

from __future__ import annotations

from typing import Any, Dict

import numpy as np

from repro.core import serial, ychg
from repro.kernels import ops as kernel_ops

BACKENDS = ("jax", "fused", "pallas", "serial", "scalar")


def _summary_to_dict(s: ychg.YCHGSummary) -> Dict[str, np.ndarray]:
    return {
        "runs": np.asarray(s.runs),
        "cut_vertices": np.asarray(s.cut_vertices),
        "transitions": np.asarray(s.transitions),
        "births": np.asarray(s.births),
        "deaths": np.asarray(s.deaths),
        "n_hyperedges": np.asarray(s.n_hyperedges),
        "n_transitions": np.asarray(s.n_transitions),
    }


def analyze_image(img: Any, backend: str = "jax") -> Dict[str, np.ndarray]:
    """Run the paper's two-step algorithm; returns host NumPy values."""
    if backend == "jax":
        return _summary_to_dict(ychg.analyze_jit(img))
    if backend == "fused":
        return _summary_to_dict(kernel_ops.analyze_fused(np.asarray(img)))
    if backend == "pallas":
        out = kernel_ops.analyze(img)
        return {k: np.asarray(v) for k, v in out.items()}
    if backend == "serial":
        return serial.analyze_numpy(np.asarray(img))
    if backend == "scalar":
        return serial.analyze_scalar(np.asarray(img))
    raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
