"""Pure-jnp oracles for the yCHG Pallas kernels.

These restate the kernel math with plain jnp ops; the kernel tests sweep
shapes/dtypes and assert exact equality (integer outputs) against these.
They intentionally do NOT share code with repro.core.ychg so that a bug in
one implementation cannot hide in both (ychg.py is additionally cross-checked
against core.serial).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def colscan_runs_ref(img: Array) -> Array:
    """(H, W) mask -> (W,) int32 maximal-run counts per column."""
    x = (img != 0).astype(jnp.int32)
    # rising edges scanning down each column; row 0 compares against 0.
    interior = jnp.clip(x[1:, :] - x[:-1, :], 0, 1)
    return x[0, :] + jnp.sum(interior, axis=0, dtype=jnp.int32)


def transitions_ref(runs: Array) -> tuple[Array, Array, Array]:
    """(W,) int32 -> (transitions bool, births i32, deaths i32), runs[-1]:=0."""
    prev = jnp.concatenate([jnp.zeros((1,), runs.dtype), runs[:-1]])
    delta = (runs - prev).astype(jnp.int32)
    return delta != 0, jnp.maximum(delta, 0), jnp.maximum(-delta, 0)


def analyze_ref(img: Array) -> dict[str, Array]:
    runs = colscan_runs_ref(img)
    t, b, d = transitions_ref(runs)
    return {
        "runs": runs,
        "transitions": t,
        "births": b,
        "deaths": d,
        "n_hyperedges": jnp.sum(b, dtype=jnp.int32),
    }
