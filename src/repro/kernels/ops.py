"""jit'd public wrappers for the yCHG Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; interpret
mode executes the kernel body in Python for correctness validation). On a real
TPU backend the same calls compile to Mosaic.

The heuristic between the full-column and streamed step-1 kernels is a VMEM
budget: a full (H, block_w) int8 tile plus boolean temporaries must fit
comfortably in 16 MiB VMEM; past ~4 MiB for the raw tile we stream over H.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.ychg import YCHGSummary
from repro.kernels import ychg_colscan as _k
from repro.kernels import ychg_fused as _f

Array = jax.Array

# raw int8 tile budget before switching to the streamed kernel (bytes)
_FULL_COLUMN_VMEM_BUDGET = 4 * 1024 * 1024


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def colscan_runs(
    img: Array,
    *,
    block_w: int = 128,
    block_h: int = 2048,
    interpret: bool | None = None,
    vmem_budget: int | None = None,
) -> Array:
    """Step 1: per-column maximal-run counts. (H, W) mask -> (W,) int32."""
    if interpret is None:
        interpret = _default_interpret()
    if vmem_budget is None:
        vmem_budget = _FULL_COLUMN_VMEM_BUDGET
    h, _ = img.shape
    if h * block_w > vmem_budget:
        return _k.colscan_runs_streamed(
            img, block_w=block_w, block_h=block_h, interpret=interpret
        )
    return _k.colscan_runs_pallas(img, block_w=block_w, interpret=interpret)


def transitions(
    runs: Array, *, block_w: int = 128, interpret: bool | None = None
) -> tuple[Array, Array, Array]:
    """Step 2: (W,) run counts -> (transitions bool, births i32, deaths i32)."""
    if interpret is None:
        interpret = _default_interpret()
    return _k.transitions_pallas(runs, block_w=block_w, interpret=interpret)


def analyze(
    img: Array,
    *,
    block_w: int = 128,
    block_h: int = 2048,
    interpret: bool | None = None,
    vmem_budget: int | None = None,
) -> Dict[str, Array]:
    """Both steps fused end-to-end on device; returns the poster's outputs."""
    runs = colscan_runs(img, block_w=block_w, block_h=block_h, interpret=interpret,
                        vmem_budget=vmem_budget)
    trans, births, deaths = transitions(runs, block_w=block_w, interpret=interpret)
    return {
        "runs": runs,
        "cut_vertices": 2 * runs,
        "transitions": trans,
        "births": births,
        "deaths": deaths,
        "n_hyperedges": jnp.sum(births, dtype=jnp.int32),
        "n_transitions": jnp.sum(trans, dtype=jnp.int32),
    }


def analyze_fused(
    img: Array,
    *,
    block_w: int = 128,
    block_h: int = 2048,
    interpret: bool | None = None,
    vmem_budget: int | None = None,
) -> YCHGSummary:
    """Fused batched pipeline: one kernel launch for a whole (B, H, W) stack.

    Accepts (H, W) or (B, H, W); returns a ``YCHGSummary`` bit-identical to
    ``repro.core.ychg.analyze`` (same dtypes, shapes, and values). Tall
    images (full column tile over the VMEM budget) stream over H inside the
    same single launch via the carry-row variant.
    """
    if interpret is None:
        interpret = _default_interpret()
    if vmem_budget is None:
        vmem_budget = _FULL_COLUMN_VMEM_BUDGET
    squeeze = img.ndim == 2
    imgs = img[None] if squeeze else img
    if imgs.ndim != 3:
        raise ValueError(f"expected (H, W) or (B, H, W) mask, got {img.shape}")
    b, h, _ = imgs.shape
    if b == 0:  # nothing to launch; keep the contract via the jnp path
        from repro.core import ychg as _ychg

        return _ychg.analyze(img)
    if h * block_w > vmem_budget:
        out = _f.fused_analyze_streamed(
            imgs, block_w=block_w, block_h=block_h, interpret=interpret
        )
    else:
        out = _f.fused_analyze_pallas(imgs, block_w=block_w, interpret=interpret)
    if squeeze:
        out = {k: v[0] for k, v in out.items()}
    return YCHGSummary(
        runs=out["runs"],
        cut_vertices=2 * out["runs"],
        transitions=out["transitions"],
        births=out["births"],
        deaths=out["deaths"],
        n_hyperedges=out["n_hyperedges"],
        n_transitions=out["n_transitions"],
    )
