"""Connected-components labeling (CCL) — jnp reference + Pallas kernel.

Coarse-to-fine parallel CCL in the style of Chen et al. (arXiv 1712.09789):
every foreground pixel starts as its own component seeded with its linear
index, then iterated 4-neighbour **min propagation** drives each component
to a unique fixpoint — the minimum linear index over the component. The
fixpoint is schedule-independent, so any propagation order (the jnp
reference adds pointer-jumping to converge in ~log steps; the Pallas kernel
does plain neighbour sweeps in VMEM) lands on bit-identical labels.

A final **canonical re-ranking** maps root labels to consecutive component
ids 1..n in row-major first-encounter order. That makes labels invariant
under the service tier's pad-to-bucket batching: zero padding never starts
a component, and padding right/bottom preserves the row-major order of the
native pixels, so canonical labels crop back bit-exactly (the same
padding-inertness argument as ``service.batching`` makes for yCHG).

Layout mirrors ``kernels.ops``: ``labels(stack)`` is the jnp reference,
``labels_pallas(stack)`` the kernel path; both take (B, H, W) stacks of
any dtype (nonzero = foreground) and return a :class:`CCLSummary` of
``labels`` (B, H, W) int32 and ``n_components`` (B,) int32.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

CCL_FIELDS = ("labels", "n_components")

# Sentinel larger than any linear pixel index + 1; background carries it
# during propagation so minima never leak across components. A Python int
# (not a jnp scalar) so the Pallas kernel does not capture a device
# constant; it folds into each trace as an int32 literal.
_INF = 1 << 30


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CCLSummary:
    """Batched CCL output: canonical labels + per-image component count."""

    labels: Array        # (B, H, W) int32, 0 = background, 1..n per image
    n_components: Array  # (B,) int32


def _seed_labels(fg: Array) -> Array:
    """(B, H, W) bool -> initial labels: linear index + 1 on fg, _INF on bg."""
    _, h, w = fg.shape
    idx = (jax.lax.broadcasted_iota(jnp.int32, (h, w), 0) * w
           + jax.lax.broadcasted_iota(jnp.int32, (h, w), 1) + 1)
    return jnp.where(fg, idx[None], _INF)


def _neighbor_min(lab: Array) -> Array:
    """Min over self + 4-neighbours; borders padded with _INF."""
    pad = ((0, 0), (1, 0), (0, 0))
    up = jnp.pad(lab[:, :-1, :], pad, constant_values=_INF)
    down = jnp.pad(lab[:, 1:, :], ((0, 0), (0, 1), (0, 0)),
                   constant_values=_INF)
    left = jnp.pad(lab[:, :, :-1], ((0, 0), (0, 0), (1, 0)),
                   constant_values=_INF)
    right = jnp.pad(lab[:, :, 1:], ((0, 0), (0, 0), (0, 1)),
                    constant_values=_INF)
    return jnp.minimum(lab, jnp.minimum(jnp.minimum(up, down),
                                        jnp.minimum(left, right)))


def _canonicalize(lab: Array, fg: Array) -> CCLSummary:
    """Fixpoint labels (min linear index + 1 per component) -> consecutive
    ids 1..n in row-major first-encounter order, 0 on background."""
    b, h, w = lab.shape
    flat = jnp.where(fg, lab, 0).reshape(b, h * w)
    pos = jnp.arange(h * w, dtype=jnp.int32)[None, :] + 1
    is_root = (flat == pos).astype(jnp.int32)   # bg is 0, never a root
    rank = jnp.cumsum(is_root, axis=1, dtype=jnp.int32)
    canon = jnp.where(
        flat > 0,
        jnp.take_along_axis(rank, jnp.maximum(flat - 1, 0), axis=1),
        0,
    )
    n = rank[:, -1] if h * w else jnp.zeros((b,), jnp.int32)
    return CCLSummary(labels=canon.reshape(b, h, w), n_components=n)


@jax.jit
def labels(stack: Array) -> CCLSummary:
    """jnp reference: (B, H, W) stack -> canonical CCL summary.

    Coarse step: 4-neighbour min propagation. Fine step: pointer jumping
    (label <- label-at-root-candidate) so chains collapse logarithmically
    instead of one pixel per sweep. Both preserve the per-component
    minimum, so the fixpoint equals the kernel path's bit for bit.
    """
    fg = stack != 0
    b, h, w = fg.shape
    if h * w == 0:
        return CCLSummary(labels=jnp.zeros((b, h, w), jnp.int32),
                          n_components=jnp.zeros((b,), jnp.int32))
    lab0 = _seed_labels(fg)

    def jump(lab: Array) -> Array:
        # follow the indirection: each fg pixel adopts its current root
        # candidate's own label (bg _INF entries are never dereferenced)
        flat = jnp.where(fg, lab, 0).reshape(b, h * w)
        hop = jnp.take_along_axis(flat, jnp.maximum(flat - 1, 0), axis=1)
        hop = hop.reshape(b, h, w)
        return jnp.where(fg & (hop > 0), hop, lab)

    def body(state):
        lab, _ = state
        new = jnp.where(fg, _neighbor_min(lab), _INF)
        new = jump(jump(new))
        return new, jnp.any(new != lab)

    lab, _ = jax.lax.while_loop(lambda s: s[1], body,
                                (lab0, jnp.bool_(True)))
    return _canonicalize(lab, fg)


def _ccl_kernel(img_ref, out_ref):
    """One image per grid step: whole (1, H, W) block in VMEM; iterated
    neighbour-min sweeps (no gather — TPU-friendly) to the fixpoint."""
    fg = img_ref[...] != 0
    lab0 = _seed_labels(fg)

    def body(state):
        lab, _ = state
        new = jnp.where(fg, _neighbor_min(lab), _INF)
        return new, jnp.any(new != lab)

    lab, _ = jax.lax.while_loop(lambda s: s[1], body,
                                (lab0, jnp.bool_(True)))
    out_ref[...] = jnp.where(fg, lab, 0)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def labels_pallas(stack: Array, *, interpret: bool | None = None) -> CCLSummary:
    """Pallas path: per-image fixpoint kernel + shared jnp canonicalization.

    The kernel holds one full (H, W) image in VMEM per grid step (CCL needs
    global connectivity, so unlike the yCHG colscan there is no independent
    column tiling to stream); re-ranking runs outside the kernel where the
    gather is cheap. Bit-identical to :func:`labels`.
    """
    if interpret is None:
        interpret = _default_interpret()
    b, h, w = stack.shape
    if b == 0 or h * w == 0:
        return labels(stack)
    raw = pl.pallas_call(
        _ccl_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w), jnp.int32),
        interpret=interpret,
    )(stack)
    return _canonicalize(raw, stack != 0)
