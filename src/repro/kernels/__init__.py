"""Pallas TPU kernels for the compute hot-spot the paper optimizes:
the yCHG column scan (step 1) and neighbour diff (step 2).

These kernels are *backends*, not entry points: the canonical public API is
``repro.engine.Engine``, where they register as ``"fused"`` (single
launch, batched, mesh-capable) and ``"pallas"`` (two-pass) with capability
flags that drive ``backend="auto"`` dispatch. Call
``Engine(YCHGConfig(backend="fused")).analyze_batch(stack)`` rather
than ``ops.analyze_fused`` directly — the engine keeps results
device-resident, applies the VMEM streaming threshold from its config, and
composes with batch sharding (a mesh attached to the engine shard_maps the
fused backend). See ``repro.engine`` for the migration table.

  ychg_colscan.py  two-pass pl.pallas_call kernels + BlockSpec VMEM tiling
                   (one launch per step, HBM round-trip for the counts)
  ychg_fused.py    fused batched pipeline: BOTH steps for a (B, H, W) stack
                   in ONE launch — step 2's diff computed in-register from
                   step 1's tile result, with a (1, 1) VMEM carry for the
                   tile seam and revisited accumulator blocks for per-image
                   totals; streamed variant adds an H-tile grid dim with a
                   carry row for images past the VMEM budget
  ychg_packed.py   1-bit row packing (8x less HBM traffic on the scan)
  ops.py           jit'd wrappers (interpret=True off-TPU);
                   ``analyze_fused`` returns a core.ychg.YCHGSummary,
                   bit-identical to core.ychg.analyze
  ref.py           pure-jnp oracles for the exact-equality sweeps

Fused-vs-two-pass, measured (CPU, Pallas interpret mode; benchmarks/run.py
``bench_fused_batch_sweep``, us/call):

  batch x res   fused (1 launch)  two-pass (2B launches)  fused gain
  1  x 128        265               653                    2.46x
  8  x 128        505              1523                    3.02x
  32 x 128       1818              8475                    4.66x
  8  x 256       1138              2518                    2.21x

The gain grows with batch size exactly as the paper's data-parallel claim
predicts — launch/dispatch overhead amortises over the batch. At large
B*H*W (e.g. 32 x 512) interpret mode inverts the curve: each grid step is
evaluated in Python, so per-step overhead dominates and the two-pass
pipeline's smaller per-step blocks win. That inversion is an artifact of
interpret mode only; on a compiled TPU backend the fused kernel strictly
removes one launch per image, one HBM round-trip of the (W,) counts vector,
and one shifted HBM copy. The pure-jnp path (core.ychg) stays the fastest
on this CPU-only box and remains the production default there; the fused
kernel is the TPU path and the launch-count ledger above is its contract.
"""

from repro.kernels import ops, ref
from repro.kernels.ops import analyze_fused

__all__ = ["ops", "ref", "analyze_fused"]
