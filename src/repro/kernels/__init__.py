"""Pallas TPU kernels for the compute hot-spot the paper optimizes:
the yCHG column scan (step 1) and neighbour diff (step 2).

  ychg_colscan.py  pl.pallas_call kernels + BlockSpec VMEM tiling
  ops.py           jit'd wrappers (interpret=True off-TPU)
  ref.py           pure-jnp oracles for the allclose sweeps
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
