"""P-HGRMS-style hypergraph RMS denoising — jnp reference + Pallas kernel.

The same group's P-HGRMS filter (arXiv 1306.5390) removes impulse noise by
treating each pixel's 3x3 neighbourhood as a hypergraph block: a pixel that
sits far from its neighbourhood consensus is classified noisy and replaced
by the block's root-mean-square value; consistent pixels pass through
untouched. This module implements the data-parallel core of that scheme:

  mean_j  = sum of the zero-padded 3x3 window / 9
  rms_j   = sqrt(sum of squares over the same window / 9)
  out_j   = rms_j   if |x_j - mean_j| > tau * rms_j     (impulse outlier)
            x_j     otherwise

The window uses **zero padding with a fixed divisor of 9** everywhere —
deliberately, because that makes the filter invariant under the service
tier's pad-to-bucket batching: a native pixel at the image border sees
exactly the same (zero-extended) window whether the zeros come from the
mathematical boundary or from bucket padding, so padded outputs crop back
bit-exactly. Output is float32 regardless of input dtype so every backend
shares one arithmetic path.

Layout mirrors ``kernels.ops``: ``denoise(stack)`` is the jnp reference,
``denoise_pallas(stack)`` the kernel path; both take (B, H, W) stacks and
return a :class:`DenoiseSummary` holding ``image`` (B, H, W) float32.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DENOISE_FIELDS = ("image",)

# Outlier threshold: |x - mean| > TAU * rms flags an impulse. A fixed
# module constant (not a config knob) so cache keys and cross-backend
# bit-identity never depend on runtime tuning.
TAU = 0.75


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DenoiseSummary:
    """Batched denoise output."""

    image: Array  # (B, H, W) float32


def _window_sum(x: Array) -> Array:
    """Sum of the zero-padded 3x3 window around each pixel, (..., H, W)."""
    p = jnp.pad(x, ((0, 0), (1, 1), (1, 1)))
    return (
        p[:, :-2, :-2] + p[:, :-2, 1:-1] + p[:, :-2, 2:]
        + p[:, 1:-1, :-2] + p[:, 1:-1, 1:-1] + p[:, 1:-1, 2:]
        + p[:, 2:, :-2] + p[:, 2:, 1:-1] + p[:, 2:, 2:]
    )


def _filter(x: Array) -> Array:
    """The shared arithmetic path: (B, H, W) float32 -> float32."""
    mean = _window_sum(x) * (1.0 / 9.0)
    rms = jnp.sqrt(_window_sum(x * x) * (1.0 / 9.0))
    return jnp.where(jnp.abs(x - mean) > TAU * rms, rms, x)


@jax.jit
def denoise(stack: Array) -> DenoiseSummary:
    """jnp reference: (B, H, W) stack of any dtype -> float32 summary."""
    return DenoiseSummary(image=_filter(stack.astype(jnp.float32)))


def _denoise_kernel(img_ref, out_ref):
    """One image per grid step: whole (1, H, W) block in VMEM. Elementwise
    VPU work; the 3x3 halo is materialised by the in-kernel pad, so blocks
    are self-contained without neighbour re-reads."""
    out_ref[...] = _filter(img_ref[...].astype(jnp.float32))


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def denoise_pallas(stack: Array, *,
                   interpret: bool | None = None) -> DenoiseSummary:
    """Pallas path, bit-identical to :func:`denoise`."""
    if interpret is None:
        interpret = _default_interpret()
    b, h, w = stack.shape
    if b == 0 or h * w == 0:
        return denoise(stack)
    out = pl.pallas_call(
        _denoise_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w), jnp.float32),
        interpret=interpret,
    )(stack)
    return DenoiseSummary(image=out)
