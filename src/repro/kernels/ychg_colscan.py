"""Pallas TPU kernels for the yCHG two-step algorithm.

TPU adaptation of the paper's CUDA mechanism (DESIGN.md §2). The CUDA code
assigns one *thread* per image column; on TPU we assign one *grid step* per
column tile of 128·k lanes, stream the tile HBM->VMEM via BlockSpec, and let
the 8x128 VPU evaluate the run-start predicate ``x[i] & ~x[i-1]`` for all
columns of the tile at once, reducing down the row (sublane) axis.

Two kernels, mirroring the paper's two steps:

  step 1a ``_colscan_kernel``          full column per block — grid over W only;
                                       block (H, bw) int8 in VMEM.
  step 1b ``_colscan_streamed_kernel`` grid over (W tiles, H tiles) with an
                                       int8 carry row in VMEM scratch, for
                                       images whose full column tile would
                                       not fit VMEM (H·bw > ~4 MiB).
  step 2  ``_diff_kernel``             neighbour-column comparison on the
                                       (W,) counts vector; the wrapper feeds
                                       the shifted copy so each block is
                                       self-contained (the CUDA version
                                       re-reads its left neighbour from
                                       global memory; on TPU we shift once
                                       in HBM instead — cheaper than a halo).

Grid iteration on TPU is sequential row-major with the last grid dim fastest;
the streamed kernel relies on that for its carry (W tile fixed, H tiles in
order) and accumulates into a revisited output block — the standard TPU
reduction pattern.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _colscan_kernel(img_ref, out_ref):
    """Block: img (H, bw) int8 -> out (1, bw) int32 run counts."""
    x = img_ref[...] != 0  # (H, bw) bool in VREGs
    first = x[0:1, :]
    rising = jnp.logical_and(x[1:, :], jnp.logical_not(x[:-1, :]))
    count = first.astype(jnp.int32).sum(axis=0) + rising.astype(jnp.int32).sum(axis=0)
    out_ref[...] = count[None, :]


def _colscan_streamed_kernel(img_ref, out_ref, carry_ref):
    """Grid (W tiles, H tiles); carry_ref holds the previous H-block's last row."""
    h = pl.program_id(1)

    @pl.when(h == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)
        out_ref[...] = jnp.zeros_like(out_ref)

    x = img_ref[...] != 0  # (bh, bw)
    prev_last = carry_ref[...] != 0  # (1, bw)
    prev_rows = jnp.concatenate([prev_last, x[:-1, :]], axis=0)
    rising = jnp.logical_and(x, jnp.logical_not(prev_rows))
    out_ref[...] += rising.astype(jnp.int32).sum(axis=0)[None, :]
    carry_ref[...] = x[-1:, :].astype(jnp.int8)


def _diff_kernel(runs_ref, prev_ref, trans_ref, births_ref, deaths_ref):
    """Block: runs/prev (1, bw) int32 -> transitions/births/deaths (1, bw) int32."""
    delta = runs_ref[...] - prev_ref[...]
    trans_ref[...] = (delta != 0).astype(jnp.int32)
    births_ref[...] = jnp.maximum(delta, 0)
    deaths_ref[...] = jnp.maximum(-delta, 0)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def colscan_runs_pallas(img: Array, *, block_w: int = 128, interpret: bool = True) -> Array:
    """Step 1, full-column blocks. img: (H, W) any dtype; returns (W,) int32.

    The wrapper pads W to a lane multiple with background columns (0 runs,
    sliced off afterwards) and casts to int8 for dense VMEM tiles.
    """
    h, w = img.shape
    x = (img != 0).astype(jnp.int8)
    w_pad = -w % block_w
    if w_pad:
        x = jnp.pad(x, ((0, 0), (0, w_pad)))
    wp = w + w_pad
    out = pl.pallas_call(
        _colscan_kernel,
        grid=(wp // block_w,),
        in_specs=[pl.BlockSpec((h, block_w), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, block_w), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, wp), jnp.int32),
        interpret=interpret,
    )(x)
    return out[0, :w]


@functools.partial(jax.jit, static_argnames=("block_w", "block_h", "interpret"))
def colscan_runs_streamed(
    img: Array, *, block_w: int = 128, block_h: int = 2048, interpret: bool = True
) -> Array:
    """Step 1 for tall images: grid over (W, H) tiles with a carry row."""
    h, w = img.shape
    x = (img != 0).astype(jnp.int8)
    w_pad = -w % block_w
    h_pad = -h % block_h
    if w_pad or h_pad:
        x = jnp.pad(x, ((0, h_pad), (0, w_pad)))  # zero rows end runs; no new rises
    hp, wp = h + h_pad, w + w_pad
    out = pl.pallas_call(
        _colscan_streamed_kernel,
        grid=(wp // block_w, hp // block_h),
        in_specs=[pl.BlockSpec((block_h, block_w), lambda j, i: (i, j))],
        out_specs=pl.BlockSpec((1, block_w), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, wp), jnp.int32),
        scratch_shapes=[_vmem_scratch(block_w)],
        interpret=interpret,
    )(x)
    return out[0, :w]


def _vmem_scratch(block_w: int):
    """VMEM scratch for the carry row; kept in a helper so the TPU-only import
    stays localised (interpret mode accepts it unchanged)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM((1, block_w), jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def transitions_pallas(
    runs: Array, *, block_w: int = 128, interpret: bool = True
) -> tuple[Array, Array, Array]:
    """Step 2. runs: (W,) int32 -> (transitions bool, births i32, deaths i32)."""
    (w,) = runs.shape
    prev = jnp.concatenate([jnp.zeros((1,), runs.dtype), runs[:-1]])
    w_pad = -w % block_w
    if w_pad:
        runs = jnp.pad(runs, (0, w_pad))
        prev = jnp.pad(prev, (0, w_pad))
    wp = w + w_pad
    spec = pl.BlockSpec((1, block_w), lambda j: (0, j))
    trans, births, deaths = pl.pallas_call(
        _diff_kernel,
        grid=(wp // block_w,),
        in_specs=[spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((1, wp), jnp.int32)] * 3,
        interpret=interpret,
    )(runs[None, :], prev[None, :])
    return (trans[0, :w] != 0), births[0, :w], deaths[0, :w]
