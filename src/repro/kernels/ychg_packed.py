"""Beyond-paper optimization of the paper's own kernel: 1-bit row packing.

The column scan is purely memory-bound (reads H*W mask bytes, writes 4*W
count bytes; ~2 integer ops/pixel). The paper stores one pixel per byte (as
does our baseline kernel). Packing 8 rows per byte cuts HBM traffic 8x —
directly 8x on the dominant roofline term — at the cost of a few cheap
bitwise ops per byte, which the VPU absorbs (still memory-bound after).

Bit layout: bit i of packed[r, c] = mask[8r + i, c] (LSB = topmost row).
Rising-edge detection entirely in registers:

    prev_bits = (b << 1) | carry          # bit i <- row above (carry = MSB
    rising    = b & ~prev_bits            #   of the byte above, at bit 0)
    runs[c]  += popcount(rising)          # lax.population_count (TPU native)

The carry chain down packed rows is a vectorised shift of the MSB column —
no sequential loop. Step 2 (neighbour diff) is fused into the same pass:
within a tile, births/deaths come from the tile-local shifted counts; the
one column per tile boundary is stitched by the wrapper with an O(W/bw)
vector op, so the fused kernel still makes a single trip over the image.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def pack_rows(img: Array) -> Array:
    """(H, W) mask -> (ceil(H/8), W) uint8, bit i = row 8r+i (LSB-first)."""
    h, w = img.shape
    x = (img != 0).astype(jnp.uint8)
    pad = -h % 8
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    x = x.reshape(-1, 8, w)
    weights = (1 << jnp.arange(8, dtype=jnp.uint8))[None, :, None]
    return jnp.sum(x * weights, axis=1, dtype=jnp.uint8)


def _packed_colscan_kernel(pk_ref, runs_ref):
    """Block: packed (Hp, bw) uint8 -> runs (1, bw) int32."""
    b = pk_ref[...]
    # carry: MSB of the byte above, placed at bit 0 of this byte's row
    msb = (b >> 7).astype(jnp.uint8)
    carry = jnp.concatenate([jnp.zeros_like(msb[:1]), msb[:-1]], axis=0)
    prev = ((b << 1) | carry).astype(jnp.uint8)
    rising = (b & (~prev).astype(jnp.uint8)).astype(jnp.uint8)
    counts = jax.lax.population_count(rising).astype(jnp.int32)
    runs_ref[...] = jnp.sum(counts, axis=0)[None, :]


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def packed_colscan(packed: Array, *, block_w: int = 128,
                   interpret: bool = True) -> Array:
    """Step 1 on a row-packed mask. packed: (Hp, W) uint8 -> (W,) int32."""
    hp, w = packed.shape
    w_pad = -w % block_w
    if w_pad:
        packed = jnp.pad(packed, ((0, 0), (0, w_pad)))
    wp = w + w_pad
    out = pl.pallas_call(
        _packed_colscan_kernel,
        grid=(wp // block_w,),
        in_specs=[pl.BlockSpec((hp, block_w), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, block_w), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, wp), jnp.int32),
        interpret=interpret,
    )(packed)
    return out[0, :w]


def _packed_fused_kernel(pk_ref, runs_ref, births_ref, deaths_ref):
    """Fused step 1 + tile-local step 2 (boundary column stitched outside)."""
    b = pk_ref[...]
    msb = (b >> 7).astype(jnp.uint8)
    carry = jnp.concatenate([jnp.zeros_like(msb[:1]), msb[:-1]], axis=0)
    prev = ((b << 1) | carry).astype(jnp.uint8)
    rising = (b & (~prev).astype(jnp.uint8)).astype(jnp.uint8)
    runs = jnp.sum(jax.lax.population_count(rising).astype(jnp.int32), axis=0)
    prev_runs = jnp.concatenate([jnp.zeros((1,), jnp.int32), runs[:-1]])
    delta = runs - prev_runs
    runs_ref[...] = runs[None, :]
    births_ref[...] = jnp.maximum(delta, 0)[None, :]
    deaths_ref[...] = jnp.maximum(-delta, 0)[None, :]


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def packed_analyze(img: Array, *, block_w: int = 128,
                   interpret: bool = True) -> dict[str, Array]:
    """Full two-step pipeline, one pass over a bit-packed image."""
    h, w = img.shape
    packed = pack_rows(img)
    hp = packed.shape[0]
    w_pad = -w % block_w
    if w_pad:
        packed = jnp.pad(packed, ((0, 0), (0, w_pad)))
    wp = w + w_pad
    spec = pl.BlockSpec((1, block_w), lambda j: (0, j))
    runs, births, deaths = pl.pallas_call(
        _packed_fused_kernel,
        grid=(wp // block_w,),
        in_specs=[pl.BlockSpec((hp, block_w), lambda j: (0, j))],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((1, wp), jnp.int32)] * 3,
        interpret=interpret,
    )(packed)
    runs, births, deaths = runs[0, :w], births[0, :w], deaths[0, :w]
    # stitch tile boundaries: the kernel assumed prev=0 at each tile's first
    # column; correct those W/bw columns against the true left neighbour.
    n_tiles = wp // block_w
    starts_np = [i * block_w for i in range(1, n_tiles) if i * block_w < w]
    if starts_np:
        starts = jnp.asarray(starts_np, jnp.int32)
        left = runs[starts - 1]
        delta = runs[starts] - left
        births = births.at[starts].set(jnp.maximum(delta, 0))
        deaths = deaths.at[starts].set(jnp.maximum(-delta, 0))
    return {
        "runs": runs,
        "cut_vertices": 2 * runs,
        "births": births,
        "deaths": deaths,
        "transitions": (births - deaths) != 0,
        "n_hyperedges": jnp.sum(births, dtype=jnp.int32),
        "n_transitions": jnp.sum((births - deaths) != 0, dtype=jnp.int32),
    }
