"""Fused, batched Pallas kernels: both yCHG steps in ONE ``pallas_call``.

The two-kernel pipeline in ``ychg_colscan.py`` mirrors the paper's CUDA
structure (step 1 kernel, HBM round-trip for the (W,) run-count vector,
step 2 kernel over a shifted copy). That round-trip is pure overhead: the
step-2 neighbour diff needs only the previous *tile's* last column count,
which the step-1 kernel already holds in registers. These kernels fuse the
diff into the column scan and batch a whole (B, H, W) stack into a single
launch:

  grid (B, W tiles)            — one grid step per (image, column tile);
  step 1 in-register           — run counts for the tile's columns from the
                                 rising-edge reduction, never written to HBM
                                 before step 2 consumes them;
  inter-tile carry             — a (1, 1) int32 VMEM scratch holds the last
                                 column's run count of the previous W tile
                                 (TPU grid order is row-major, last dim
                                 fastest, so tiles of one image are visited
                                 in order; the carry is re-zeroed at j == 0
                                 for each new image);
  per-image totals             — ``n_hyperedges`` / ``n_transitions``
                                 accumulate into a revisited (1, 1) output
                                 block (standard TPU reduction pattern),
                                 masked to the valid W columns so padding
                                 never leaks into the totals.

``fused_analyze_streamed`` extends the same structure with a third grid dim
over H tiles for images whose full column does not fit the VMEM budget,
reusing the carry-row pattern of ``_colscan_streamed_kernel``: an int8
(1, block_w) scratch carries the previous H block's last row, the per-column
counts accumulate into the revisited ``runs`` block, and the step-2 diff +
total accumulation fire on the final H tile of each column tile, when the
tile's counts are complete.

Both wrappers return per-image (B, W) planes and (B,) totals; padding
columns (W rounded up to the lane multiple) are sliced off and padded rows
(streamed variant) are zero, which cannot start a run. Outputs are
bit-identical to ``repro.core.ychg.analyze`` — the parity suite in
``tests/test_ychg_fused.py`` enforces exact equality including dtypes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _vmem(shape, dtype):
    """VMEM scratch allocator; TPU-only import kept local (interpret mode
    accepts the spec unchanged)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _valid_cols(j, *, w: int, block_w: int) -> Array:
    """(block_w,) bool — True for columns of tile j inside the real width w."""
    col = j * block_w + jax.lax.broadcasted_iota(jnp.int32, (1, block_w), 1)
    return col[0] < w


def _step2_finish(runs, j, carry_ref, nh_ref, nt_ref, *, w: int, block_w: int):
    """In-register step 2 for a tile's completed (bw,) run counts: diff
    against the carried left-neighbour count, accumulate the masked per-image
    totals, advance the carry. Shared by both kernels so the seam/masking
    logic cannot diverge. Returns (trans_i32, births, deaths) as
    (1, 1, bw) output planes."""
    prev = jnp.concatenate([carry_ref[0], runs[:-1]])
    delta = runs - prev
    births = jnp.maximum(delta, 0)
    deaths = jnp.maximum(-delta, 0)
    trans = delta != 0
    valid = _valid_cols(j, w=w, block_w=block_w)
    nh_ref[...] += jnp.sum(jnp.where(valid, births, 0), dtype=jnp.int32)
    nt_ref[...] += jnp.sum(
        jnp.where(valid, trans, False).astype(jnp.int32), dtype=jnp.int32
    )
    carry_ref[...] = runs[-1:].reshape(1, 1)
    return (
        trans.astype(jnp.int32)[None, None, :],
        births[None, None, :],
        deaths[None, None, :],
    )


def _fused_kernel(
    img_ref,
    runs_ref,
    trans_ref,
    births_ref,
    deaths_ref,
    nh_ref,
    nt_ref,
    carry_ref,
    *,
    w: int,
    block_w: int,
):
    """Grid (B, W tiles). Block: img (1, H, bw) int8 -> all step-1/2 outputs.

    carry_ref (1, 1) int32: run count of the previous tile's last column.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _new_image():
        carry_ref[...] = jnp.zeros_like(carry_ref)
        nh_ref[...] = jnp.zeros_like(nh_ref)
        nt_ref[...] = jnp.zeros_like(nt_ref)

    x = img_ref[0] != 0  # (H, bw) bool in VREGs
    first = x[0:1, :].astype(jnp.int32)
    rising = jnp.logical_and(x[1:, :], jnp.logical_not(x[:-1, :]))
    runs = first.sum(axis=0) + rising.astype(jnp.int32).sum(axis=0)  # (bw,)

    # step 2 in-register: the only cross-tile dependency is one scalar.
    trans_p, births_p, deaths_p = _step2_finish(
        runs, j, carry_ref, nh_ref, nt_ref, w=w, block_w=block_w
    )
    runs_ref[...] = runs[None, None, :]
    trans_ref[...] = trans_p
    births_ref[...] = births_p
    deaths_ref[...] = deaths_p


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def fused_analyze_pallas(
    imgs: Array, *, block_w: int = 128, interpret: bool = True
) -> dict[str, Array]:
    """Both yCHG steps for a (B, H, W) stack in one kernel launch.

    Returns dict of runs/transitions/births/deaths (B, W) and
    n_hyperedges/n_transitions (B,) — same values as ``core.ychg.analyze``.
    """
    b, h, w = imgs.shape
    x = (imgs != 0).astype(jnp.int8)
    w_pad = -w % block_w
    if w_pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, w_pad)))
    wp = w + w_pad
    vec = pl.BlockSpec((1, 1, block_w), lambda bi, j: (bi, 0, j))
    tot = pl.BlockSpec((1, 1), lambda bi, j: (bi, 0))
    runs, trans, births, deaths, nh, nt = pl.pallas_call(
        functools.partial(_fused_kernel, w=w, block_w=block_w),
        grid=(b, wp // block_w),
        in_specs=[pl.BlockSpec((1, h, block_w), lambda bi, j: (bi, 0, j))],
        out_specs=[vec, vec, vec, vec, tot, tot],
        out_shape=[jax.ShapeDtypeStruct((b, 1, wp), jnp.int32)] * 4
        + [jax.ShapeDtypeStruct((b, 1), jnp.int32)] * 2,
        scratch_shapes=[_vmem((1, 1), jnp.int32)],
        interpret=interpret,
    )(x)
    return {
        "runs": runs[:, 0, :w],
        "transitions": trans[:, 0, :w] != 0,
        "births": births[:, 0, :w],
        "deaths": deaths[:, 0, :w],
        "n_hyperedges": nh[:, 0],
        "n_transitions": nt[:, 0],
    }


def _fused_streamed_kernel(
    img_ref,
    runs_ref,
    trans_ref,
    births_ref,
    deaths_ref,
    nh_ref,
    nt_ref,
    row_carry_ref,
    tile_carry_ref,
    *,
    w: int,
    block_w: int,
):
    """Grid (B, W tiles, H tiles); H fastest so each column tile completes
    before the next starts.

    row_carry_ref  (1, bw) int8  — previous H block's last row (run detection
                                   across the H seam).
    tile_carry_ref (1, 1) int32  — previous W tile's last-column run count
                                   (step-2 seam), updated only on final H
                                   tiles so it survives the H loop.
    """
    j = pl.program_id(1)
    i = pl.program_id(2)
    last_i = pl.num_programs(2) - 1

    @pl.when(jnp.logical_and(j == 0, i == 0))
    def _new_image():
        tile_carry_ref[...] = jnp.zeros_like(tile_carry_ref)
        nh_ref[...] = jnp.zeros_like(nh_ref)
        nt_ref[...] = jnp.zeros_like(nt_ref)

    @pl.when(i == 0)
    def _new_tile():
        row_carry_ref[...] = jnp.zeros_like(row_carry_ref)
        runs_ref[...] = jnp.zeros_like(runs_ref)
        trans_ref[...] = jnp.zeros_like(trans_ref)
        births_ref[...] = jnp.zeros_like(births_ref)
        deaths_ref[...] = jnp.zeros_like(deaths_ref)

    x = img_ref[0] != 0  # (bh, bw)
    prev_last = row_carry_ref[...] != 0  # (1, bw)
    prev_rows = jnp.concatenate([prev_last, x[:-1, :]], axis=0)
    rising = jnp.logical_and(x, jnp.logical_not(prev_rows))
    runs_ref[...] += rising.astype(jnp.int32).sum(axis=0)[None, None, :]
    row_carry_ref[...] = x[-1:, :].astype(jnp.int8)

    @pl.when(i == last_i)
    def _finish_tile():
        runs = runs_ref[0, 0, :]  # complete per-column counts for tile j
        trans_p, births_p, deaths_p = _step2_finish(
            runs, j, tile_carry_ref, nh_ref, nt_ref, w=w, block_w=block_w
        )
        trans_ref[...] = trans_p
        births_ref[...] = births_p
        deaths_ref[...] = deaths_p


@functools.partial(jax.jit, static_argnames=("block_w", "block_h", "interpret"))
def fused_analyze_streamed(
    imgs: Array,
    *,
    block_w: int = 128,
    block_h: int = 2048,
    interpret: bool = True,
) -> dict[str, Array]:
    """Streamed fused pipeline for tall images: one launch, H tiled too."""
    b, h, w = imgs.shape
    x = (imgs != 0).astype(jnp.int8)
    w_pad = -w % block_w
    h_pad = -h % block_h
    if w_pad or h_pad:
        # zero rows end runs and start none; zero cols carry zero counts.
        x = jnp.pad(x, ((0, 0), (0, h_pad), (0, w_pad)))
    hp, wp = h + h_pad, w + w_pad
    vec = pl.BlockSpec((1, 1, block_w), lambda bi, j, i: (bi, 0, j))
    tot = pl.BlockSpec((1, 1), lambda bi, j, i: (bi, 0))
    runs, trans, births, deaths, nh, nt = pl.pallas_call(
        functools.partial(_fused_streamed_kernel, w=w, block_w=block_w),
        grid=(b, wp // block_w, hp // block_h),
        in_specs=[pl.BlockSpec((1, block_h, block_w), lambda bi, j, i: (bi, i, j))],
        out_specs=[vec, vec, vec, vec, tot, tot],
        out_shape=[jax.ShapeDtypeStruct((b, 1, wp), jnp.int32)] * 4
        + [jax.ShapeDtypeStruct((b, 1), jnp.int32)] * 2,
        scratch_shapes=[_vmem((1, block_w), jnp.int8), _vmem((1, 1), jnp.int32)],
        interpret=interpret,
    )(x)
    return {
        "runs": runs[:, 0, :w],
        "transitions": trans[:, 0, :w] != 0,
        "births": births[:, 0, :w],
        "deaths": deaths[:, 0, :w],
        "n_hyperedges": nh[:, 0],
        "n_transitions": nt[:, 0],
    }
