"""Fault-tolerant checkpointing: atomic npz shards + manifest, resume logic.

Design (scaled-down Orbax-style, no external deps):

  ckpt_dir/
    step_000100/
      manifest.json        {step, leaf index: path -> (file, shape, dtype), done: true}
      shard_00000.npz      flat leaves, chunked ~512 MB per file
    step_000200/ ...
    LATEST                 atomic pointer file, written last

Crash safety: shards are written to ``step_X.tmp/`` then the directory is
atomically renamed and LATEST updated (the manifest itself is also written
via temp + ``os.replace`` inside the staging dir); a step directory whose
manifest is missing, unparsable, lacks ``done: true``, or references a
shard file that is absent or not a valid zip archive is treated as
*invalid*: ``latest_step`` warns and falls back to the newest **valid**
step instead of crashing the restoring job, so a kill mid-save — or a torn
disk write that corrupts the newest checkpoint — costs at most one
checkpoint interval, never the whole bulk job. ``keep`` bounds disk usage.

Elastic restore: leaves are stored by pytree path, restore re-shards onto
whatever mesh/topology the restoring job uses (restore(shardings=...) places
each leaf with jax.device_put against the *new* sharding), so scale-up /
scale-down restarts work — tested in tests/test_checkpoint.py.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import warnings
import zipfile
from typing import Any, Optional

import jax
import numpy as np

_SHARD_BYTES = 512 * 1024 * 1024


def _flatten_with_paths(tree: Any):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(p), v) for p, v in leaves]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any) -> str:
        """Blocking unless async_save; returns the final step directory."""
        host = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host)
        return os.path.join(self.dir, f"step_{step:08d}")

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        items = _flatten_with_paths(host_tree)
        index, shard, size, shard_id = {}, {}, 0, 0

        def flush():
            nonlocal shard, size, shard_id
            if shard:
                np.savez(os.path.join(tmp, f"shard_{shard_id:05d}.npz"), **shard)
                shard, size = {}, 0
                shard_id += 1

        for i, (path, arr) in enumerate(items):
            key = f"leaf_{i:06d}"
            index[path] = {
                "file": f"shard_{shard_id:05d}.npz",
                "key": key,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
            shard[key] = arr
            size += arr.nbytes
            if size >= _SHARD_BYTES:
                flush()
        flush()
        # manifest via temp + atomic rename: a kill mid-json.dump leaves a
        # .tmp file the validator ignores, never a half-written manifest
        # that parses but lies
        man_tmp = os.path.join(tmp, "manifest.json.tmp")
        with open(man_tmp, "w") as f:
            json.dump({"step": step, "index": index, "done": True}, f)
        os.replace(man_tmp, os.path.join(tmp, "manifest.json"))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        latest_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(os.path.basename(final))
        os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self):
        steps = sorted(
            d for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for d in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def _validate_step_dir(self, name: str) -> Optional[int]:
        """Step number if ``name`` holds a complete, readable checkpoint.

        A valid step dir has a parsable manifest with ``done: true`` whose
        every referenced shard file exists and is a well-formed zip (npz)
        containing the expected member. Anything else — truncated JSON from
        a kill mid-write, a missing or torn shard — returns None.
        """
        d = os.path.join(self.dir, name)
        man = os.path.join(d, "manifest.json")
        try:
            with open(man) as f:
                m = json.load(f)
        except (OSError, ValueError):
            return None
        if not m.get("done") or not isinstance(m.get("step"), int):
            return None
        index = m.get("index", {})
        try:
            members_by_file: dict[str, set] = {}
            for meta in index.values():
                members_by_file.setdefault(meta["file"], set()).add(
                    meta["key"] + ".npy")
            for fname, members in members_by_file.items():
                with zipfile.ZipFile(os.path.join(d, fname)) as z:
                    if not members.issubset(set(z.namelist())):
                        return None
        except (OSError, KeyError, TypeError, zipfile.BadZipFile):
            return None
        return m["step"]

    def latest_step(self) -> Optional[int]:
        """Newest *valid* step, or None.

        The LATEST pointer is a hint, not an authority: if the step it
        names fails validation (kill during ``_write``, torn shard), this
        warns and scans the step directories newest-first for the first
        one that validates, so a corrupt checkpoint costs one save
        interval instead of crashing the whole bulk job.
        """
        ptr = os.path.join(self.dir, "LATEST")
        pointed: Optional[str] = None
        if os.path.exists(ptr):
            try:
                with open(ptr) as f:
                    pointed = f.read().strip()
            except OSError:
                pointed = None
        if pointed:
            step = self._validate_step_dir(pointed)
            if step is not None:
                return step
            warnings.warn(
                f"checkpoint {pointed!r} (named by LATEST) is incomplete "
                f"or corrupt; falling back to the newest valid step",
                RuntimeWarning, stacklevel=2)
        candidates = sorted(
            (d for d in os.listdir(self.dir)
             if d.startswith("step_") and not d.endswith(".tmp")),
            reverse=True)
        for name in candidates:
            if name == pointed:
                continue  # already failed validation above
            step = self._validate_step_dir(name)
            if step is not None:
                return step
            warnings.warn(
                f"checkpoint {name!r} is incomplete or corrupt; skipping",
                RuntimeWarning, stacklevel=2)
        return None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; optionally re-shard."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        index = manifest["index"]
        files: dict[str, Any] = {}

        def load(path: str):
            meta = index[path]
            if meta["file"] not in files:
                files[meta["file"]] = np.load(os.path.join(d, meta["file"]))
            arr = files[meta["file"]][meta["key"]]
            return arr

        paths_leaves = jax.tree_util.tree_leaves_with_path(like)
        shard_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None
            else [None] * len(paths_leaves)
        )
        out = []
        for (p, leaf), shd in zip(paths_leaves, shard_leaves):
            arr = load(jax.tree_util.keystr(p))
            arr = arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), out
        )
