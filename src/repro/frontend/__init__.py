"""`repro.frontend` — the network edge of the yCHG ROI service.

`repro.service` answers "how do I serve the algorithm to in-process
callers"; this package answers "how does traffic reach it over a wire":
an asyncio HTTP/JSON transport (plus an optional length-prefixed TCP RPC)
that bridges requests onto the threaded :class:`~repro.service.YCHGService`
with ``run_in_executor`` + futures, streams batched results as NDJSON in
completion order, maps admission-control sheds to HTTP 429 with a
drain-rate-derived ``Retry-After``, and exposes ``/healthz`` +
``/metrics`` (Prometheus text, per-bucket shed counters included).

    from repro.frontend import ServerThread, YCHGClient
    from repro.service import ServiceConfig, YCHGService

    service = YCHGService(config=ServiceConfig(bucket_sides=(128, 256)))
    with service, ServerThread(service) as srv, \\
            YCHGClient("127.0.0.1", srv.port) as client:
        out = client.analyze(mask)              # to_host()-shaped dict
        for item in client.analyze_batch(masks):  # completion order
            ...

Results over the wire are **bit-identical** to in-process
``service.submit`` (base64 of the raw array bytes, dtypes preserved) —
the tier-1 suite and the CI frontend-smoke job both hold it to that bar.
"""

from repro.frontend.client import (
    AsyncRPCClient,
    BatchItem,
    FrontendError,
    FrontendOverloaded,
    YCHGClient,
)
from repro.frontend.server import FrontendServer, ServerThread

__all__ = [
    "AsyncRPCClient",
    "BatchItem",
    "FrontendError",
    "FrontendOverloaded",
    "FrontendServer",
    "ServerThread",
    "YCHGClient",
]
