"""Wire format shared by the HTTP and RPC transports.

Everything on the wire is JSON; arrays travel as raw little-endian bytes
base64-encoded next to their shape and dtype, so a round trip is
**bit-identical** — the CI frontend-smoke job holds a client result to
byte equality with the in-process ``YCHGService.submit`` result, and this
encoding is what makes that a meaningful check (float-free, no repr
round-off, dtypes preserved).

Three layers live here, all transport-agnostic and numpy-only (no jax):

  * array codec — :func:`encode_array` / :func:`decode_array`;
  * result codec — :func:`encode_result` / :func:`decode_result`: the
    seven ``YCHGResult`` fields as encoded arrays (the host view a
    ``result.to_host()`` call produces);
  * framing — :func:`dumps_line` for NDJSON streaming over HTTP, and
    :func:`pack_frame` / :func:`read_frame` for the length-prefixed TCP
    RPC transport (4-byte big-endian payload length, then JSON).
"""

from __future__ import annotations

import base64
import json
from typing import Any, Dict, Optional

import numpy as np

# yCHG result fields, in the engine's canonical order
RESULT_FIELDS = ("runs", "cut_vertices", "transitions", "births", "deaths",
                 "n_hyperedges", "n_transitions")

# Per-op result fields. yCHG keeps RESULT_FIELDS (its wire format is
# byte-for-byte what it was before the multi-op refactor); each new op
# lists its own ``to_host()`` keys in canonical order. A pipeline key
# ("denoise+ychg") answers with its terminal stage's fields.
OP_RESULT_FIELDS = {
    "ychg": RESULT_FIELDS,
    "ccl": ("labels", "n_components"),
    "denoise": ("image",),
}


def result_fields(op: str) -> tuple:
    """The wire fields for an op (or ``"+"``-joined pipeline) key."""
    terminal = op.rsplit("+", 1)[-1]
    try:
        return OP_RESULT_FIELDS[terminal]
    except KeyError:
        raise ProtocolError(
            f"unknown op {op!r} on the wire; known ops: "
            f"{sorted(OP_RESULT_FIELDS)}") from None

# one RPC frame's maximum payload: far above any bucket-ladder mask or
# result, far below anything that could balloon a peer's memory
MAX_FRAME_BYTES = 64 * 1024 * 1024

# Traffic-shaping contract (docs/traffic.md): one name per transport.
# HTTP requests carry these headers; RPC frames carry the matching
# optional fields "klass", "deadline_ms", "tenant". None of the three
# ever changes a payload, cache key, or routing key — a classed result
# is bit-identical to an unclassed one.
TRAFFIC_CLASS_HEADER = "X-YCHG-Class"
TRAFFIC_DEADLINE_HEADER = "X-YCHG-Deadline-Ms"
TRAFFIC_TENANT_HEADER = "X-YCHG-Tenant"


def decode_traffic(klass: Any = None, deadline_ms: Any = None,
                   tenant: Any = None) -> Dict[str, Any]:
    """Validate the three optional traffic-shaping fields off the wire.

    Accepts raw header strings or RPC frame JSON values; returns the
    ``Service.submit`` kwargs dict (``klass`` / ``deadline_ms`` /
    ``tenant``, absent fields as None). Malformed values raise
    :class:`ProtocolError` — a bad deadline is a 400-class client error,
    never a 500.
    """
    out: Dict[str, Any] = {"klass": None, "deadline_ms": None,
                           "tenant": None}
    if klass is not None:
        if not isinstance(klass, str) or not klass.strip():
            raise ProtocolError(f"malformed traffic class {klass!r}")
        out["klass"] = klass.strip()
    if deadline_ms is not None:
        try:
            out["deadline_ms"] = float(deadline_ms)
        except (TypeError, ValueError) as e:
            raise ProtocolError(
                f"malformed deadline_ms {deadline_ms!r}: {e}") from e
    if tenant is not None:
        if not isinstance(tenant, str) or not tenant.strip():
            raise ProtocolError(f"malformed tenant {tenant!r}")
        out["tenant"] = tenant.strip()
    return out


class ProtocolError(ValueError):
    """A malformed wire payload (bad JSON shape, dtype, length, frame)."""


# ------------------------------------------------------------ array codec


def encode_array(a: np.ndarray) -> Dict[str, Any]:
    """A numpy array as a JSON-safe dict: shape + dtype + base64 bytes."""
    a = np.asarray(a)
    if not a.flags.c_contiguous:
        # NOT ascontiguousarray unconditionally: it silently promotes 0-d
        # arrays (the B=1 result scalars) to 1-d, breaking bit-identity
        a = np.ascontiguousarray(a)
    return {
        "shape": list(a.shape),
        "dtype": str(a.dtype),
        "b64": base64.b64encode(a.tobytes()).decode("ascii"),
    }


def decode_array(d: Dict[str, Any]) -> np.ndarray:
    """Inverse of :func:`encode_array`; extra keys (``id``) are ignored.

    Validates that the payload length matches shape x dtype, so a
    truncated or padded body fails loudly instead of reshaping garbage.
    Dims must be strictly positive: nothing on this wire carries empty
    arrays, and a shape like ``[-1, -8]`` has a positive *product* (its
    byte length can match), which would otherwise sail past the length
    check into a bare ``reshape`` ValueError outside the ProtocolError
    contract — the server would answer 500 for what is a bad request.
    """
    try:
        shape = tuple(int(s) for s in d["shape"])
        dtype = np.dtype(str(d["dtype"]))
        raw = base64.b64decode(d["b64"], validate=True)
    except (KeyError, TypeError, ValueError) as e:
        raise ProtocolError(f"malformed array payload: {e}") from e
    if any(s <= 0 for s in shape):
        raise ProtocolError(f"array shape {list(shape)} has non-positive dims")
    expect = dtype.itemsize
    for s in shape:   # python ints: absurd dims can't overflow into a
        expect *= s   # wrong (or negative) int64 expectation
    if len(raw) != expect:
        raise ProtocolError(
            f"array payload is {len(raw)} bytes, shape {shape} dtype "
            f"{dtype} needs {expect}")
    return np.frombuffer(raw, dtype).reshape(shape).copy()


# ----------------------------------------------------------- result codec


def encode_result(result: Any, op: str = "ychg") -> Dict[str, Any]:
    """An op result pytree (or host dict of its fields) as encoded arrays.

    The default ``op="ychg"`` keeps every pre-multi-op call site and wire
    payload unchanged.
    """
    host = result if isinstance(result, dict) else result.to_host()
    return {f: encode_array(np.asarray(host[f])) for f in result_fields(op)}


def decode_result(d: Dict[str, Any],
                  op: str = "ychg") -> Dict[str, np.ndarray]:
    """Inverse of :func:`encode_result`: the ``to_host()``-shaped dict."""
    try:
        return {f: decode_array(d[f]) for f in result_fields(op)}
    except KeyError as e:
        raise ProtocolError(f"result payload missing field {e}") from e


# ---------------------------------------------------------------- framing


def dumps_line(obj: Any) -> bytes:
    """One NDJSON line: compact JSON + newline (the HTTP stream unit)."""
    return json.dumps(obj, separators=(",", ":")).encode() + b"\n"


def pack_frame(obj: Any) -> bytes:
    """One RPC frame: 4-byte big-endian payload length, then JSON."""
    payload = json.dumps(obj, separators=(",", ":")).encode()
    if len(payload) > MAX_FRAME_BYTES:
        raise ProtocolError(
            f"frame payload {len(payload)} bytes exceeds "
            f"MAX_FRAME_BYTES={MAX_FRAME_BYTES}")
    return len(payload).to_bytes(4, "big") + payload


def unpack_frame_header(head: bytes) -> int:
    """Payload length from the 4-byte frame header, bounds-checked."""
    n = int.from_bytes(head, "big")
    if n > MAX_FRAME_BYTES:
        raise ProtocolError(
            f"frame of {n} bytes exceeds MAX_FRAME_BYTES={MAX_FRAME_BYTES}")
    return n


async def read_frame(reader: Any) -> Optional[Any]:
    """Read one frame from an asyncio ``StreamReader``; None on clean EOF.

    EOF mid-frame (header or payload truncated) raises
    :class:`ProtocolError` — a peer vanishing between frames is normal,
    vanishing inside one is a broken transport.
    """
    import asyncio

    try:
        head = await reader.readexactly(4)
    except asyncio.IncompleteReadError as e:
        if not e.partial:
            return None
        raise ProtocolError("EOF inside a frame header") from e
    n = unpack_frame_header(head)
    try:
        payload = await reader.readexactly(n)
    except asyncio.IncompleteReadError as e:
        raise ProtocolError("EOF inside a frame payload") from e
    try:
        return json.loads(payload)
    except ValueError as e:
        raise ProtocolError(f"frame payload is not JSON: {e}") from e
