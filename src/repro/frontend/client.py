"""Clients for the yCHG front end: blocking HTTP + async RPC.

`YCHGClient` is the stdlib-only blocking client: one persistent HTTP/1.1
connection (keep-alive, reconnect on failure), ``analyze`` for one mask,
and **streaming** ``analyze_batch`` — results are yielded as the server
completes them (NDJSON lines decoded incrementally off the chunked
response), not after the whole batch lands, so a consumer can overlap its
own work with the service's compute. A 429 on ``analyze`` raises
:class:`FrontendOverloaded` carrying the server's drain-rate-derived
``retry_after_s``; inside a batch stream, shed masks arrive as per-item
error lines while admitted masks keep streaming.

`AsyncRPCClient` speaks the length-prefixed TCP transport: many analyzes
in flight on one connection, responses demuxed by id in completion order.
"""

from __future__ import annotations

import asyncio
import dataclasses
import http.client
import json
import math
import time
from typing import Any, Dict, Iterable, Iterator, List, Optional, Sequence

import numpy as np

from repro.frontend import protocol
from repro.obs import maybe_trace

# must match repro.frontend.server.TRACE_HEADER (kept literal here so the
# client stays importable without the server module)
TRACE_HEADER = "X-YCHG-Trace"


def _traffic_headers(klass: Optional[str], deadline_ms: Optional[float],
                     tenant: Optional[str]) -> Dict[str, str]:
    """The traffic-shaping headers for one request (docs/traffic.md);
    absent kwargs send nothing, so an unshaped request is byte-for-byte
    the pre-traffic-classes wire request."""
    headers: Dict[str, str] = {}
    if klass is not None:
        headers[protocol.TRAFFIC_CLASS_HEADER] = str(klass)
    if deadline_ms is not None:
        headers[protocol.TRAFFIC_DEADLINE_HEADER] = repr(float(deadline_ms))
    if tenant is not None:
        headers[protocol.TRAFFIC_TENANT_HEADER] = str(tenant)
    return headers


def _put_traffic_fields(frame: Dict[str, Any], klass: Optional[str],
                        deadline_ms: Optional[float],
                        tenant: Optional[str]) -> None:
    """RPC-frame twin of :func:`_traffic_headers`: set only the fields
    given, so an unshaped frame is byte-for-byte the pre-traffic frame."""
    if klass is not None:
        frame["klass"] = str(klass)
    if deadline_ms is not None:
        frame["deadline_ms"] = float(deadline_ms)
    if tenant is not None:
        frame["tenant"] = str(tenant)


class FrontendError(RuntimeError):
    """A non-2xx response from the front end (with its HTTP status)."""

    def __init__(self, message: str, status: int = 500):
        super().__init__(message)
        self.status = status


class FrontendOverloaded(FrontendError):
    """HTTP 429: the service shed this request at an admission bound.

    ``retry_after_s`` is the server's estimate of how long the current
    backlog needs to drain (the ``Retry-After`` header, float precision
    from the JSON body when present).

    ``kind`` distinguishes what shed the request: ``"overload"`` (an
    admission bound), ``"deadline"`` (predicted delay past the request's
    ``deadline_ms``), or ``"quota"`` (tenant token bucket empty) — the
    body's ``kind`` field, defaulting to ``"overload"`` for older
    servers.
    """

    def __init__(self, message: str, retry_after_s: float = 1.0,
                 kind: str = "overload"):
        super().__init__(message, status=429)
        self.retry_after_s = retry_after_s
        self.kind = kind


@dataclasses.dataclass(frozen=True)
class BatchItem:
    """One completed line of a streamed batch: a result or an error."""

    id: Any
    result: Optional[Dict[str, np.ndarray]] = None
    error: Optional[str] = None
    status: int = 200
    retry_after_s: Optional[float] = None

    @property
    def ok(self) -> bool:
        return self.result is not None


def _retry_after_s(obj: Dict[str, Any], headers: Any,
                   default: float = 1.0) -> float:
    """Backoff seconds out of a 429: the body's float-precision
    ``retry_after_s`` when usable, else the ``Retry-After`` header, else
    ``default``. A malformed, empty, or absent value must degrade to the
    default — never raise out of the client (pre-fix, a bogus header made
    ``float()`` throw ``ValueError`` instead of ``FrontendOverloaded``)."""
    for value in (obj.get("retry_after_s"), headers.get("Retry-After")):
        try:
            retry = float(value)
        except (TypeError, ValueError):
            continue
        if math.isfinite(retry) and retry >= 0:
            return retry
    return default


def _decode_line(obj: Dict[str, Any]) -> BatchItem:
    if "result" in obj:
        return BatchItem(id=obj.get("id"),
                         result=protocol.decode_result(obj["result"]))
    return BatchItem(id=obj.get("id"), error=obj.get("error", "unknown"),
                     status=int(obj.get("status", 500)),
                     retry_after_s=obj.get("retry_after_s"))


class YCHGClient:
    """Blocking HTTP client over one keep-alive loopback connection."""

    def __init__(self, host: str = "127.0.0.1", port: int = 8788, *,
                 timeout: float = 300.0):
        self.host = host
        self.port = port
        self.timeout = timeout
        self._conn: Optional[http.client.HTTPConnection] = None

    # ------------------------------------------------------------- plumbing

    def _connection(self) -> http.client.HTTPConnection:
        if self._conn is None:
            self._conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.timeout)
        return self._conn

    def close(self) -> None:
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def __enter__(self) -> "YCHGClient":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    def _request(self, method: str, path: str,
                 body: Optional[bytes] = None,
                 headers: Optional[Dict[str, str]] = None,
                 ) -> http.client.HTTPResponse:
        """One request with a single transparent retry on a dropped
        keep-alive connection (the server or an idle timeout closed it)."""
        for attempt in (0, 1):
            conn = self._connection()
            try:
                hdrs = dict(headers or {})
                if body:
                    hdrs.setdefault("Content-Type", "application/json")
                conn.request(method, path, body=body, headers=hdrs)
                return conn.getresponse()
            except (http.client.HTTPException, ConnectionError, OSError):
                self.close()
                if attempt:
                    raise
        raise AssertionError("unreachable")

    def wait_ready(self, timeout: float = 30.0) -> Dict[str, Any]:
        """Poll /healthz until the server answers (connect retries), for
        callers racing a freshly launched server process."""
        deadline = time.monotonic() + timeout
        while True:
            try:
                return self.health()
            except (FrontendError, ConnectionError, OSError):
                if time.monotonic() > deadline:
                    raise
                time.sleep(0.05)

    # ------------------------------------------------------------- requests

    def health(self) -> Dict[str, Any]:
        resp = self._request("GET", "/healthz")
        body = resp.read()
        if resp.status != 200:
            raise FrontendError(body.decode(errors="replace"), resp.status)
        return json.loads(body)

    def metrics_text(self) -> str:
        resp = self._request("GET", "/metrics")
        body = resp.read()
        if resp.status != 200:
            raise FrontendError(body.decode(errors="replace"), resp.status)
        return body.decode()

    def debug_traces(self) -> Dict[str, Any]:
        """The server's flight recorder as parsed Chrome-trace JSON
        (``{"traceEvents": [...]}``), straight off ``GET /debug/traces``."""
        resp = self._request("GET", "/debug/traces")
        body = resp.read()
        if resp.status != 200:
            raise FrontendError(body.decode(errors="replace"), resp.status)
        return json.loads(body)

    def analyze(self, mask: np.ndarray, id: Any = None,
                trace_id: Optional[str] = None, *,
                op: Optional[str] = None, klass: Optional[str] = None,
                deadline_ms: Optional[float] = None,
                tenant: Optional[str] = None) -> Dict[str, np.ndarray]:
        """One mask -> the ``to_host()``-shaped result dict (bit-identical
        to in-process ``service.submit(mask).result().to_host()``).

        ``op`` posts to ``/v1/{op}`` (``/v1/ccl``, ``/v1/denoise``, ...);
        the default keeps the historical ``/v1/analyze`` route and wire
        format. ``trace_id`` propagates over the ``X-YCHG-Trace`` header
        so the server's spans join the caller's trace; the client's own
        encode + wire spans land in this process's flight recorder under
        the same id. ``klass`` / ``deadline_ms`` / ``tenant`` ride the
        traffic-shaping headers (docs/traffic.md); a shed comes back as
        :class:`FrontendOverloaded` with ``kind`` naming the check that
        tripped."""
        path = "/v1/analyze" if op is None else f"/v1/{op}"
        return self._analyze_path(path, mask, id, trace_id,
                                  wire_op=op or "ychg",
                                  traffic=_traffic_headers(
                                      klass, deadline_ms, tenant))

    def pipeline(self, mask: np.ndarray, stages: Sequence[str],
                 id: Any = None, trace_id: Optional[str] = None, *,
                 klass: Optional[str] = None,
                 deadline_ms: Optional[float] = None,
                 tenant: Optional[str] = None) -> Dict[str, np.ndarray]:
        """One mask through ``POST /v1/pipeline``; the terminal stage's
        ``to_host()``-shaped result dict."""
        stages = [str(s) for s in stages]
        if not stages:
            raise ValueError("pipeline needs at least one stage")
        return self._analyze_path("/v1/pipeline", mask, id, trace_id,
                                  wire_op=stages[-1], stages=stages,
                                  traffic=_traffic_headers(
                                      klass, deadline_ms, tenant))

    def _analyze_path(self, path: str, mask: np.ndarray, id: Any,
                      trace_id: Optional[str], *, wire_op: str,
                      stages: Optional[List[str]] = None,
                      traffic: Optional[Dict[str, str]] = None,
                      ) -> Dict[str, np.ndarray]:
        tr = maybe_trace(trace_id, process="client")
        try:
            t0 = time.monotonic()
            req = dict(protocol.encode_array(np.asarray(mask)))
            payload_obj: Dict[str, Any] = {"mask": req, "id": id}
            if stages is not None:
                payload_obj["stages"] = stages
            body = json.dumps(payload_obj).encode()
            t1 = time.monotonic()
            tr.add("client.encode", t0, t1, bytes=len(body))
            headers = dict(traffic) if traffic else {}
            if tr.enabled:
                headers[TRACE_HEADER] = tr.trace_id
            resp = self._request("POST", path, body, headers or None)
            payload = resp.read()
            tr.add("client.wire", t1, time.monotonic(),
                   status=resp.status)
            if resp.status == 429:
                try:
                    obj = json.loads(payload)
                except ValueError:
                    obj = {}
                raise FrontendOverloaded(
                    obj.get("error", "overloaded"),
                    retry_after_s=_retry_after_s(obj, resp.headers),
                    kind=obj.get("kind", "overload"))
            if resp.status != 200:
                raise FrontendError(payload.decode(errors="replace"),
                                    resp.status)
            return protocol.decode_result(json.loads(payload)["result"],
                                          wire_op)
        finally:
            tr.finish()

    def analyze_batch(self, masks: Sequence[np.ndarray],
                      ids: Optional[Iterable[Any]] = None,
                      trace_id: Optional[str] = None, *,
                      klass: Optional[str] = None,
                      deadline_ms: Optional[float] = None,
                      tenant: Optional[str] = None) -> Iterator[BatchItem]:
        """Submit a batch; yield :class:`BatchItem` per mask **in the
        server's completion order**, as the lines arrive off the wire."""
        id_list: List[Any] = (list(ids) if ids is not None
                              else list(range(len(masks))))
        if len(id_list) != len(masks):
            raise ValueError(
                f"{len(masks)} masks but {len(id_list)} ids")
        tr = maybe_trace(trace_id, process="client")
        try:
            t0 = time.monotonic()
            items = []
            for rid, m in zip(id_list, masks):
                d = dict(protocol.encode_array(np.asarray(m)))
                d["id"] = rid
                items.append(d)
            body = json.dumps({"masks": items}).encode()
            t1 = time.monotonic()
            tr.add("client.encode", t0, t1, bytes=len(body),
                   masks=len(items))
            headers = _traffic_headers(klass, deadline_ms, tenant)
            if tr.enabled:
                headers[TRACE_HEADER] = tr.trace_id
            resp = self._request("POST", "/v1/analyze_batch", body,
                                 headers or None)
            if resp.status != 200:
                payload = resp.read()
                raise FrontendError(payload.decode(errors="replace"),
                                    resp.status)
            # http.client decodes the chunked framing; readline() returns
            # one NDJSON line as soon as its chunk lands — the streaming
            while True:
                line = resp.readline()
                if not line:
                    break
                yield _decode_line(json.loads(line))
            tr.add("client.wire", t1, time.monotonic(), masks=len(items))
        finally:
            tr.finish()


class AsyncRPCClient:
    """Length-prefixed TCP RPC client: pipelined analyzes on one socket."""

    def __init__(self, host: str = "127.0.0.1", port: int = 8789):
        self.host = host
        self.port = port
        self._reader: Optional[asyncio.StreamReader] = None
        self._writer: Optional[asyncio.StreamWriter] = None
        self._pending: Dict[int, "asyncio.Future[Dict[str, Any]]"] = {}
        self._next_id = 0
        self._demux: Optional[asyncio.Task] = None
        self._conn_exc: Optional[Exception] = None

    async def connect(self) -> "AsyncRPCClient":
        self._reader, self._writer = await asyncio.open_connection(
            self.host, self.port)
        self._demux = asyncio.ensure_future(self._demux_loop())
        return self

    async def _demux_loop(self) -> None:
        assert self._reader is not None
        try:
            while True:
                frame = await protocol.read_frame(self._reader)
                if frame is None:
                    break
                fut = self._pending.pop(frame.get("id"), None)
                if fut is not None and not fut.done():
                    fut.set_result(frame)
        except (protocol.ProtocolError, ConnectionError, OSError) as e:
            self._conn_exc = FrontendError(str(e) or type(e).__name__)
            for fut in self._pending.values():
                if not fut.done():
                    fut.set_exception(FrontendError(str(e)))
            self._pending.clear()
        finally:
            # once the demux is gone nothing can ever resolve a pending
            # future, so later call()s must fail fast instead of hanging
            if self._conn_exc is None:
                self._conn_exc = FrontendError("connection closed")
            for fut in self._pending.values():
                if not fut.done():
                    fut.set_exception(FrontendError("connection closed"))
            self._pending.clear()

    async def call(self, frame: Dict[str, Any]) -> Dict[str, Any]:
        """One raw frame -> its response frame (id assigned here). The
        fleet router forwards pre-encoded analyze frames through this
        without re-encoding the mask, which is what keeps the router path
        trivially bit-identical."""
        assert self._writer is not None, "connect() first"
        if self._conn_exc is not None:
            raise self._conn_exc
        rid = self._next_id
        self._next_id += 1
        frame = dict(frame)
        frame["id"] = rid
        fut: "asyncio.Future[Dict[str, Any]]" = (
            asyncio.get_running_loop().create_future())
        self._pending[rid] = fut
        self._writer.write(protocol.pack_frame(frame))
        await self._writer.drain()
        return await fut

    _call = call   # pre-fleet internal name, kept for callers/tests

    async def analyze(self, mask: np.ndarray, *,
                      op: Optional[str] = None, klass: Optional[str] = None,
                      deadline_ms: Optional[float] = None,
                      tenant: Optional[str] = None) -> Dict[str, np.ndarray]:
        frame: Dict[str, Any] = {
            "op": "analyze", "mask": protocol.encode_array(np.asarray(mask))}
        if op is not None:
            frame["opname"] = op
        _put_traffic_fields(frame, klass, deadline_ms, tenant)
        resp = await self._call(frame)
        return self._unwrap(resp, op or "ychg")

    async def pipeline(self, mask: np.ndarray, stages: Sequence[str], *,
                       klass: Optional[str] = None,
                       deadline_ms: Optional[float] = None,
                       tenant: Optional[str] = None) -> Dict[str, np.ndarray]:
        stages = [str(s) for s in stages]
        frame: Dict[str, Any] = {
            "op": "pipeline", "stages": stages,
            "mask": protocol.encode_array(np.asarray(mask))}
        _put_traffic_fields(frame, klass, deadline_ms, tenant)
        resp = await self._call(frame)
        return self._unwrap(resp, stages[-1] if stages else "ychg")

    @staticmethod
    def _unwrap(resp: Dict[str, Any], wire_op: str) -> Dict[str, np.ndarray]:
        if "result" in resp:
            return protocol.decode_result(resp["result"], wire_op)
        status = int(resp.get("status", 500))
        if status == 429:
            raise FrontendOverloaded(resp.get("error", "overloaded"),
                                     retry_after_s=_retry_after_s(resp, {}),
                                     kind=resp.get("kind", "overload"))
        raise FrontendError(resp.get("error", "rpc error"), status)

    async def health(self) -> Dict[str, Any]:
        return await self._call({"op": "health"})

    async def aclose(self) -> None:
        if self._writer is not None:
            self._writer.close()
            try:
                await self._writer.wait_closed()
            except (ConnectionError, OSError):
                pass
        if self._demux is not None:
            await asyncio.wait([self._demux], timeout=5)
            self._demux.cancel()
