"""Asyncio HTTP/JSON (+ optional length-prefixed RPC) front end.

`FrontendServer` puts a network edge on :class:`repro.service.YCHGService`
without adding a second scheduler: every request is bridged onto the
threaded service with ``loop.run_in_executor`` + ``asyncio.wrap_future``,
so the service's own admission control is the only admission control —

  * ``overload_policy="block"`` parks the executor worker (never the event
    loop) until a slot frees: backpressure propagates to exactly the slow
    client, and once all workers are parked further requests queue in the
    executor — the whole edge slows to the service's pace;
  * ``overload_policy="shed"`` maps :class:`ServiceOverloaded` to HTTP 429
    with a ``Retry-After`` derived from the observed queue drain rate
    (completions/second over a rolling sample), so clients back off for
    roughly as long as the backlog needs to clear rather than a constant.

Endpoints (HTTP/1.1, keep-alive, loopback-friendly):

  ``GET  /healthz``           liveness + resolved backend + queue depth
  ``GET  /metrics``           ``ServiceMetrics`` in Prometheus text format
                              (per-bucket shed counters included)
  ``POST /v1/{op}``           one mask -> one JSON result for any
                              registered op (``/v1/ychg``, ``/v1/ccl``,
                              ``/v1/denoise``); an unknown op answers 404
                              JSON naming the registered ops
  ``POST /v1/analyze``        kept alias for ``/v1/ychg`` (the pre-multi-op
                              route, byte-identical responses)
  ``POST /v1/pipeline``       ``{"mask": ..., "stages": [op, ...]}`` -> the
                              terminal stage's result, computed
                              device-resident end to end
  ``POST /v1/analyze_batch``  masks -> chunked NDJSON, one line per result
                              **in completion order** (a slow mask never
                              blocks the lines behind it; shed masks get
                              per-line 429 errors while admitted ones
                              stream normally)

The RPC transport speaks :func:`protocol.pack_frame` frames over TCP with
the same completion-order discipline: many analyzes may be in flight per
connection and responses demux by ``id``. Fleet verbs ride the same
transport: ``cache_probe`` (sibling cache lookup by serialized key, local
only) and ``set_peers`` (point a worker's peered cache at its siblings).

``ServerThread`` runs the whole thing on a dedicated event-loop thread for
synchronous callers (tests, the CLI smoke, benchmarks).
"""

from __future__ import annotations

import asyncio
import functools
import json
import math
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.engine import registry
from repro.engine.ops import op_names
from repro.frontend import protocol
from repro.obs import PromBuilder, maybe_trace, recorder
from repro.service import ServiceOverloaded, YCHGService
from repro.service.metrics import bucket_labels

# request-trace propagation header: a client (or the fleet router) sends
# its trace id here and this process's spans join that trace
TRACE_HEADER = "x-ychg-trace"

# traffic-shaping headers (docs/traffic.md), lowercased to match
# _parse_head's header normalisation; the canonical spellings live in
# repro.frontend.protocol next to the matching RPC frame fields
CLASS_HEADER = protocol.TRAFFIC_CLASS_HEADER.lower()
DEADLINE_HEADER = protocol.TRAFFIC_DEADLINE_HEADER.lower()
TENANT_HEADER = protocol.TRAFFIC_TENANT_HEADER.lower()

# executor width: how many clients may sit inside service.submit at once
# (under "block" each parked worker IS one unit of propagated backpressure)
DEFAULT_SUBMIT_WORKERS = 32


class _DrainRate:
    """Rolling completions/second estimate for Retry-After.

    Samples (monotonic time, completed count) at most once per interval;
    the rate is measured across the window between the oldest kept sample
    and now, so one quiet poll cannot zero it out.
    """

    def __init__(self, interval_s: float = 0.25, keep: int = 8):
        self._interval = interval_s
        self._keep = keep
        self._samples: list[Tuple[float, int]] = []

    def observe(self, completed: int) -> None:
        now = time.monotonic()
        if self._samples and now - self._samples[-1][0] < self._interval:
            return
        self._samples.append((now, completed))
        del self._samples[: -self._keep]

    def rate(self) -> float:
        if len(self._samples) < 2:
            return 0.0
        (t0, c0), (t1, c1) = self._samples[0], self._samples[-1]
        if t1 <= t0:
            return 0.0
        return max(0.0, (c1 - c0) / (t1 - t0))

    def retry_after_s(self, queue_depth: int) -> float:
        """Seconds until the current backlog plausibly drains; 1.0 when no
        drain has been observed yet (cold server), clamped to [0.05, 30]."""
        r = self.rate()
        if r <= 0.0:
            return 1.0
        return min(30.0, max(0.05, (queue_depth + 1) / r))


class FrontendServer:
    """One HTTP (and optionally one RPC) listener over one service."""

    def __init__(self, service: YCHGService, *, host: str = "127.0.0.1",
                 port: int = 0, rpc_port: Optional[int] = None,
                 submit_workers: int = DEFAULT_SUBMIT_WORKERS):
        self.service = service
        self.host = host
        self._want_port = port
        self._want_rpc_port = rpc_port
        self._pool = ThreadPoolExecutor(
            max_workers=submit_workers, thread_name_prefix="ychg-frontend")
        self._drain = _DrainRate()
        self._http_server: Optional[asyncio.AbstractServer] = None
        self._rpc_server: Optional[asyncio.AbstractServer] = None
        self._conns: set = set()

    # ------------------------------------------------------------ lifecycle

    async def start(self) -> None:
        self._http_server = await asyncio.start_server(
            self._handle_http, self.host, self._want_port)
        if self._want_rpc_port is not None:
            self._rpc_server = await asyncio.start_server(
                self._handle_rpc, self.host, self._want_rpc_port)

    @property
    def port(self) -> int:
        assert self._http_server is not None, "server not started"
        return self._http_server.sockets[0].getsockname()[1]

    @property
    def rpc_port(self) -> Optional[int]:
        if self._rpc_server is None:
            return None
        return self._rpc_server.sockets[0].getsockname()[1]

    async def aclose(self) -> None:
        for srv in (self._http_server, self._rpc_server):
            if srv is not None:
                srv.close()
                await srv.wait_closed()
        # close established connections too, so peers see EOF instead of a
        # half-open socket (the fleet router relies on that to reroute
        # promptly when a worker goes away)
        for writer in list(self._conns):
            try:
                writer.close()
            except Exception:
                pass
        self._pool.shutdown(wait=False)

    # ----------------------------------------------------- service bridging

    async def _submit(self, mask, trace=None, op=None, stages=None,
                      traffic=None) -> Any:
        """submit on the executor (a "block" park never blocks the loop),
        then await the service future on the loop. ``trace`` joins the
        service's stage spans to this request's trace (the frontend stays
        the finisher). ``op`` selects a single operator; ``stages`` an
        ordered pipeline (mutually exclusive with ``op``). ``traffic`` is
        the validated klass/deadline_ms/tenant kwargs dict from
        :func:`protocol.decode_traffic`."""
        loop = asyncio.get_running_loop()
        traffic = traffic or {}
        if stages is not None:
            fn = functools.partial(self.service.submit_pipeline, mask,
                                   stages, trace=trace, **traffic)
        else:
            fn = functools.partial(self.service.submit, mask, op=op,
                                   trace=trace, **traffic)
        cf = await loop.run_in_executor(self._pool, fn)
        return await asyncio.wrap_future(cf)

    def _overload_body(self, exc: Exception) -> Tuple[Dict[str, Any], float]:
        """429 body + Retry-After for any admission shed. Deadline and
        quota sheds carry their own exact retry_after_s (the scheduler
        computed it at the shed); plain overload falls back to the
        frontend's drain-rate estimate over the current backlog."""
        m = self.service.metrics()
        self._drain.observe(m.completed)
        retry = getattr(exc, "retry_after_s", None)
        if retry is None:
            retry = self._drain.retry_after_s(m.queue_depth)
        kind = {"DeadlineExceeded": "deadline",
                "TenantQuotaExceeded": "quota"}.get(
                    type(exc).__name__, "overload")
        return ({"error": str(exc), "status": 429, "kind": kind,
                 "retry_after_s": round(retry, 3)}, retry)

    # ------------------------------------------------------------- HTTP side

    async def _handle_http(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        self._conns.add(writer)
        try:
            while True:
                try:
                    head = await reader.readuntil(b"\r\n\r\n")
                except asyncio.IncompleteReadError:
                    break   # clean close between requests
                method, target, headers = _parse_head(head)
                body = b""
                try:
                    n = int(headers.get("content-length", "0") or "0")
                except ValueError:
                    await _respond_json(writer, 400, {
                        "error": "malformed Content-Length"}, False)
                    break
                if n > protocol.MAX_FRAME_BYTES or n < 0:
                    # same bound as the RPC transport: reject before
                    # buffering, a Content-Length is just a claim
                    await _respond_json(writer, 413, {
                        "error": f"body of {n} bytes exceeds "
                                 f"{protocol.MAX_FRAME_BYTES}"}, False)
                    break
                if n:
                    body = await reader.readexactly(n)
                keep = headers.get("connection", "").lower() != "close"
                keep = await self._route(method, target, body, writer, keep,
                                         headers)
                if not keep:
                    break
        except (ConnectionError, asyncio.LimitOverrunError,
                asyncio.IncompleteReadError):
            pass
        finally:
            self._conns.discard(writer)
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _route(self, method: str, target: str, body: bytes,
                     writer: asyncio.StreamWriter, keep: bool,
                     headers: Optional[Dict[str, str]] = None) -> bool:
        """Dispatch one request; returns whether to keep the connection."""
        h = headers or {}
        trace_id = h.get(TRACE_HEADER) or None
        try:
            # validated once per request: a malformed class/deadline/tenant
            # header is a 400 via the ProtocolError handler below, never
            # a silently-dropped field
            traffic = protocol.decode_traffic(
                klass=h.get(CLASS_HEADER),
                deadline_ms=h.get(DEADLINE_HEADER),
                tenant=h.get(TENANT_HEADER))
            if method == "GET" and target == "/healthz":
                m = self.service.metrics()
                await _respond_json(writer, 200, {
                    "status": "ok", "backend": m.backend,
                    "queue_depth": m.queue_depth}, keep)
            elif method == "GET" and target == "/metrics":
                await _respond(writer, 200, self._render_metrics().encode(),
                               "text/plain; version=0.0.4", keep)
            elif method == "GET" and target == "/debug/traces":
                # the flight recorder's ring as Chrome-trace JSON — load it
                # straight into Perfetto/chrome://tracing
                await _respond(writer, 200,
                               recorder().to_chrome_json().encode(),
                               "application/json", keep)
            elif method == "POST" and target == "/v1/analyze":
                # kept alias: the pre-multi-op route is exactly /v1/ychg
                await self._http_analyze(body, writer, keep, trace_id,
                                         traffic=traffic)
            elif method == "POST" and target == "/v1/analyze_batch":
                await self._http_analyze_batch(body, writer, trace_id,
                                               traffic=traffic)
                keep = False   # chunked stream ends the exchange
            elif method == "POST" and target == "/v1/pipeline":
                await self._http_pipeline(body, writer, keep, trace_id,
                                          traffic=traffic)
            elif method == "POST" and target.startswith("/v1/"):
                opname = target[len("/v1/"):]
                if opname in op_names():
                    await self._http_analyze(body, writer, keep, trace_id,
                                             op=opname, traffic=traffic)
                else:
                    await _respond_json(writer, 404, {
                        "error": f"unknown op {opname!r}",
                        "ops": list(op_names())}, keep)
            else:
                await _respond_json(writer, 404, {
                    "error": f"no route for {method} {target}"}, keep)
        except protocol.ProtocolError as e:
            await _respond_json(writer, 400, {"error": str(e)}, keep)
        except (ValueError, KeyError, json.JSONDecodeError) as e:
            await _respond_json(writer, 400, {"error": f"bad request: {e}"},
                                keep)
        except ConnectionError:
            raise   # the client is gone; nothing left to answer
        except Exception as e:
            # a failing submit (service closing, backend error) must come
            # back as a 500, not a dropped connection the client retries
            await _respond_json(writer, 500, {"error": str(e)}, keep)
        return keep

    async def _http_analyze(self, body: bytes, writer: asyncio.StreamWriter,
                            keep: bool, trace_id: Optional[str] = None,
                            op: Optional[str] = None,
                            traffic: Optional[Dict[str, Any]] = None) -> None:
        tr = maybe_trace(trace_id, process="frontend")
        try:
            t0 = time.monotonic()
            payload = json.loads(body)
            mask = protocol.decode_array(payload["mask"])
            tr.add("frontend.parse", t0, time.monotonic(),
                   bytes=len(body))
            try:
                result = await self._submit(mask, tr, op=op, traffic=traffic)
            except ServiceOverloaded as e:
                out, retry = self._overload_body(e)
                await _respond_json(
                    writer, 429, out, keep,
                    extra=[("Retry-After", str(max(1, math.ceil(retry))))])
                return
            await _respond_json(
                writer, 200,
                {"id": payload.get("id"),
                 "result": protocol.encode_result(
                     result, op or self.service.engine.op)},
                keep)
        finally:
            # the frontend created this trace (possibly adopting the
            # client's id), so the frontend finishes it — on every path
            tr.finish()

    async def _http_pipeline(self, body: bytes, writer: asyncio.StreamWriter,
                             keep: bool,
                             trace_id: Optional[str] = None,
                             traffic: Optional[Dict[str, Any]] = None) -> None:
        """One mask through an ordered op chain; answers with the terminal
        stage's result fields. Spec errors (unknown op, terminal op mid-
        chain, empty stage list) come back 400 via the route's ValueError
        handler."""
        tr = maybe_trace(trace_id, process="frontend")
        try:
            t0 = time.monotonic()
            payload = json.loads(body)
            stages = payload.get("stages")
            if (not isinstance(stages, list) or
                    not all(isinstance(s, str) for s in stages)):
                raise protocol.ProtocolError(
                    "'stages' must be a list of op names")
            mask = protocol.decode_array(payload["mask"])
            tr.add("frontend.parse", t0, time.monotonic(), bytes=len(body))
            try:
                result = await self._submit(mask, tr, stages=stages,
                                            traffic=traffic)
            except ServiceOverloaded as e:
                out, retry = self._overload_body(e)
                await _respond_json(
                    writer, 429, out, keep,
                    extra=[("Retry-After", str(max(1, math.ceil(retry))))])
                return
            await _respond_json(
                writer, 200,
                {"id": payload.get("id"),
                 "result": protocol.encode_result(result, stages[-1])},
                keep)
        finally:
            tr.finish()

    async def _http_analyze_batch(self, body: bytes,
                                  writer: asyncio.StreamWriter,
                                  trace_id: Optional[str] = None,
                                  traffic: Optional[Dict[str, Any]] = None,
                                  ) -> None:
        """Chunked NDJSON, one line per mask in COMPLETION order."""
        tr = maybe_trace(trace_id, process="frontend")
        t0 = time.monotonic()
        payload = json.loads(body)
        items = payload["masks"]
        if not isinstance(items, list):
            raise protocol.ProtocolError("'masks' must be a list")
        tr.add("frontend.parse", t0, time.monotonic(), bytes=len(body),
               masks=len(items))

        async def run_one(i: int, item: Dict[str, Any]) -> Dict[str, Any]:
            rid = item.get("id", i)
            try:
                mask = protocol.decode_array(item)
                result = await self._submit(mask, tr, traffic=traffic)
            except ServiceOverloaded as e:
                out, _ = self._overload_body(e)
                out["id"] = rid
                return out
            except protocol.ProtocolError as e:
                return {"id": rid, "error": str(e), "status": 400}
            except Exception as e:   # a failed request must not kill the stream
                return {"id": rid, "error": str(e), "status": 500}
            return {"id": rid, "result": protocol.encode_result(result)}

        writer.write(_head(200, "application/x-ndjson", keep=False,
                           chunked=True))
        tasks = [asyncio.ensure_future(run_one(i, it))
                 for i, it in enumerate(items)]
        try:
            for fut in asyncio.as_completed(tasks):
                line = protocol.dumps_line(await fut)
                writer.write(_chunk(line))
                await writer.drain()   # slow client -> backpressure here
            writer.write(b"0\r\n\r\n")
            await writer.drain()
        finally:
            for t in tasks:
                t.cancel()
            tr.finish()

    def _render_metrics(self) -> str:
        """ServiceMetrics in Prometheus text exposition format."""
        m = self.service.metrics()
        self._drain.observe(m.completed)
        b = PromBuilder()
        b.counter("ychg_submitted_total", m.submitted,
                  "requests accepted by submit()")
        b.counter("ychg_completed_total", m.completed,
                  "futures fulfilled (cache hits + computed)")
        b.counter("ychg_completed_from_cache_total", m.completed_from_cache,
                  "completions served straight from the result cache")
        b.counter("ychg_cache_hits_total", m.cache_hits,
                  "result-cache lookups that hit")
        b.counter("ychg_cache_misses_total", m.cache_misses,
                  "result-cache lookups that missed")
        b.counter("ychg_coalesced_total", m.coalesced,
                  "duplicate in-flight requests joined to a leader")
        b.counter("ychg_batches_total", m.batches,
                  "bucket stacks dispatched to the engine")
        b.counter("ychg_shed_total", m.shed,
                  "submits rejected with ServiceOverloaded")
        b.counter("ychg_blocked_total", m.blocked,
                  "submits that waited at the admission gate")
        b.counter("ychg_cache_peer_hits_total", m.peer_hits,
                  "local misses served by a sibling's cache")
        b.counter("ychg_cache_peer_misses_total", m.peer_misses,
                  "outbound peer probes no sibling could answer")
        b.header("ychg_shed_bucket_total", "counter",
                 "sheds attributed to the rejected request's bucket")
        for bucket, count in m.shed_by_bucket:
            b.sample("ychg_shed_bucket_total", bucket_labels(bucket), count)
        # traffic-shaping attribution (docs/traffic.md): every shed lands
        # in the class counter; quota sheds additionally name the tenant
        b.counter("ychg_shed_deadline_total", m.shed_deadline,
                  "submits shed because the predicted delay exceeded "
                  "their deadline")
        b.counter("ychg_shed_quota_total", m.shed_quota,
                  "submits shed by a tenant token bucket")
        b.header("ychg_shed_class_total", "counter",
                 "sheds attributed to the rejected request's traffic class")
        for klass, count in m.shed_by_class:
            b.sample("ychg_shed_class_total", (("class", klass),), count)
        b.header("ychg_shed_tenant_total", "counter",
                 "quota sheds attributed to the over-quota tenant")
        for tenant, count in m.shed_by_tenant:
            b.sample("ychg_shed_tenant_total", (("tenant", tenant),), count)
        b.gauge("ychg_queue_depth", m.queue_depth,
                "requests waiting + pending-in-bucket")
        b.gauge("ychg_hit_rate", m.hit_rate, "cache hit rate")
        b.gauge("ychg_p50_latency_ms", m.p50_latency_ms,
                "median request latency from the histogram, compute only")
        b.gauge("ychg_p95_latency_ms", m.p95_latency_ms,
                "p95 request latency from the histogram, compute only")
        b.gauge("ychg_mpx_per_s", m.mpx_per_s,
                "real request pixels served per active second")
        b.gauge("ychg_pad_fraction", m.pad_fraction,
                "dispatched pixels that were padding")
        b.gauge("ychg_compiled_shapes", m.n_compiled_shapes,
                "distinct dispatched batch shapes")
        b.gauge("ychg_drain_rate_rps", round(self._drain.rate(), 3),
                "observed completion rate feeding Retry-After")
        b.gauge("ychg_backend_info", 1,
                "resolved engine backend as a label",
                labels=(("backend", m.backend),))
        # scene/bulk workload progress (repro.scene), attached via
        # service.attach_scene_progress(); all zero when none is running
        b.gauge("ychg_scene_tiles_done", m.scene_tiles_done,
                "scene tiles stitched so far")
        b.gauge("ychg_scene_tiles_total", m.scene_tiles_total,
                "scene tiles expected")
        b.counter("ychg_scene_resumes_total", m.scene_resumes,
                  "checkpoint restores across the scene job")
        b.gauge("ychg_scene_stitch_seconds", round(m.scene_stitch_time_s, 6),
                "host-side seam/stitch time accumulated")
        # fixed-boundary histograms: end-to-end latency per request bucket,
        # per-stage timing, and the engine's synchronous dispatch cost —
        # the boundaries are module constants, so a fleet rollup may sum
        # these series across workers exactly
        b.histogram("ychg_request_latency_seconds", m.latency_hists,
                    "submit -> result ready, compute completions only")
        b.histogram("ychg_stage_seconds", m.stage_hists,
                    "per-stage request timing (docs/observability.md)")
        b.histogram(
            "ychg_engine_dispatch_seconds",
            [((("op", op), ("backend", name)), snap)
             for (op, name), snap in
             sorted(registry.dispatch_seconds().items())],
            "synchronous engine dispatch cost per (op, backend)")
        return b.render()

    # -------------------------------------------------------------- RPC side

    def _cache_probe(self, frame: Dict[str, Any]) -> Dict[str, Any]:
        """Sibling cache lookup by serialized key (hex). Purely local:
        answers out of this worker's cache index or says miss — it never
        computes and never probes onward, so fleet probes cannot cascade.
        The hit carries the STORED entry layout ((1, W)/(1,) arrays, not
        ``to_host()``'s squeezed view) so the prober can reconstruct a
        device-resident result indistinguishable from its own cache's."""
        rid = frame.get("id")
        try:
            skey = bytes.fromhex(frame["key"])
            fields = protocol.result_fields(
                str(frame.get("opname", "ychg")))
        except (KeyError, TypeError, ValueError,
                protocol.ProtocolError) as e:
            return {"id": rid, "error": f"bad cache_probe frame: {e}",
                    "status": 400}
        entry = self.service.cache.probe_serialized(skey)
        if entry is None:
            return {"id": rid, "hit": False}
        return {"id": rid, "hit": True, "result": {
            f: protocol.encode_array(np.asarray(getattr(entry, f)))
            for f in fields}}

    def _set_peers(self, frame: Dict[str, Any]) -> Dict[str, Any]:
        """Point this worker's cache at its siblings ([host, rpc_port]
        pairs). ``ok: false`` when the cache cannot peer (plain
        ResultCache) — the router treats that as a worker without the
        feature, not an error."""
        rid = frame.get("id")
        set_peers = getattr(self.service.cache, "set_peers", None)
        if set_peers is None:
            return {"id": rid, "ok": False}
        try:
            peers = [(str(h), int(p)) for h, p in frame.get("peers", [])]
        except (TypeError, ValueError) as e:
            return {"id": rid, "error": f"bad set_peers payload: {e}",
                    "status": 400, "ok": False}
        set_peers(peers)
        return {"id": rid, "ok": True}

    async def _handle_rpc(self, reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
        """Frame loop: many analyzes in flight, responses in completion
        order, demuxed by id on the client side."""
        self._conns.add(writer)
        wlock = asyncio.Lock()
        tasks: set = set()

        async def send(obj: Dict[str, Any]) -> None:
            async with wlock:
                writer.write(protocol.pack_frame(obj))
                await writer.drain()

        async def run_analyze(frame: Dict[str, Any]) -> None:
            rid = frame.get("id")
            # the RPC frame's "trace" field is the fleet's propagation
            # seam: a router puts its trace id here and this worker's
            # spans join the router's trace. "opname" selects the
            # operator (the frame key "op" is already the RPC verb);
            # "stages" instead runs an ordered pipeline.
            tr = maybe_trace(frame.get("trace") or None, process="worker")
            try:
                t0 = time.monotonic()
                opname = frame.get("opname")
                stages = frame.get("stages")
                if opname is not None and opname not in op_names():
                    await send({"id": rid,
                                "error": f"unknown op {opname!r}",
                                "ops": list(op_names()), "status": 404})
                    return
                # the frame fields mirror the HTTP headers one to one
                # (protocol.decode_traffic is the shared validator)
                traffic = protocol.decode_traffic(
                    klass=frame.get("klass"),
                    deadline_ms=frame.get("deadline_ms"),
                    tenant=frame.get("tenant"))
                mask = protocol.decode_array(frame["mask"])
                tr.add("frontend.parse", t0, time.monotonic())
                if stages is not None:
                    result = await self._submit(mask, tr, stages=stages,
                                                traffic=traffic)
                    wire_op = str(stages[-1])
                else:
                    result = await self._submit(mask, tr, op=opname,
                                                traffic=traffic)
                    wire_op = opname or self.service.engine.op
            except ServiceOverloaded as e:
                out, _ = self._overload_body(e)
                out["id"] = rid
                await send(out)
                return
            except (protocol.ProtocolError, KeyError, ValueError) as e:
                await send({"id": rid, "error": str(e), "status": 400})
                return
            except Exception as e:
                await send({"id": rid, "error": str(e), "status": 500})
                return
            finally:
                tr.finish()
            await send({"id": rid,
                        "result": protocol.encode_result(result, wire_op)})

        try:
            while True:
                try:
                    frame = await protocol.read_frame(reader)
                except protocol.ProtocolError as e:
                    await send({"error": str(e), "status": 400})
                    break
                if frame is None:
                    break
                op = frame.get("op")
                if op in ("analyze", "pipeline"):
                    # "pipeline" is "analyze" with a required stages list;
                    # both demux by id and share the in-flight discipline
                    if op == "pipeline" and not frame.get("stages"):
                        await send({"id": frame.get("id"),
                                    "error": "pipeline needs a non-empty "
                                             "'stages' list", "status": 400})
                        continue
                    t = asyncio.ensure_future(run_analyze(frame))
                    tasks.add(t)
                    t.add_done_callback(tasks.discard)
                elif op == "health":
                    m = self.service.metrics()
                    await send({"id": frame.get("id"), "status": "ok",
                                "backend": m.backend,
                                "queue_depth": m.queue_depth})
                elif op == "cache_probe":
                    await send(self._cache_probe(frame))
                elif op == "set_peers":
                    await send(self._set_peers(frame))
                else:
                    await send({"id": frame.get("id"),
                                "error": f"unknown op {op!r}", "status": 400})
            if tasks:
                await asyncio.gather(*tasks, return_exceptions=True)
        except (ConnectionError, OSError):
            pass
        finally:
            self._conns.discard(writer)
            for t in tasks:
                t.cancel()
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass


# ------------------------------------------------------------ HTTP plumbing


def _parse_head(head: bytes) -> Tuple[str, str, Dict[str, str]]:
    lines = head.decode("latin-1").split("\r\n")
    try:
        method, target, _version = lines[0].split(" ", 2)
    except ValueError:
        raise protocol.ProtocolError(f"bad request line {lines[0]!r}")
    headers: Dict[str, str] = {}
    for line in lines[1:]:
        if not line:
            continue
        name, _, value = line.partition(":")
        headers[name.strip().lower()] = value.strip()
    return method, target, headers


_STATUS = {200: "OK", 400: "Bad Request", 404: "Not Found",
           413: "Payload Too Large", 429: "Too Many Requests",
           500: "Internal Server Error"}


def _head(status: int, content_type: str, *, keep: bool,
          chunked: bool = False, length: Optional[int] = None,
          extra: Optional[list] = None) -> bytes:
    lines = [f"HTTP/1.1 {status} {_STATUS.get(status, 'Status')}",
             f"Content-Type: {content_type}",
             f"Connection: {'keep-alive' if keep else 'close'}"]
    if chunked:
        lines.append("Transfer-Encoding: chunked")
    else:
        lines.append(f"Content-Length: {length or 0}")
    for name, value in (extra or []):
        lines.append(f"{name}: {value}")
    return ("\r\n".join(lines) + "\r\n\r\n").encode()


def _chunk(data: bytes) -> bytes:
    return f"{len(data):x}\r\n".encode() + data + b"\r\n"


async def _respond(writer: asyncio.StreamWriter, status: int, body: bytes,
                   content_type: str, keep: bool,
                   extra: Optional[list] = None) -> None:
    writer.write(_head(status, content_type, keep=keep, length=len(body),
                       extra=extra) + body)
    await writer.drain()


async def _respond_json(writer: asyncio.StreamWriter, status: int,
                        obj: Any, keep: bool,
                        extra: Optional[list] = None) -> None:
    await _respond(writer, status, json.dumps(obj).encode(),
                   "application/json", keep, extra)


# -------------------------------------------------------- sync entry point


class ServerThread:
    """A `FrontendServer` on its own event-loop thread, for sync callers.

    ::

        with ServerThread(service) as srv:
            client = YCHGClient("127.0.0.1", srv.port)
            ...

    Startup errors (port in use, bad host) re-raise in the constructor;
    ``close()`` stops the loop and joins the thread.
    """

    def __init__(self, service: YCHGService, *, host: str = "127.0.0.1",
                 port: int = 0, rpc_port: Optional[int] = None,
                 start_timeout: float = 30.0, **kw: Any):
        self._server = FrontendServer(service, host=host, port=port,
                                      rpc_port=rpc_port, **kw)
        self._ready = threading.Event()
        self._stop: Optional[asyncio.Event] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._exc: Optional[BaseException] = None
        self.port: Optional[int] = None
        self.rpc_port: Optional[int] = None
        self._thread = threading.Thread(
            target=lambda: asyncio.run(self._main()),
            name="ychg-frontend-server", daemon=True)
        self._thread.start()
        if not self._ready.wait(start_timeout):
            raise RuntimeError("frontend server failed to start in time")
        if self._exc is not None:
            raise self._exc

    async def _main(self) -> None:
        try:
            await self._server.start()
            self.port = self._server.port
            self.rpc_port = self._server.rpc_port
            self._loop = asyncio.get_running_loop()
            self._stop = asyncio.Event()
        except BaseException as e:
            self._exc = e
            self._ready.set()
            return
        self._ready.set()
        await self._stop.wait()
        await self._server.aclose()

    def close(self, timeout: float = 30.0) -> None:
        """Stop the loop and join; idempotent — fleet tests kill a worker
        mid-test and the teardown sweep closes everything again."""
        if self._loop is not None and self._stop is not None:
            try:
                self._loop.call_soon_threadsafe(self._stop.set)
            except RuntimeError:   # loop already closed
                pass
        self._thread.join(timeout)

    def __enter__(self) -> "ServerThread":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
