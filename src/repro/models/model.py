"""Config-driven LM assembly.

Layer stack = ``lax.scan`` over layer *groups* (one group = one period of
``cfg.layer_pattern``), so HLO is O(period) in depth: every leaf of
``params["layers"]["p<k>"]`` carries a leading ``num_groups`` axis (logical
axis "layers", never sharded). Remat wraps the group body per ``cfg.remat``.

Three entry points per model:
  forward(...)                train / prefill (optionally returns the cache)
  decode_step(...)            one new token against the cache (serve_step)
  loss_fn(...)                next-token CE + MoE aux loss

Param/cache trees exist in concrete form (rng init — used on CPU for the
small-scale examples/tests) and abstract form (ShapeDtypeStruct — used by the
multi-pod dry-run; a 400B-param tree costs nothing to "init").
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    Builder,
    P,
    Sharder,
    apply_norm,
    init_norm,
    sinusoidal_pos,
    split_tree,
)
from repro.models.mlp import init_mlp, mlp_apply

Array = jax.Array


class _Stacked:
    """Builder proxy that prepends the (num_groups,) 'layers' axis."""

    def __init__(self, b: Builder, g: int):
        self.b = b
        self.g = g

    def make(self, shape, axes, **kw) -> P:
        return self.b.make((self.g, *shape), ("layers", *axes), **kw)


def _init_mixer(b, cfg: ModelConfig, spec: LayerSpec) -> dict:
    if spec.mixer == "attn":
        return attn.init_attn(b, cfg)
    if spec.mixer == "mla":
        return attn.init_mla(b, cfg)
    if spec.mixer == "mamba":
        return ssm_mod.init_mamba(b, cfg)
    if spec.mixer == "rwkv":
        return rwkv_mod.init_rwkv_time(b, cfg)
    raise ValueError(spec.mixer)


def _init_channel(b, cfg: ModelConfig, spec: LayerSpec) -> dict:
    if spec.channel == "mlp":
        return init_mlp(b, cfg)
    if spec.channel == "moe":
        return moe_mod.init_moe(b, cfg)
    if spec.channel == "rwkv_ffn":
        return rwkv_mod.init_rwkv_channel(b, cfg)
    raise ValueError(spec.channel)


def _build(cfg: ModelConfig, key, abstract: bool):
    b = Builder(key, cfg.param_dtype, abstract=abstract)
    sb = _Stacked(b, cfg.num_groups)
    layers: Dict[str, Any] = {}
    for k, spec in enumerate(cfg.layer_pattern):
        entry = {
            "norm1": init_norm(sb, cfg.d_model, cfg.norm_type),
            "mixer": _init_mixer(sb, cfg, spec),
            "channel": _init_channel(sb, cfg, spec),
        }
        if not cfg.parallel_block:
            entry["norm2"] = init_norm(sb, cfg.d_model, cfg.norm_type)
        layers[f"p{k}"] = entry
    tree = {
        "embed": b.make((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                        init="normal", scale=0.02),
        "final_norm": init_norm(b, cfg.d_model, cfg.norm_type),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = b.make((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return tree


def init_params(cfg: ModelConfig, key) -> Any:
    params, _ = split_tree(_build(cfg, key, abstract=False))
    if cfg.weight_quant == "int8":
        from repro.models import quant

        q, s = quant.quantize_layers(params["layers"])
        params["layers"], params["layers_scale"] = q, s
    return params


def abstract_params(cfg: ModelConfig) -> Any:
    params, _ = split_tree(_build(cfg, None, abstract=True))
    if cfg.weight_quant == "int8":
        from repro.models import quant

        q, s = quant.abstract_quantized_layers(params["layers"])
        params["layers"], params["layers_scale"] = q, s
    return params


def param_logical_axes(cfg: ModelConfig) -> Any:
    _, axes = split_tree(_build(cfg, None, abstract=True))
    if cfg.weight_quant == "int8":
        from repro.models import quant

        axes["layers_scale"] = quant.scale_logical_axes(axes["layers"])
    return axes


def count_params(cfg: ModelConfig) -> int:
    return sum(
        int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(abstract_params(cfg))
    )


def active_params(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: routed experts_per_token of num_experts)."""
    total = 0
    for leaf_path, leaf in jax.tree_util.tree_leaves_with_path(abstract_params(cfg)):
        n = int(np.prod(leaf.shape))
        path = jax.tree_util.keystr(leaf_path)
        if (
            "'channel'" in path
            and cfg.num_experts
            and any(w in path for w in ("'w_gate'", "'w_up'", "'w_down'"))
            and "'shared'" not in path
        ):
            n = n * cfg.experts_per_token // cfg.num_experts
        total += n
    return total


# ---------------------------------------------------------------------------
# block application


def _apply_mixer(spec, p, x, cfg, shd, positions):
    if spec.mixer == "attn":
        return attn.attn_forward(p, x, cfg, shd, positions)
    if spec.mixer == "mla":
        return attn.mla_forward(p, x, cfg, shd, positions)
    if spec.mixer == "mamba":
        return ssm_mod.mamba_forward(p, x, cfg, shd)
    if spec.mixer == "rwkv":
        return rwkv_mod.rwkv_time_forward(p, x, cfg, shd)
    raise ValueError(spec.mixer)


def _apply_channel(spec, p, x, cfg, shd):
    """Returns (y, aux_loss, state)."""
    if spec.channel == "mlp":
        return mlp_apply(p, x, cfg, shd), 0.0, None
    if spec.channel == "moe":
        y, aux = moe_mod.moe_apply(p, x, cfg, shd)
        return y, aux, None
    if spec.channel == "rwkv_ffn":
        y, st = rwkv_mod.rwkv_channel_forward(p, x, cfg, shd)
        return y, 0.0, st
    raise ValueError(spec.channel)


def _group_body(cfg: ModelConfig, shd: Sharder, positions, collect_cache: bool,
                carry, group_params):
    x, aux = carry
    caches = {}
    for k, spec in enumerate(cfg.layer_pattern):
        gp = group_params[f"p{k}"]
        h = apply_norm(gp["norm1"], x, cfg.norm_type, cfg.norm_eps)
        mix_out, mix_cache = _apply_mixer(spec, gp["mixer"], h, cfg, shd, positions)
        if cfg.parallel_block:
            ch_out, a, ch_state = _apply_channel(spec, gp["channel"], h, cfg, shd)
            x = x + mix_out + ch_out
        else:
            x = x + mix_out
            h2 = apply_norm(gp["norm2"], x, cfg.norm_type, cfg.norm_eps)
            ch_out, a, ch_state = _apply_channel(spec, gp["channel"], h2, cfg, shd)
            x = x + ch_out
        x = shd(x, ("act_batch", "act_seq", "act_embed"))
        aux = aux + a
        if collect_cache:
            caches[f"p{k}"] = {"mixer": mix_cache, "channel": ch_state}
    return (x, aux), caches if collect_cache else None


def forward(
    params: Any,
    cfg: ModelConfig,
    tokens: Array,
    shd: Optional[Sharder] = None,
    frontend_embeds: Optional[Array] = None,
    return_cache: bool = False,
) -> Tuple[Array, Array, Any]:
    """tokens: (B,S) int32 -> (logits (B,S,V), aux_loss, cache|None)."""
    shd = shd or Sharder()
    b_, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype)
    if frontend_embeds is not None and cfg.frontend != "none":
        f = frontend_embeds.shape[1]
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x[:, f:, :]], axis=1)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b_, s))
    if cfg.pos_embed == "sinusoidal":
        x = x + sinusoidal_pos(positions, cfg.d_model).astype(x.dtype)
    x = shd(x, ("act_batch", "act_seq", "act_embed"))

    body = functools.partial(_group_body, cfg, shd, positions, return_cache)
    if cfg.remat == "full":
        body = jax.checkpoint(body)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    carry = (x, jnp.float32(0.0))
    if cfg.scan_layers:
        (x, aux), caches = jax.lax.scan(body, carry, params["layers"])
    else:  # unrolled (cost probes / tiny models): same math, straight-line HLO
        cache_list = []
        for i in range(cfg.num_groups):
            gp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            carry, c = body(carry, gp)
            cache_list.append(c)
        (x, aux) = carry
        caches = (
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *cache_list)
            if return_cache else None
        )

    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    logits = shd(logits, ("act_batch", "act_seq", "act_vocab"))
    return logits, aux, caches


# ---------------------------------------------------------------------------
# decode


def _decode_group_body(cfg, shd, cur_index, carry, xs):
    x = carry
    group_params, cache = xs
    new_caches = {}
    for k, spec in enumerate(cfg.layer_pattern):
        gp = group_params[f"p{k}"]
        c = cache[f"p{k}"]
        h = apply_norm(gp["norm1"], x, cfg.norm_type, cfg.norm_eps)
        if spec.mixer == "attn":
            mix_out, mc = attn.attn_decode(gp["mixer"], h, cfg, shd, c["mixer"], cur_index)
        elif spec.mixer == "mla":
            mix_out, mc = attn.mla_decode(gp["mixer"], h, cfg, shd, c["mixer"], cur_index)
        elif spec.mixer == "mamba":
            mix_out, mc = ssm_mod.mamba_decode(gp["mixer"], h, cfg, shd, c["mixer"])
        elif spec.mixer == "rwkv":
            mix_out, mc = rwkv_mod.rwkv_time_decode(gp["mixer"], h, cfg, shd, c["mixer"])
        else:
            raise ValueError(spec.mixer)
        if cfg.parallel_block:
            ch_out, _, cc = _decode_channel(spec, gp["channel"], h, cfg, shd, c["channel"])
            x = x + mix_out + ch_out
        else:
            x = x + mix_out
            h2 = apply_norm(gp["norm2"], x, cfg.norm_type, cfg.norm_eps)
            ch_out, _, cc = _decode_channel(spec, gp["channel"], h2, cfg, shd, c["channel"])
            x = x + ch_out
        new_caches[f"p{k}"] = {"mixer": mc, "channel": cc}
    return x, new_caches


def _decode_channel(spec, p, x, cfg, shd, state):
    if spec.channel == "mlp":
        return mlp_apply(p, x, cfg, shd), 0.0, None
    if spec.channel == "moe":
        y, aux = moe_mod.moe_apply(p, x, cfg, shd)
        return y, aux, None
    if spec.channel == "rwkv_ffn":
        y, st = rwkv_mod.rwkv_channel_decode(p, x, cfg, shd, state)
        return y, 0.0, st
    raise ValueError(spec.channel)


def decode_step(
    params: Any,
    cfg: ModelConfig,
    cache: Any,
    tokens: Array,
    cur_index: Array,
    shd: Optional[Sharder] = None,
) -> Tuple[Array, Any]:
    """tokens: (B,1) -> (logits (B,V), new cache). cur_index: scalar int32."""
    shd = shd or Sharder()
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype)
    if cfg.pos_embed == "sinusoidal":
        pos = jnp.full((x.shape[0], 1), cur_index, jnp.int32)
        x = x + sinusoidal_pos(pos, cfg.d_model).astype(x.dtype)
    x = shd(x, ("act_batch", None, "act_embed"))
    inner = functools.partial(_decode_group_body, cfg, shd, cur_index)
    if cfg.weight_quant == "int8":
        from repro.models import quant

        def body(carry, xs):
            gp_q, gp_s, c = xs
            gp = quant.dequantize_group(gp_q, gp_s, cfg.activation_dtype)
            return inner(carry, (gp, c))

        xs_all = (params["layers"], params["layers_scale"], cache)
    else:
        def body(carry, xs):
            return inner(carry, xs)

        xs_all = (params["layers"], cache)
    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(body, x, xs_all)
    else:
        entries = []
        for i in range(cfg.num_groups):
            xs = jax.tree_util.tree_map(lambda a: a[i], xs_all)
            x, c = body(x, xs)
            entries.append(c)
        new_cache = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *entries)
    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits[:, 0, :], new_cache


# ---------------------------------------------------------------------------
# cache


def _cache_entry(cfg: ModelConfig, spec: LayerSpec, batch: int, seq: int,
                 abstract: bool):
    dt = jnp.dtype(cfg.activation_dtype)

    def mk(shape, dtype, axes):
        if abstract:
            return P(jax.ShapeDtypeStruct(shape, dtype), axes)
        return P(jnp.zeros(shape, dtype), axes)

    g = cfg.num_groups
    if spec.mixer == "attn":
        kv = (g, batch, seq, cfg.num_kv_heads, cfg.head_dim)
        ax = ("layers", "act_batch", "act_kv_seq", "act_kv_heads", None)
        mixer = {"k": mk(kv, dt, ax), "v": mk(kv, dt, ax)}
    elif spec.mixer == "mla":
        mixer = {
            "ckv": mk((g, batch, seq, cfg.kv_lora_rank), dt,
                      ("layers", "act_batch", "act_kv_seq", None)),
            "k_rope": mk((g, batch, seq, cfg.qk_rope_dim), dt,
                         ("layers", "act_batch", "act_kv_seq", None)),
        }
    elif spec.mixer == "mamba":
        di = ssm_mod.d_inner_of(cfg)
        mixer = {
            "h": mk((g, batch, di, cfg.ssm_state_dim), jnp.float32,
                    ("layers", "act_batch", "act_mlp", None)),
            "conv": mk((g, batch, cfg.ssm_conv_dim - 1, di), dt,
                       ("layers", "act_batch", None, "act_mlp")),
        }
    elif spec.mixer == "rwkv":
        h = rwkv_mod.num_heads_of(cfg)
        k = cfg.rwkv_head_dim
        mixer = {
            "wkv": mk((g, batch, h, k, k), jnp.float32,
                      ("layers", "act_batch", "act_heads", None, None)),
            "shift": mk((g, batch, cfg.d_model), dt,
                        ("layers", "act_batch", "act_embed")),
        }
    else:
        raise ValueError(spec.mixer)
    channel = None
    if spec.channel == "rwkv_ffn":
        channel = {"shift": mk((g, batch, cfg.d_model), dt,
                               ("layers", "act_batch", "act_embed"))}
    return {"mixer": mixer, "channel": channel}


def _cache_tree(cfg: ModelConfig, batch: int, seq: int, abstract: bool):
    return {
        f"p{k}": _cache_entry(cfg, spec, batch, seq, abstract)
        for k, spec in enumerate(cfg.layer_pattern)
    }


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> Any:
    cache, _ = split_tree(_cache_tree(cfg, batch, seq, abstract=False))
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, seq: int) -> Any:
    cache, _ = split_tree(_cache_tree(cfg, batch, seq, abstract=True))
    return cache


def cache_logical_axes(cfg: ModelConfig, batch: int = 1, seq: int = 8) -> Any:
    _, axes = split_tree(_cache_tree(cfg, batch, seq, abstract=True))
    return axes


# ---------------------------------------------------------------------------
# loss


def loss_fn(
    params: Any,
    cfg: ModelConfig,
    tokens: Array,
    labels: Array,
    shd: Optional[Sharder] = None,
    frontend_embeds: Optional[Array] = None,
    loss_mask: Optional[Array] = None,
    aux_coeff: float = 0.01,
) -> Tuple[Array, Dict[str, Array]]:
    """Next-token CE (f32) + MoE aux. labels: (B,S) int32, -1 = ignore."""
    logits, aux, _ = forward(params, cfg, tokens, shd, frontend_embeds)
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    valid = labels >= 0
    if loss_mask is not None:
        valid = valid & (loss_mask != 0)
    safe = jnp.where(valid, labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(valid.sum(), 1)
    ce = jnp.where(valid, nll, 0.0).sum() / denom
    loss = ce + aux_coeff * aux
    return loss, {"ce": ce, "aux": aux, "ntokens": denom}
