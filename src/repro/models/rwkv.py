"""RWKV-6 "Finch" blocks: time-mix (data-dependent decay linear attention)
and channel-mix. Attention-free: the recurrent state (B, H, K, V) replaces a
KV cache, so long_500k decode is O(1) in sequence length.

Recurrence per head (K = V = head dim):
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T          (w_t in (0,1), data-dependent)
    out_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

Token-shift uses the data-dependent linear interpolation (ddlerp) of RWKV-6
with low-rank adapters. The sequence dimension is processed by a chunked
lax.scan (checkpointed body; within-chunk steps unrolled by a tiny inner
scan) — same chunking scheme as ssm.py, adapted for the matrix-valued state.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Builder, Sharder, groupnorm_heads

Array = jax.Array

_MIX_NAMES = ("w", "k", "v", "r", "g")


def num_heads_of(cfg) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def init_rwkv_time(b: Builder, cfg) -> dict:
    d = cfg.d_model
    lr = cfg.rwkv_mix_lora
    dr = cfg.rwkv_decay_lora
    h = num_heads_of(cfg)
    k = cfg.rwkv_head_dim
    p = {
        "mu_x": b.make((d,), (None,), init="zeros"),
        "mix_w1": b.make((d, len(_MIX_NAMES) * lr), ("embed", None)),
        "mix_w2": b.make((len(_MIX_NAMES), lr, d), (None, None, "embed"),
                         init="normal", scale=0.01),
        "mu": b.make((len(_MIX_NAMES), d), (None, None), init="zeros"),
        "w_r": b.make((d, d), ("embed", "heads_flat")),
        "w_k": b.make((d, d), ("embed", "heads_flat")),
        "w_v": b.make((d, d), ("embed", "heads_flat")),
        "w_g": b.make((d, d), ("embed", "heads_flat")),
        "w_o": b.make((d, d), ("heads_flat", "embed")),
        "decay_base": b.make((d,), (None,), init="zeros"),
        "decay_w1": b.make((d, dr), ("embed", None)),
        "decay_w2": b.make((dr, d), (None, "embed"), init="normal", scale=0.01),
        "bonus_u": b.make((h, k), ("heads", None), init="zeros"),
        "ln_scale": b.make((h, k), ("heads", None), init="ones"),
        "ln_bias": b.make((h, k), ("heads", None), init="zeros"),
    }
    return p


def init_rwkv_channel(b: Builder, cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": b.make((d,), (None,), init="zeros"),
        "mu_r": b.make((d,), (None,), init="zeros"),
        "w_k": b.make((d, f), ("embed", "mlp")),
        "w_v": b.make((f, d), ("mlp", "embed")),
        "w_r": b.make((d, d), ("embed", "embed_out")),
    }


def _ddlerp(p: dict, x: Array, sx: Array) -> list[Array]:
    """Data-dependent token-shift interpolation -> one mixed x per quantity."""
    xx = x + sx * p["mu_x"]
    z = jnp.tanh(jnp.einsum("bsd,dr->bsr", xx, p["mix_w1"]))
    z = z.reshape(*z.shape[:-1], len(_MIX_NAMES), -1)  # (B,S,5,lr)
    adj = jnp.einsum("bsnr,nrd->bnsd", z, p["mix_w2"])  # (B,5,S,d)
    outs = []
    for i in range(len(_MIX_NAMES)):
        mix = p["mu"][i] + adj[:, i]
        outs.append(x + sx * mix)
    return outs


def _time_mix_scan(r: Array, k: Array, v: Array, w: Array, u: Array,
                   s0: Array, chunk: int) -> Tuple[Array, Array]:
    """r/k/v/w: (B,S,H,K); u: (H,K); s0: (B,H,K,V). Returns (out (B,S,H,K), s_last)."""
    b_, s, h, kd = r.shape
    c = min(chunk, s)
    pad = -s % c
    if pad:
        # identity updates: w=1 (no decay), k=0 -> state and out[:s] unaffected
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    sp = s + pad
    t = sp // c

    def chunk_fn(state, xs):
        rc, kc, vc, wc = xs  # (B,c,H,K)

        def step(st, ts):
            rt, kt, vt, wt = ts  # (B,H,K)
            kv = kt[..., :, None] * vt[..., None, :]  # (B,H,K,V)
            out = jnp.einsum("bhk,bhkv->bhv", rt, st + u[..., None] * kv)
            st = wt[..., None] * st + kv
            return st, out

        st, outs = jax.lax.scan(
            step, state,
            (rc.swapaxes(0, 1), kc.swapaxes(0, 1), vc.swapaxes(0, 1), wc.swapaxes(0, 1)),
        )
        return st, outs.swapaxes(0, 1)  # (B,c,H,K)

    chunk_fn = jax.checkpoint(chunk_fn)
    xs = tuple(
        a.reshape(b_, t, c, h, kd).swapaxes(0, 1) for a in (r, k, v, w)
    )
    s_last, out_t = jax.lax.scan(chunk_fn, s0, xs)
    return out_t.swapaxes(0, 1).reshape(b_, sp, h, kd)[:, :s], s_last


def rwkv_time_forward(p: dict, x: Array, cfg, shd: Sharder,
                      state: dict | None = None) -> Tuple[Array, dict]:
    """Train/prefill time-mix. x: (B,S,D)."""
    b_, s, d = x.shape
    h, kd = num_heads_of(cfg), cfg.rwkv_head_dim
    prev = state["shift"][:, None, :] if state else jnp.zeros((b_, 1, d), x.dtype)
    x_prev = jnp.concatenate([prev, x[:, :-1, :]], axis=1)
    sx = x_prev - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, sx)
    r = jnp.einsum("bsd,de->bse", xr, p["w_r"]).reshape(b_, s, h, kd)
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"]).reshape(b_, s, h, kd)
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"]).reshape(b_, s, h, kd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["w_g"]))
    dec = p["decay_base"] + jnp.einsum(
        "bsd,dr,re->bse", xw, p["decay_w1"], p["decay_w2"]
    )
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32))).reshape(b_, s, h, kd)
    r = shd(r, ("act_batch", "act_seq", "act_heads", None))
    k = shd(k, ("act_batch", "act_seq", "act_heads", None))
    s0 = state["wkv"] if state else jnp.zeros((b_, h, kd, kd), jnp.float32)
    out, s_last = _time_mix_scan(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        w, p["bonus_u"].astype(jnp.float32), s0, cfg.ssm_chunk,
    )
    out = groupnorm_heads(out, p["ln_scale"], p["ln_bias"], cfg.norm_eps)
    out = out.reshape(b_, s, d).astype(x.dtype) * g
    y = jnp.einsum("bse,ed->bsd", out, p["w_o"])
    new_state = {"wkv": s_last, "shift": x[:, -1, :]}
    return shd(y, ("act_batch", "act_seq", "act_embed")), new_state


def rwkv_time_decode(p: dict, x: Array, cfg, shd: Sharder, state: dict
                     ) -> Tuple[Array, dict]:
    """One-token step; state: wkv (B,H,K,V) f32, shift (B,D)."""
    b_, _, d = x.shape
    h, kd = num_heads_of(cfg), cfg.rwkv_head_dim
    sx = state["shift"][:, None, :] - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, sx)
    r = jnp.einsum("bsd,de->bse", xr, p["w_r"]).reshape(b_, h, kd)
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"]).reshape(b_, h, kd)
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"]).reshape(b_, h, kd)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["w_g"]))[:, 0]
    dec = p["decay_base"] + jnp.einsum("bsd,dr,re->bse", xw, p["decay_w1"], p["decay_w2"])
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32))).reshape(b_, h, kd)
    st = state["wkv"]
    kv = k.astype(jnp.float32)[..., :, None] * v.astype(jnp.float32)[..., None, :]
    out = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32),
                     st + p["bonus_u"].astype(jnp.float32)[..., None] * kv)
    st = w[..., None] * st + kv
    out = groupnorm_heads(out, p["ln_scale"], p["ln_bias"], cfg.norm_eps)
    out = (out.reshape(b_, d).astype(x.dtype) * g)[:, None, :]
    y = jnp.einsum("bse,ed->bsd", out, p["w_o"])
    return y, {"wkv": st, "shift": x[:, -1, :]}


def rwkv_channel_forward(p: dict, x: Array, cfg, shd: Sharder,
                         state: dict | None = None) -> Tuple[Array, dict]:
    b_, s, d = x.shape
    prev = state["shift"][:, None, :] if state else jnp.zeros((b_, 1, d), x.dtype)
    x_prev = jnp.concatenate([prev, x[:, :-1, :]], axis=1)
    sx = x_prev - x
    xk = x + sx * p["mu_k"]
    xr = x + sx * p["mu_r"]
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["w_k"])))
    k = shd(k, ("act_batch", "act_seq", "act_mlp"))
    kv = jnp.einsum("bsf,fd->bsd", k, p["w_v"])
    y = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_r"])) * kv
    return y, {"shift": x[:, -1, :]}


def rwkv_channel_decode(p: dict, x: Array, cfg, shd: Sharder, state: dict
                        ) -> Tuple[Array, dict]:
    sx = state["shift"][:, None, :] - x
    xk = x + sx * p["mu_k"]
    xr = x + sx * p["mu_r"]
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["w_k"])))
    kv = jnp.einsum("bsf,fd->bsd", k, p["w_v"])
    y = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_r"])) * kv
    return y, {"shift": x[:, -1, :]}
