"""Channel mixers: dense MLP (swiglu / gelu) and the RWKV channel-mix."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Builder, Sharder, act_fn

Array = jax.Array


def init_mlp(b: Builder, cfg, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_act == "swiglu":
        return {
            "w_gate": b.make((d, f), ("embed", "mlp")),
            "w_up": b.make((d, f), ("embed", "mlp")),
            "w_down": b.make((f, d), ("mlp", "embed")),
        }
    return {
        "w_up": b.make((d, f), ("embed", "mlp")),
        "b_up": b.make((f,), ("mlp",), init="zeros"),
        "w_down": b.make((f, d), ("mlp", "embed")),
        "b_down": b.make((d,), ("embed",), init="zeros"),
    }


def mlp_apply(p: dict, x: Array, cfg, shd: Sharder) -> Array:
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
        h = h * jnp.einsum("bsd,df->bsf", x, p["w_up"])
        h = shd(h, ("act_batch", "act_seq", "act_mlp"))
        return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    h = act_fn("gelu", jnp.einsum("bsd,df->bsf", x, p["w_up"]) + p["b_up"])
    h = shd(h, ("act_batch", "act_seq", "act_mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"]) + p["b_down"]
