"""Mixture-of-Experts channel mixer.

Baseline impl ("dispatch"): sort-based capacity dispatch in pure pjit-friendly
jnp — top-k routing, per-expert rank via stable sort, scatter into (E, C, d)
expert buffers, batched expert matmuls with the expert axis sharded over
"model" (expert parallelism), gather/combine back. Tokens past capacity are
dropped (GShard semantics); aux load-balancing loss returned for training.

The all-to-all pattern between the token-sharded and expert-sharded layouts
is left to XLA SPMD here — that choice is deliberate: it is the baseline the
§Perf hillclimb measures against (a shard_map variant with explicit
all_to_all is the optimized path).

Routing flavours:
  softmax top-k, renormalised (phi3.5-moe, jamba)      — experts_per_token=2
  sigmoid top-1 + shared expert (llama4-maverick)      — experts_per_token=1
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Builder, Sharder
from repro.models.mlp import init_mlp, mlp_apply

Array = jax.Array


def init_moe(b: Builder, cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    p = {
        # router replicated: tiny, and the all-to-all path routes locally
        "router": b.make((d, e), (None, None), init="normal", scale=0.02),
        "w_gate": b.make((e, d, f), ("experts", "embed", "mlp")),
        "w_up": b.make((e, d, f), ("experts", "embed", "mlp")),
        "w_down": b.make((e, f, d), ("experts", "mlp", "embed")),
    }
    if getattr(cfg, "moe_shared_experts", 0) or cfg.name.startswith("llama4"):
        p["shared"] = init_mlp(b, cfg)
    return p


def _route(p: dict, xt: Array, cfg) -> Tuple[Array, Array, Array]:
    """xt: (T, d) -> (gates (T,k), idx (T,k), aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"].astype(jnp.float32))
    k = cfg.experts_per_token
    if k == 1 and "shared" in p:  # llama4: sigmoid gate on the top-1 expert
        top_val, top_idx = jax.lax.top_k(logits, 1)
        gates = jax.nn.sigmoid(top_val)
        probs = jax.nn.softmax(logits, axis=-1)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gates, top_idx = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load balancing aux loss
    e = cfg.num_experts
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.mean(
        (jax.nn.one_hot(top_idx[:, 0], e, dtype=jnp.float32)), axis=0
    )
    aux = e * jnp.sum(me * ce)
    return gates, top_idx, aux


def moe_apply(p: dict, x: Array, cfg, shd: Sharder) -> Tuple[Array, Array]:
    """x: (B,S,d) -> (y, aux_loss). Dispatches on cfg.moe_impl."""
    if cfg.moe_impl == "alltoall" and shd.mesh is not None:
        tp = shd.mesh.shape.get("model", 1)
        b_, s, _ = x.shape
        dp = 1
        for ax in ("pod", "data"):
            dp *= shd.mesh.shape.get(ax, 1)
        t_loc = (b_ // dp) * s if b_ % dp == 0 else 0
        if tp > 1 and t_loc % tp == 0:
            return moe_apply_alltoall(p, x, cfg, shd)
    return moe_apply_dispatch(p, x, cfg, shd)


def moe_apply_dispatch(p: dict, x: Array, cfg, shd: Sharder) -> Tuple[Array, Array]:
    """Baseline: sort+scatter capacity dispatch, collectives left to XLA SPMD."""
    b_, s, d = x.shape
    t = b_ * s
    k = cfg.experts_per_token
    e = cfg.num_experts
    xt = x.reshape(t, d)
    gates, idx, aux = _route(p, xt, cfg)

    # capacity per expert: cf x the mean load, floored at 8 slots so tiny
    # decode batches keep headroom (serve configs raise cf for dropless-ness)
    cap = max(-(-int(cfg.moe_capacity_factor * t * k) // e), 8)

    flat_e = idx.reshape(-1)  # (T*k,) expert id per (token, slot)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank of each entry within its expert group
    first_of_group = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(t * k) - first_of_group
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    slot = jnp.where(rank < cap, flat_e * cap + rank, e * cap)  # sentinel drop row

    x_rep = jnp.repeat(xt, k, axis=0)  # (T*k, d)
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].add(x_rep)
    buf = buf[: e * cap].reshape(e, cap, d)
    buf = shd(buf, ("experts", None, "act_embed"))

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = shd(h, ("experts", None, "act_mlp"))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])

    flat_out = out_buf.reshape(e * cap, d)
    flat_out = jnp.concatenate([flat_out, jnp.zeros((1, d), x.dtype)], axis=0)
    y_rep = flat_out[slot]  # dropped tokens pick the zero row
    y = (y_rep.reshape(t, k, d) * gates[..., None].astype(x.dtype)).sum(axis=1)

    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, cfg, shd).reshape(t, d)
    return y.reshape(b_, s, d), aux


# ---------------------------------------------------------------------------
# optimized path: explicit expert-parallel all-to-all under shard_map
# (§Perf beyond-paper optimization — see EXPERIMENTS.md. The pjit dispatch
# above lets XLA resolve the token->expert reshard, which materialises the
# full (E, C, d) buffer per device and all-reduces it (~GBs per MoE layer at
# 1M tokens). Here every device routes its own token slice, exchanges ONLY
# real token payloads over the "model" axis (all_to_all there and back), and
# FSDP-gathers its local experts' weights explicitly.)


def _local_dispatch(xt, gates, idx, e, cap, d):
    """Scatter tokens into per-expert slots. xt: (T,d); idx/gates: (T,k)."""
    t, k = idx.shape
    flat_e = idx.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank_sorted = jnp.arange(t * k) - first
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    slot = jnp.where(rank < cap, flat_e * cap + rank, e * cap)
    x_rep = jnp.repeat(xt, k, axis=0)
    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].add(x_rep)
    return buf[: e * cap], slot


def moe_apply_alltoall(p: dict, x: Array, cfg, shd: Sharder) -> Tuple[Array, Array]:
    """x: (B,S,d) -> (y, aux). Requires shd.mesh with a "model" axis."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh = shd.mesh
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    fsdp = "data" if "data" in mesh.shape else None
    tp = mesh.shape["model"]
    e, k = cfg.num_experts, cfg.experts_per_token
    e_loc = e // tp
    assert e % tp == 0, (e, tp)
    b_, s, d = x.shape

    # weights arrive FSDP-sharded on the d/f dims (P from the rule table);
    # gather them explicitly inside (transpose = reduce-scatter for grads).
    wg_spec = P("model", fsdp, None)
    wd_spec = P("model", None, fsdp)

    def body(x_blk, router, wg, wu, wd):
        # x_blk: (B_loc, S, d) — replicated over "model"; take this shard's
        # token slice so the 16 model shards don't duplicate routing work.
        if fsdp:
            wg_ = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
            wu_ = jax.lax.all_gather(wu, fsdp, axis=1, tiled=True)
            wd_ = jax.lax.all_gather(wd, fsdp, axis=2, tiled=True)
        else:
            wg_, wu_, wd_ = wg, wu, wd
        t_loc = x_blk.shape[0] * x_blk.shape[1]
        tpd = t_loc // tp
        my = jax.lax.axis_index("model")
        xt = x_blk.reshape(t_loc, d)
        xs = jax.lax.dynamic_slice_in_dim(xt, my * tpd, tpd, axis=0)

        logits = jnp.einsum("td,de->te", xs.astype(jnp.float32),
                            router.astype(jnp.float32))
        if k == 1 and cfg.name.startswith("llama4"):
            top_val, top_idx = jax.lax.top_k(logits, 1)
            gates = jax.nn.sigmoid(top_val)
            probs = jax.nn.softmax(logits, axis=-1)
        else:
            probs = jax.nn.softmax(logits, axis=-1)
            gates, top_idx = jax.lax.top_k(probs, k)
            gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        cap = max(-(-int(cfg.moe_capacity_factor * tpd * k) // e), 4)
        buf, slot = _local_dispatch(xs, gates, top_idx, e, cap, d)
        # (E*cap, d) -> (tp, E_loc*cap, d): destination-major
        send = buf.reshape(tp, e_loc * cap, d)
        recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                                  tiled=False)
        # recv: (tp, E_loc*cap, d) — rows from every source, my experts only
        hbuf = recv.reshape(tp, e_loc, cap, d).transpose(1, 0, 2, 3) \
                   .reshape(e_loc, tp * cap, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", hbuf, wg_))
        h = h * jnp.einsum("ecd,edf->ecf", hbuf, wu_)
        obuf = jnp.einsum("ecf,efd->ecd", h, wd_)
        back = obuf.reshape(e_loc, tp, cap, d).transpose(1, 0, 2, 3) \
                   .reshape(tp, e_loc * cap, d)
        ret = jax.lax.all_to_all(back, "model", split_axis=0, concat_axis=0,
                                 tiled=False)
        flat = jnp.concatenate(
            [ret.reshape(e * cap, d), jnp.zeros((1, d), x_blk.dtype)], axis=0)
        y_rep = flat[slot]
        ys = (y_rep.reshape(tpd, k, d) * gates[..., None].astype(x_blk.dtype)
              ).sum(axis=1)
        # reassemble the full local token set across the model axis
        y = jax.lax.all_gather(ys, "model", axis=0, tiled=True)
        # aux loss (switch-style), averaged over every shard's token slice
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(top_idx[:, 0], e, dtype=jnp.float32), axis=0)
        aux = e * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, "model")
        for ax in batch_axes:
            aux = jax.lax.pmean(aux, ax)
        return y.reshape(x_blk.shape), aux

    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(batch_axes or None, None, None), P(None, None),
                  wg_spec, wg_spec, wd_spec),
        out_specs=(P(batch_axes or None, None, None), P()),
        check_rep=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if "shared" in p:
        y = y + mlp_apply(p["shared"], x, cfg, shd)
    return y, aux
