"""Shared building blocks: param builder, norms, rope, embeddings, sharder.

Param convention: init functions return nested dicts whose leaves are
``P(value, axes)``; ``split_tree`` separates them into a value tree and a
logical-axes tree of identical structure. ``Builder`` works in concrete mode
(real rng init) or abstract mode (ShapeDtypeStruct leaves — used by the
dry-run so no host RAM is ever allocated for 400B-param models).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class P:
    value: Any
    axes: Tuple[Optional[str], ...]


def _is_p(x) -> bool:
    return isinstance(x, P)


def split_tree(tree):
    """P-leaf tree -> (value tree, logical-axes tree)."""
    values = jax.tree_util.tree_map(lambda p: p.value, tree, is_leaf=_is_p)
    axes = jax.tree_util.tree_map(lambda p: p.axes, tree, is_leaf=_is_p)
    return values, axes


class Builder:
    """Creates parameters (concrete or abstract) with logical axes attached."""

    def __init__(self, key, dtype: str, abstract: bool = False):
        self.key = key
        self.dtype = jnp.dtype(dtype)
        self.abstract = abstract

    def make(self, shape, axes, init: str = "fan_in", scale: float | None = None) -> P:
        assert len(shape) == len(axes), (shape, axes)
        if self.abstract:
            return P(jax.ShapeDtypeStruct(tuple(shape), self.dtype), tuple(axes))
        self.key, sub = jax.random.split(self.key)
        if init == "zeros":
            v = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            v = jnp.ones(shape, self.dtype)
        elif init == "normal":
            v = (scale if scale is not None else 0.02) * jax.random.normal(
                sub, shape, self.dtype
            )
        elif init == "fan_in":
            # fan-in = product of all dims but the last
            fan_in = max(1, int(np.prod(shape[:-1])))
            v = jax.random.normal(sub, shape, self.dtype) / np.sqrt(fan_in)
        else:
            raise ValueError(init)
        return P(v, tuple(axes))


# ---------------------------------------------------------------------------
# sharding hook


class Sharder:
    """Applies with_sharding_constraint from logical activation axes.

    A no-op unless constructed with (mesh, rules); the model code calls
    ``shd(x, ("act_batch", "act_seq", "act_embed"))`` everywhere it matters
    and stays mesh-agnostic.
    """

    def __init__(self, mesh=None, rules=None):
        self.mesh = mesh
        self.rules = rules

    def __call__(self, x: Array, axes: Tuple[Optional[str], ...]) -> Array:
        if self.mesh is None or self.rules is None:
            return x
        from repro.sharding.logical import spec_for  # local import, no cycle

        spec = spec_for(axes, self.rules, self.mesh, x.shape)
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(self.mesh, spec)
        )


# ---------------------------------------------------------------------------
# norms


def rmsnorm(x: Array, scale: Array, eps: float) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def layernorm(x: Array, scale: Array, bias: Array, eps: float) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * scale + (
        bias if bias is not None else 0
    )


def init_norm(b: Builder, d: int, norm_type: str) -> dict:
    out = {"scale": b.make((d,), (None,), init="ones")}
    if norm_type == "layernorm":
        out["bias"] = b.make((d,), (None,), init="zeros")
    return out


def apply_norm(p: dict, x: Array, norm_type: str, eps: float) -> Array:
    if norm_type == "layernorm":
        return layernorm(x, p["scale"], p.get("bias"), eps)
    return rmsnorm(x, p["scale"], eps)


def groupnorm_heads(x: Array, scale: Array, bias: Array, eps: float) -> Array:
    """Per-head groupnorm over the last dim; x: (..., H, K)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(dt) * scale + bias


# ---------------------------------------------------------------------------
# position embeddings


def rope_angles(positions: Array, dim: int, theta: float) -> tuple[Array, Array]:
    """positions: (...,) int -> cos/sin of shape (..., dim//2)."""
    freqs = 1.0 / (
        theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    )  # (dim/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (..., S, H, K); cos/sin: (..., S, K//2) -> rotate-half rope."""
    dt = x.dtype
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


def sinusoidal_pos(positions: Array, d_model: int) -> Array:
    """(...,) int -> (..., d_model) fixed sinusoidal table (musicgen-style)."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (np.log(10000.0) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# activations


def act_fn(name: str, x: Array) -> Array:
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "silu":
        return jax.nn.silu(x)
    raise ValueError(name)
