"""Weight-only int8 quantization for the serve path (§Perf, llama4 decode).

Per-group-slice symmetric per-tensor quantization of the scanned layer
stack: each stacked leaf (G, ...) gets a per-group scale (G,), so the scan
body dequantizes its slice with one scalar multiply. Embedding / final norm
/ lm_head stay bf16 (gathers + tiny tensors; the 97% of bytes are in the
layer stack — for llama4, the experts).

Effect on the decode roofline: weight bytes (HBM stream and, when FSDP-
sharded, the per-layer all-gather payload) halve vs bf16. Accuracy: weight-
only int8 is the standard production setting (per-channel scales would be
the next refinement; per-tensor is enough for the dry-run's byte accounting
and the CPU equivalence test).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _is_quantizable(leaf) -> bool:
    # stacked layer leaves are (G, ...) float arrays with >= 2 dims
    return hasattr(leaf, "ndim") and leaf.ndim >= 2 and jnp.issubdtype(
        jnp.result_type(leaf.dtype), jnp.floating
    )


def quantize_layers(layers: Any) -> Tuple[Any, Any]:
    """(int8 tree, per-group scale tree). Non-quantizable leaves pass through
    (their 'scale' is None)."""

    def q(leaf):
        if not _is_quantizable(leaf):
            return leaf
        red = tuple(range(1, leaf.ndim))
        scale = jnp.max(jnp.abs(leaf.astype(jnp.float32)), axis=red) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        qv = jnp.round(
            leaf.astype(jnp.float32) / scale.reshape((-1,) + (1,) * (leaf.ndim - 1))
        ).astype(jnp.int8)
        return qv

    def s(leaf):
        if not _is_quantizable(leaf):
            return None
        return jnp.max(
            jnp.abs(leaf.astype(jnp.float32)), axis=tuple(range(1, leaf.ndim))
        ) / 127.0

    return (jax.tree_util.tree_map(q, layers),
            jax.tree_util.tree_map(s, layers))


def abstract_quantized_layers(layers_sds: Any) -> Tuple[Any, Any]:
    def q(leaf):
        if leaf.ndim >= 2 and jnp.issubdtype(leaf.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(leaf.shape, jnp.int8)
        return leaf

    def s(leaf):
        if leaf.ndim >= 2 and jnp.issubdtype(leaf.dtype, jnp.floating):
            return jax.ShapeDtypeStruct((leaf.shape[0],), jnp.float32)
        return None

    return (jax.tree_util.tree_map(q, layers_sds),
            jax.tree_util.tree_map(s, layers_sds))


def scale_logical_axes(layer_axes: Any) -> Any:
    """Axes tree for the scales: ('layers',) for quantized leaves."""

    def s(axes):
        if isinstance(axes, tuple) and len(axes) >= 2:
            return ("layers",)
        return None

    return jax.tree_util.tree_map(
        s, layer_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x),
    )


def dequantize_group(gp_q: Any, gp_scale: Any, dtype) -> Any:
    """Dequantize one scan slice: q (…) int8, scale scalar -> float."""

    def d(q, s):
        if s is None:
            return q
        return (q.astype(jnp.float32) * s).astype(dtype)

    return jax.tree_util.tree_map(
        d, gp_q, gp_scale,
        is_leaf=lambda x: x is None or hasattr(x, "ndim"),
    )
