"""Attention token mixers: GQA/MHA (qk_norm/bias options) and MLA.

Train/prefill use a chunked, flash-style causal attention in pure jnp
(numerically stable online softmax over kv chunks). The baseline computes the
full block rectangle with a causal mask — a known ~2x FLOP overhead on the
strictly-causal half that we track in the roofline's useful-compute ratio and
attack in §Perf (the Pallas flash kernel with real block skipping is the TPU
runtime path; the jnp path is what the dry-run lowers so cost_analysis sees
honest XLA HLO).

Decode attends one new token against the cache: GQA caches (k, v) per layer;
MLA caches the *compressed* kv latent + shared rope key (its whole point) and
uses the absorbed-matmul formulation (DeepSeek-V2 appendix) so no per-step
re-expansion of the cache happens.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import (
    Builder,
    Sharder,
    apply_norm,
    apply_rope,
    init_norm,
    rmsnorm,
    rope_angles,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# params


def init_attn(b: Builder, cfg) -> dict:
    d, h, g, k = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": b.make((d, h, k), ("embed", "heads", "head")),
        "wk": b.make((d, g, k), ("embed", "kv_heads", "head")),
        "wv": b.make((d, g, k), ("embed", "kv_heads", "head")),
        "wo": b.make((h, k, d), ("heads", "head", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = b.make((h, k), ("heads", "head"), init="zeros")
        p["bk"] = b.make((g, k), ("kv_heads", "head"), init="zeros")
        p["bv"] = b.make((g, k), ("kv_heads", "head"), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = b.make((k,), (None,), init="ones")
        p["k_norm"] = b.make((k,), (None,), init="ones")
    return p


def init_mla(b: Builder, cfg) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_down": b.make((d, rq), ("embed", "q_lora")),
        "q_norm": b.make((rq,), (None,), init="ones"),
        "wq_up": b.make((rq, h, dn + dr), ("q_lora", "heads", "head")),
        "wkv_down": b.make((d, rkv), ("embed", "kv_lora")),
        "kv_norm": b.make((rkv,), (None,), init="ones"),
        "wk_rope": b.make((d, dr), ("embed", "head")),
        "wk_up": b.make((rkv, h, dn), ("kv_lora", "heads", "head")),
        "wv_up": b.make((rkv, h, dv), ("kv_lora", "heads", "head")),
        "wo": b.make((h, dv, d), ("heads", "head", "embed")),
    }


# ---------------------------------------------------------------------------
# chunked causal attention core (train / prefill)


def _chunked_attention(q: Array, k: Array, v: Array, chunk: int, shd: Sharder) -> Array:
    """q: (B,S,H,K), k/v: (B,S,H,K) (kv already head-expanded). Causal.

    Online-softmax over kv chunks, scanned over q chunks. Baseline computes
    every (q-chunk, kv-chunk) pair and masks — see module docstring.
    """
    b_, s, h, d = q.shape
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    t = s // c
    scale = d**-0.5
    qc = q.reshape(b_, t, c, h, d)
    kc = k.reshape(b_, t, c, h, d).transpose(1, 0, 2, 3, 4)  # (t,B,c,H,K)
    vc = v.reshape(b_, t, c, h, d).transpose(1, 0, 2, 3, 4)

    def q_block(qi, q_blk):
        # q_blk: (B,c,H,K); online softmax over kv chunks
        m0 = jnp.full((b_, h, c), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b_, h, c), jnp.float32)
        o0 = jnp.zeros((b_, h, c, d), jnp.float32)

        def kv_block(carry, inp):
            m, l, o = carry
            kj, k_blk, v_blk = inp
            s_ = jnp.einsum(
                "bqhd,bkhd->bhqk", q_blk, k_blk, preferred_element_type=jnp.float32
            ) * scale
            # causal mask at chunk granularity + within the diagonal chunk
            qpos = qi * c + jnp.arange(c)[:, None]
            kpos = kj * c + jnp.arange(c)[None, :]
            s_ = jnp.where(qpos >= kpos, s_, -jnp.inf)
            m_new = jnp.maximum(m, s_.max(-1))
            p = jnp.exp(s_ - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            o_new = o * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, v_blk, preferred_element_type=jnp.float32
            )
            return (m_new, l_new, o_new), None

        (m, l, o), _ = jax.lax.scan(
            kv_block, (m0, l0, o0), (jnp.arange(t), kc, vc)
        )
        o = o / jnp.maximum(l[..., None], 1e-30)
        return o.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,c,H,K)

    out = jax.lax.map(lambda args: q_block(*args), (jnp.arange(t), qc.transpose(1, 0, 2, 3, 4)))
    # out: (t, B, c, H, K) -> (B, S, H, K)
    return out.transpose(1, 0, 2, 3, 4).reshape(b_, s, h, d)


def _full_attention(q: Array, k: Array, v: Array) -> Array:
    """Reference full-matrix causal attention (small S; used by tests)."""
    b_, s, h, d = q.shape
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    s_ = s_ * (d**-0.5)
    mask = jnp.tril(jnp.ones((s, s), bool))
    s_ = jnp.where(mask, s_, -jnp.inf)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v, preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def _expand_kv(x: Array, num_heads: int) -> Array:
    """(B,S,G,K) -> (B,S,H,K) by repeating each kv head H//G times."""
    b_, s, g, k = x.shape
    rep = num_heads // g
    if rep == 1:
        return x
    return jnp.repeat(x, rep, axis=2)


# ---------------------------------------------------------------------------
# GQA apply


def attn_forward(p: dict, x: Array, cfg, shd: Sharder, positions: Array,
                 use_chunked: bool = True) -> tuple[Array, dict]:
    """Train/prefill path. Returns (output, cache_entries)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dgk->bsgk", x, p["wk"])
    v = jnp.einsum("bsd,dgk->bsgk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_embed == "rope":
        cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = shd(q, ("act_batch", "act_seq", "act_heads", None))
    k = shd(k, ("act_batch", "act_seq", "act_kv_heads", None))
    v = shd(v, ("act_batch", "act_seq", "act_kv_heads", None))
    cache = {"k": k, "v": v}
    kx, vx = _expand_kv(k, cfg.num_heads), _expand_kv(v, cfg.num_heads)
    s_len = x.shape[1]
    if use_chunked and s_len > cfg.attn_chunk and s_len % cfg.attn_chunk == 0:
        o = _chunked_attention(q, kx, vx, cfg.attn_chunk, shd)
    else:
        o = _full_attention(q, kx, vx)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return shd(out, ("act_batch", "act_seq", "act_embed")), cache


def attn_decode(p: dict, x: Array, cfg, shd: Sharder, cache: dict, cur_index: Array
                ) -> tuple[Array, dict]:
    """x: (B,1,D) new token; cache: k/v (B,Smax,G,K). Returns (out, cache')."""
    b_, _, _ = x.shape
    g, h, kd = cfg.num_kv_heads, cfg.num_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dgk->bsgk", x, p["wk"])
    v = jnp.einsum("bsd,dgk->bsgk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_embed == "rope":
        pos = jnp.full((b_, 1), cur_index, jnp.int32)
        cos, sin = rope_angles(pos, kd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cur_index, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cur_index, axis=1)
    ck = shd(ck, ("act_batch", "act_kv_seq", "act_kv_heads", None))
    cv = shd(cv, ("act_batch", "act_kv_seq", "act_kv_heads", None))
    rep = h // g
    qg = q.reshape(b_, g, rep, kd)
    s_ = jnp.einsum("bgrk,bsgk->bgrs", qg, ck, preferred_element_type=jnp.float32)
    s_ = s_ * (kd**-0.5)
    smax = ck.shape[1]
    valid = jnp.arange(smax)[None, None, None, :] <= cur_index
    s_ = jnp.where(valid, s_, -jnp.inf)
    w = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bgrs,bsgk->bgrk", w, cv, preferred_element_type=jnp.float32)
    o = o.reshape(b_, 1, h, kd).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA apply


def _mla_qkv(p: dict, x: Array, cfg, positions: Array):
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wq_down"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_up"])  # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ckv = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wkv_down"]), p["kv_norm"], cfg.norm_eps)
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["wk_rope"])  # shared across heads
    cos, sin = rope_angles(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[..., None, :], cos, sin)[..., 0, :]
    return q_nope, q_rope, ckv, k_rope


def mla_forward(p: dict, x: Array, cfg, shd: Sharder, positions: Array,
                use_chunked: bool = True) -> tuple[Array, dict]:
    """Train/prefill MLA with explicit (uncompressed) attention math."""
    q_nope, q_rope, ckv, k_rope = _mla_qkv(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wk_up"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["wv_up"])
    h = cfg.num_heads
    k_rope_h = jnp.broadcast_to(k_rope[..., None, :], (*k_rope.shape[:2], h, cfg.qk_rope_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    q = shd(q, ("act_batch", "act_seq", "act_heads", None))
    k = shd(k, ("act_batch", "act_seq", "act_heads", None))
    # v head dim may differ from qk dim; pad v to qk width for the shared core
    dqk = cfg.qk_nope_dim + cfg.qk_rope_dim
    dv = cfg.v_head_dim
    s_len = x.shape[1]
    if use_chunked and s_len > cfg.attn_chunk and s_len % cfg.attn_chunk == 0:
        vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dqk - dv))) if dqk > dv else v
        o = _chunked_attention(q, k, vpad, cfg.attn_chunk, shd)[..., :dv]
    else:
        s_ = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
        s_ = s_ * (dqk**-0.5)
        s = x.shape[1]
        mask = jnp.tril(jnp.ones((s, s), bool))
        w = jax.nn.softmax(jnp.where(mask, s_, -jnp.inf), axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", w, v, preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
    cache = {"ckv": ckv, "k_rope": k_rope}
    return shd(out, ("act_batch", "act_seq", "act_embed")), cache


def mla_decode(p: dict, x: Array, cfg, shd: Sharder, cache: dict, cur_index: Array
               ) -> tuple[Array, dict]:
    """Absorbed-matmul MLA decode against the compressed cache.

    score(q, t) = q_nope^T (W_uk c_t) + q_rope^T k_rope_t
                = (W_uk^T q_nope)^T c_t + q_rope^T k_rope_t
    out_head    = W_uv^T (sum_t w_t c_t)
    so the cache stays compressed: (B, S, r_kv) + (B, S, dr).
    """
    b_ = x.shape[0]
    pos = jnp.full((b_, 1), cur_index, jnp.int32)
    q_nope, q_rope, ckv_new, k_rope_new = _mla_qkv(p, x, cfg, pos)
    c = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), cur_index, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new.astype(cache["k_rope"].dtype), cur_index, axis=1)
    c = shd(c, ("act_batch", "act_kv_seq", None))
    kr = shd(kr, ("act_batch", "act_kv_seq", None))
    # absorb W_uk into q:  (B,1,H,dn) x (r,h,dn) -> (B,H,r)
    q_c = jnp.einsum("bshk,rhk->bhr", q_nope, p["wk_up"])
    s_c = jnp.einsum("bhr,bsr->bhs", q_c, c, preferred_element_type=jnp.float32)
    s_r = jnp.einsum("bshk,btk->bht", q_rope, kr, preferred_element_type=jnp.float32)
    dqk = cfg.qk_nope_dim + cfg.qk_rope_dim
    s_ = (s_c + s_r) * (dqk**-0.5)
    smax = c.shape[1]
    valid = jnp.arange(smax)[None, None, :] <= cur_index
    w = jax.nn.softmax(jnp.where(valid, s_, -jnp.inf), axis=-1)
    o_c = jnp.einsum("bhs,bsr->bhr", w, c, preferred_element_type=jnp.float32)
    o = jnp.einsum("bhr,rhv->bhv", o_c.astype(x.dtype), p["wv_up"])
    out = jnp.einsum("bhv,hvd->bd", o, p["wo"])[:, None, :]
    return out, {"ckv": c, "k_rope": kr}
