"""Composable model zoo for the assigned architectures.

Params are plain pytrees (nested dicts). Every leaf carries a parallel
"logical axes" spec (see repro.sharding.logical) used to derive pjit
shardings per mesh. Layer stacks are scanned over layer *groups* (the
repeating pattern period), keeping HLO size O(period), not O(depth).
"""

from repro.models.model import (
    init_params,
    abstract_params,
    param_logical_axes,
    forward,
    init_cache,
    abstract_cache,
    cache_logical_axes,
    decode_step,
    loss_fn,
    count_params,
    active_params,
)

__all__ = [
    "init_params",
    "abstract_params",
    "param_logical_axes",
    "forward",
    "init_cache",
    "abstract_cache",
    "cache_logical_axes",
    "decode_step",
    "loss_fn",
    "count_params",
    "active_params",
]
