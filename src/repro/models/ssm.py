"""Mamba-1 selective SSM token mixer (jamba's mamba layers).

TPU adaptation: the CUDA reference fuses the selective scan into one kernel
with recomputation; here the scan is chunked — ``lax.scan`` over sequence
chunks whose body does a within-chunk associative scan and is wrapped in
``jax.checkpoint``, so the (B, L, d_inner, N) transient never hits HBM for
backward (only the small per-chunk dt/B/C/x inputs are saved). The diagonal
A makes the recurrence h_t = a_t * h_{t-1} + b_t with elementwise a_t, which
the associative combine (a2*a1, a2*b1 + b2) parallelises within a chunk.

Decode carries (conv window, ssm state) — both O(1) in sequence length,
which is why jamba/rwkv run the long_500k cell and full-attention archs skip.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Builder, Sharder

Array = jax.Array


def d_inner_of(cfg) -> int:
    return cfg.ssm_expand * cfg.d_model


def init_mamba(b: Builder, cfg) -> dict:
    d = cfg.d_model
    di = d_inner_of(cfg)
    n = cfg.ssm_state_dim
    r = cfg.ssm_dt_rank
    dc = cfg.ssm_conv_dim
    return {
        "w_in": b.make((d, 2 * di), ("embed", "mlp")),
        "conv_w": b.make((dc, di), (None, "mlp")),
        "conv_b": b.make((di,), ("mlp",), init="zeros"),
        "w_x_dt": b.make((di, r), ("mlp", None)),
        "w_dt": b.make((r, di), (None, "mlp")),
        "dt_bias": b.make((di,), ("mlp",), init="zeros"),
        "w_B": b.make((di, n), ("mlp", None)),
        "w_C": b.make((di, n), ("mlp", None)),
        "A_log": b.make((di, n), ("mlp", None), init="zeros"),
        "D": b.make((di,), ("mlp",), init="ones"),
        "w_out": b.make((di, d), ("mlp", "embed")),
    }


def _causal_conv(x: Array, w: Array, bias: Array) -> Array:
    """Depthwise causal conv along seq. x: (B,S,di), w: (dc,di)."""
    dc = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    # windowed sum: y[t] = sum_j w[j] * x[t - (dc-1) + j]
    y = jnp.zeros_like(x)
    for j in range(dc):  # dc is 4 — unrolled, stays tiny in HLO
        y = y + xp[:, j : j + x.shape[1], :] * w[j]
    return y + bias


def _scan_combine(c1, c2):
    a1, b1 = c1
    a2, b2 = c2
    return a1 * a2, a2 * b1 + b2


def _chunk_body(h0: Array, dt: Array, B: Array, C: Array, xg: Array, A: Array
                ) -> Tuple[Array, Array]:
    """One chunk. h0: (B,di,N); dt/xg: (B,L,di); B/C: (B,L,N) -> (h_last, y (B,L,di))."""
    a = jnp.exp(dt[..., None] * A)  # (B,L,di,N)
    bx = (dt * xg)[..., None] * B[:, :, None, :]  # (B,L,di,N)
    a_sc, b_sc = jax.lax.associative_scan(_scan_combine, (a, bx), axis=1)
    h = b_sc + a_sc * h0[:, None]  # (B,L,di,N)
    y = jnp.einsum("bldn,bln->bld", h, C)
    return h[:, -1], y


def selective_scan(dt: Array, B: Array, C: Array, xg: Array, A: Array,
                   chunk: int, h0: Array | None = None) -> Tuple[Array, Array]:
    """Chunked selective scan. dt/xg: (B,S,di); B/C: (B,S,N). Returns (y, h_last)."""
    b_, s, di = xg.shape
    n = B.shape[-1]
    c = min(chunk, s)
    pad = -s % c
    if pad:
        # identity updates: dt=0 -> a=exp(0)=1, b=0; state and y[:s] unaffected
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        xg = jnp.pad(xg, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    t = sp // c
    if h0 is None:
        h0 = jnp.zeros((b_, di, n), jnp.float32)

    step = jax.checkpoint(lambda h, xs: _chunk_body(h, *xs, A))

    xs = (
        dt.reshape(b_, t, c, di).swapaxes(0, 1),
        B.reshape(b_, t, c, n).swapaxes(0, 1),
        C.reshape(b_, t, c, n).swapaxes(0, 1),
        xg.reshape(b_, t, c, di).swapaxes(0, 1),
    )
    h_last, yt = jax.lax.scan(step, h0, xs)
    y = yt.swapaxes(0, 1).reshape(b_, sp, di)[:, :s]
    return y, h_last


def mamba_forward(p: dict, x: Array, cfg, shd: Sharder) -> Tuple[Array, dict]:
    """Train/prefill. x: (B,S,D). Returns (out, state) — state for decode handoff."""
    b_, s, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xz = shd(xz, ("act_batch", "act_seq", "act_mlp"))
    xp, z = jnp.split(xz, 2, axis=-1)
    xc = _causal_conv(xp, p["conv_w"], p["conv_b"])
    xg = jax.nn.silu(xc)
    dt = jax.nn.softplus(
        jnp.einsum("bsi,ir,re->bse", xg, p["w_x_dt"], p["w_dt"]) + p["dt_bias"]
    ).astype(jnp.float32)
    Bm = jnp.einsum("bsi,in->bsn", xg, p["w_B"]).astype(jnp.float32)
    Cm = jnp.einsum("bsi,in->bsn", xg, p["w_C"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_last = selective_scan(dt, Bm, Cm, xg.astype(jnp.float32), A, cfg.ssm_chunk)
    y = y.astype(x.dtype) + p["D"] * xg
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    state = {
        "h": h_last,  # (B,di,N) f32
        "conv": xp[:, -(cfg.ssm_conv_dim - 1):, :] if s >= cfg.ssm_conv_dim - 1
        else jnp.pad(xp, ((0, 0), (cfg.ssm_conv_dim - 1 - s, 0), (0, 0))),
    }
    return shd(out, ("act_batch", "act_seq", "act_embed")), state


def mamba_decode(p: dict, x: Array, cfg, shd: Sharder, state: dict
                 ) -> Tuple[Array, dict]:
    """One-token step. x: (B,1,D); state: h (B,di,N) f32, conv (B,dc-1,di)."""
    xz = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xp, z = jnp.split(xz, 2, axis=-1)  # (B,1,di)
    win = jnp.concatenate([state["conv"], xp], axis=1)  # (B,dc,di)
    xc = jnp.einsum("bci,ci->bi", win, p["conv_w"]) + p["conv_b"]
    xg = jax.nn.silu(xc)[:, None, :]  # (B,1,di)
    dt = jax.nn.softplus(
        jnp.einsum("bsi,ir,re->bse", xg, p["w_x_dt"], p["w_dt"]) + p["dt_bias"]
    ).astype(jnp.float32)
    Bm = jnp.einsum("bsi,in->bsn", xg, p["w_B"]).astype(jnp.float32)
    Cm = jnp.einsum("bsi,in->bsn", xg, p["w_C"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[:, 0, :, None] * A)  # (B,di,N)
    bx = (dt[:, 0] * xg[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
    h = a * state["h"] + bx
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None, :].astype(x.dtype)
    y = y + p["D"] * xg
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    return out, {"h": h, "conv": win[:, 1:, :]}
