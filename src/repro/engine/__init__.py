"""`repro.engine` — the canonical entry point for yCHG computations.

One device-resident API over every backend, batch shape, and mesh. Build a
:class:`YCHGEngine` from a frozen :class:`YCHGConfig`; call ``analyze``
(one mask), ``analyze_batch`` (a stack), or ``analyze_stream`` (an
iterable). Every call returns a :class:`YCHGResult` pytree that stays on
device; ``.to_host()`` gives the old host dict, ``.to_summary()`` the
``core.ychg.YCHGSummary`` view.

Backend dispatch lives in :mod:`repro.engine.registry`: implementations
self-register with capability flags and ``backend="auto"`` resolves per
call from the input shape and available devices — no if/elif chains, and
the shard_map path is just the fused backend with a mesh attached
(``engine.with_mesh(mesh)``).

Migration from the four legacy call sites (all now route through here):

  legacy call                                   engine form
  --------------------------------------------  ---------------------------------
  core.api.analyze_image(img, backend="jax")    YCHGEngine(YCHGConfig(
                                                  backend="jax")
                                                ).analyze(img).to_host()
  kernels.ops.analyze_fused(stack)              YCHGEngine(YCHGConfig(
                                                  backend="fused")
                                                ).analyze_batch(stack)
  sharding.batch_sharded_analyze(stack,         YCHGEngine(YCHGConfig(
      mesh=mesh)                                  backend="fused"),
                                                  mesh=mesh,
                                                ).analyze_batch(stack)
  data.pipeline.ychg_stats(masks,               data.pipeline.ychg_stats(masks,
      backend="fused")                              engine=engine)

``core.api.analyze_image`` and ``sharding.batch_sharded_analyze`` remain as
thin shims that emit ``DeprecationWarning`` and delegate here; CI runs the
examples with ``-W error::DeprecationWarning`` so no in-repo caller can
regress onto them.
"""

from repro.engine.engine import YCHGConfig, YCHGEngine, YCHGResult
from repro.engine.registry import (
    BackendSpec,
    backend_names,
    get_backend,
    register_backend,
    resolve,
)
from repro.engine import backends as _backends  # noqa: F401  (self-registration)

__all__ = [
    "BackendSpec",
    "YCHGConfig",
    "YCHGEngine",
    "YCHGResult",
    "backend_names",
    "get_backend",
    "register_backend",
    "resolve",
]
