"""`repro.engine` — the canonical entry point for image-operator compute.

One device-resident API over every op, backend, batch shape, and mesh.
Build an :class:`Engine` from a frozen :class:`EngineConfig` (née
``YCHGConfig`` — same class); call ``analyze`` (one mask), ``analyze_batch``
(a stack), ``analyze_stream`` (an iterable), or ``run_pipeline`` (an
ordered op chain, executed device-resident end to end). Every call returns
the op's result pytree that stays on device; ``.to_host()`` gives the old
host dict, ``.to_summary()`` the op's summary view.

Backend dispatch lives in :mod:`repro.engine.registry`, keyed on
``(op, backend name)``: implementations self-register with capability
flags and ``backend="auto"`` resolves per call from (op, platform, batch
shape, mesh) — no if/elif chains, and the shard_map path is just a
mesh-capable backend with a mesh attached (``engine.with_mesh(mesh)``).
What each op *is* (result pytree, reference parity bar, pipeline
chainability) lives in :mod:`repro.engine.ops`; ``docs/ops.md`` shows how
to add one.

Migration from the four legacy call sites (all now route through here):

  legacy call                                   engine form
  --------------------------------------------  ---------------------------------
  core.api.analyze_image(img, backend="jax")    Engine(EngineConfig(
                                                  backend="jax")
                                                ).analyze(img).to_host()
  kernels.ops.analyze_fused(stack)              Engine(EngineConfig(
                                                  backend="fused")
                                                ).analyze_batch(stack)
  sharding.batch_sharded_analyze(stack,         Engine(EngineConfig(
      mesh=mesh)                                  backend="fused"),
                                                  mesh=mesh,
                                                ).analyze_batch(stack)
  data.pipeline.ychg_stats(masks,               data.pipeline.ychg_stats(masks,
      backend="fused")                              engine=engine)

``core.api.analyze_image``, ``sharding.batch_sharded_analyze`` — and, since
the multi-op refactor, ``YCHGEngine`` itself — remain as thin shims that
emit ``DeprecationWarning`` and delegate here; CI runs the examples and
smoke drivers with ``-W error::DeprecationWarning`` so no in-repo caller
can regress onto them.
"""

from repro.engine.engine import (
    Engine,
    EngineConfig,
    YCHGConfig,
    YCHGEngine,
    YCHGResult,
)
from repro.engine.registry import (
    BackendSpec,
    UnknownOpError,
    backend_names,
    get_backend,
    register_backend,
    registered_ops,
    resolve,
)
from repro.engine.ops import (
    CCLResult,
    DenoiseResult,
    OpSpec,
    get_op,
    op_names,
    register_op,
)
from repro.engine.ops import _finalize_ychg_result_type as _fin

_fin()
del _fin
from repro.engine import backends as _backends  # noqa: E402,F401  (self-registration)

__all__ = [
    "BackendSpec",
    "CCLResult",
    "DenoiseResult",
    "Engine",
    "EngineConfig",
    "OpSpec",
    "UnknownOpError",
    "YCHGConfig",
    "YCHGEngine",
    "YCHGResult",
    "backend_names",
    "get_backend",
    "get_op",
    "op_names",
    "register_backend",
    "register_op",
    "registered_ops",
    "resolve",
]
