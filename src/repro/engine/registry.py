"""(op, backend) registry for the image-operator engine.

Every implementation of an operator registers itself here as a
:class:`BackendSpec` with capability flags instead of being named in an
if/elif chain. The registry is keyed on ``(op, name)`` — the platform grew
from "a yCHG server" into "an image-operator platform serving yCHG first",
so ``backend="auto"`` resolution is a pure function of (op, platform,
batch shape, mesh attached) over the registered specs:

  * ``op`` — which operator the spec implements (``"ychg"``, ``"ccl"``,
    ``"denoise"``, ...); the five original backends register under
    ``op="ychg"`` with unchanged behaviour;
  * ``device_kinds`` — platforms the backend can execute on at all
    (``"cpu"`` includes Pallas interpret mode: exact, Python-evaluated);
  * ``priority`` — per-platform preference; highest wins for ``auto``.
    This is how "fused on TPU, jnp elsewhere" is expressed as data:
    ``jax`` outranks ``fused`` on cpu/gpu, ``fused`` outranks ``jax`` on tpu;
  * ``supports_batch`` — the callable consumes a whole (B, H, W) stack in
    one device computation (vs the engine looping images on host);
  * ``supports_mesh`` — safe to ``shard_map`` over a batch-sharded device
    mesh (pure per-image math, no cross-image state).

The in-repo backends self-register on ``import repro.engine`` (see
``repro.engine.backends``). Out-of-tree code may register additional
backends — including whole new ops — with :func:`register_backend`;
``resolve.cache_clear()`` runs automatically on registration and the
generation counter lets engines revalidate cached resolutions.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import warnings
from typing import Callable, Mapping, Optional, Tuple

from repro.obs.histogram import DISPATCH_BOUNDS, Histogram, HistogramSnapshot

__all__ = [
    "BackendSpec",
    "UnknownOpError",
    "backend_names",
    "call_count",
    "dispatch_seconds",
    "get_backend",
    "note_call",
    "note_dispatch",
    "register_backend",
    "registered_ops",
    "reset_call_counts",
    "resolve",
]


class UnknownOpError(ValueError):
    """Raised when resolution names an op with no registered backend."""


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One registered operator implementation.

    ``run(imgs, config)`` takes a (B, H, W) stack (jax array for device
    backends, anything ``np.asarray``-able for host baselines) plus a
    ``YCHGConfig`` and returns the op's batched summary, bit-identical to
    the op's in-repo reference on the same stack (``core.ychg.analyze``
    for ``op="ychg"``; see ``repro.engine.ops`` for the others).
    """

    name: str
    run: Callable
    supports_batch: bool
    supports_mesh: bool
    device_kinds: Tuple[str, ...]
    # per-device-kind preference used by "auto"; kinds absent from the map
    # fall back to 0. Must only contain kinds from device_kinds.
    priority: Mapping[str, int] = dataclasses.field(default_factory=dict)
    # operator this spec implements; the registry key is (op, name)
    op: str = "ychg"

    def priority_on(self, platform: str) -> int:
        return self.priority.get(platform, 0)


_REGISTRY: dict[tuple[str, str], BackendSpec] = {}
_GENERATION = 0  # bumped on registration; engines cache resolution against it


def generation() -> int:
    """Monotonic registry version, for callers that cache resolved specs."""
    return _GENERATION


def register_backend(spec: BackendSpec) -> BackendSpec:
    """Register (or replace) a backend under (spec.op, spec.name)."""
    global _GENERATION
    for kind in spec.priority:
        if kind not in spec.device_kinds:
            raise ValueError(
                f"backend {spec.name!r}: priority for {kind!r} but "
                f"device_kinds={spec.device_kinds}"
            )
    _REGISTRY[(spec.op, spec.name)] = spec
    _GENERATION += 1
    resolve.cache_clear()
    return spec


def unregister_backend(name: str, op: str = "ychg") -> None:
    """Remove a backend (e.g. a benchmark/test stub); unknown names are a
    no-op. Engines revalidate their cached resolution via generation()."""
    global _GENERATION
    if _REGISTRY.pop((op, name), None) is not None:
        _GENERATION += 1
        resolve.cache_clear()


def backend_names(op: str = "ychg") -> tuple[str, ...]:
    return tuple(sorted(n for (o, n) in _REGISTRY if o == op))


def registered_ops() -> tuple[str, ...]:
    """Sorted names of every op with at least one registered backend."""
    return tuple(sorted({o for (o, _n) in _REGISTRY}))


# Per-(op, backend) invocation counters, bumped by the engine on every
# dispatch. Best-effort observability (GIL-atomic enough for tests and
# metrics, not a synchronised billing counter): the service layer uses them
# to prove that cache hits never reach a backend.
_CALL_COUNTS: "collections.Counter[tuple[str, str]]" = collections.Counter()


def note_call(name: str, op: str = "ychg") -> None:
    """Record one dispatch to backend ``name`` (called by the engine)."""
    _CALL_COUNTS[(op, name)] += 1


def call_count(name: Optional[str] = None, op: Optional[str] = None) -> int:
    """Dispatches so far: to backend ``name`` (summed over ops unless
    ``op`` narrows it), or to every backend when both are None."""
    return sum(
        c for (o, n), c in _CALL_COUNTS.items()
        if (name is None or n == name) and (op is None or o == op)
    )


def reset_call_counts() -> None:
    _CALL_COUNTS.clear()
    _DISPATCH_SECONDS.clear()


# Per-(op, backend) dispatch-cost histograms: how long the engine's
# synchronous dispatch call (issue, not device completion — jax dispatch is
# async) took. Same best-effort discipline as _CALL_COUNTS.
_DISPATCH_SECONDS: "dict[tuple[str, str], Histogram]" = {}


def note_dispatch(name: str, seconds: float, op: str = "ychg") -> None:
    """Record the synchronous dispatch cost of one engine call (called by
    the engine next to :func:`note_call`)."""
    key = (op, name)
    hist = _DISPATCH_SECONDS.get(key)
    if hist is None:
        hist = _DISPATCH_SECONDS.setdefault(key, Histogram(DISPATCH_BOUNDS))
    hist.observe(max(0.0, seconds))


def dispatch_seconds() -> "dict[tuple[str, str], HistogramSnapshot]":
    """Frozen dispatch-cost histogram snapshots, keyed (op, backend)."""
    return {key: h.snapshot() for key, h in _DISPATCH_SECONDS.items()}


def get_backend(name: str, op: str = "ychg") -> BackendSpec:
    try:
        return _REGISTRY[(op, name)]
    except KeyError:
        if op not in registered_ops():
            raise UnknownOpError(
                f"unknown op {op!r}; registered ops: {registered_ops()}"
            ) from None
        raise ValueError(
            f"unknown backend {name!r} for op {op!r}; registered: "
            f"{backend_names(op)}"
        ) from None


@functools.lru_cache(maxsize=None)
def resolve(backend: str, *, platform: str, need_mesh: bool = False,
            op: str = "ychg") -> BackendSpec:
    """Resolve a backend name (or ``"auto"``) to a spec for this call.

    ``auto`` picks the highest-priority spec registered for ``op`` that can
    run on ``platform`` (and, when a mesh is attached, that is
    mesh-capable). Explicit names are honoured as-is except that
    ``need_mesh`` rejects backends that cannot be shard_mapped. An op that
    is registered but has no backend claiming the current platform falls
    back to its best batch-capable backend with a warning — never a bare
    KeyError; an op nobody registered raises :class:`UnknownOpError`.
    """
    if op not in registered_ops():
        raise UnknownOpError(
            f"unknown op {op!r}; registered ops: {registered_ops()}"
        )
    if backend != "auto":
        spec = get_backend(backend, op)
        if need_mesh and not spec.supports_mesh:
            raise ValueError(
                f"backend {backend!r} (op {op!r}) does not support mesh "
                f"execution; mesh-capable backends: "
                f"{tuple(n for (o, n), s in sorted(_REGISTRY.items()) if o == op and s.supports_mesh)}"
            )
        return spec
    pool = [
        s for s in _REGISTRY.values()
        if s.op == op
        and s.supports_batch
        and (s.supports_mesh or not need_mesh)
    ]
    candidates = [s for s in pool if platform in s.device_kinds]
    if not candidates:
        if pool:
            # registered op, no backend claims this platform: pick the best
            # batch-capable spec anyway (interpret-mode backends are exact
            # everywhere) and say so, rather than dying on a lookup error
            best = max(pool, key=lambda s: (max(s.priority.values(),
                                                default=0), s.name))
            warnings.warn(
                f"op {op!r} has no backend registered for platform "
                f"{platform!r}; falling back to backend {best.name!r} "
                f"(device_kinds={best.device_kinds})",
                RuntimeWarning,
                stacklevel=2,
            )
            return best
        raise ValueError(
            f"no registered backend for op {op!r} can run on platform "
            f"{platform!r} (need_mesh={need_mesh}); registered: "
            f"{backend_names(op)}"
        )
    return max(candidates, key=lambda s: (s.priority_on(platform), s.name))
