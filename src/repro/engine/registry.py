"""Backend registry for the yCHG engine.

Every implementation of the paper's two-step algorithm registers itself
here as a :class:`BackendSpec` with capability flags instead of being named
in an if/elif chain. ``backend="auto"`` resolution is then a pure function
of (platform, batch shape, mesh attached) over the registered specs:

  * ``device_kinds`` — platforms the backend can execute on at all
    (``"cpu"`` includes Pallas interpret mode: exact, Python-evaluated);
  * ``priority`` — per-platform preference; highest wins for ``auto``.
    This is how "fused on TPU, jnp elsewhere" is expressed as data:
    ``jax`` outranks ``fused`` on cpu/gpu, ``fused`` outranks ``jax`` on tpu;
  * ``supports_batch`` — the callable consumes a whole (B, H, W) stack in
    one device computation (vs the engine looping images on host);
  * ``supports_mesh`` — safe to ``shard_map`` over a batch-sharded device
    mesh (pure per-image math, no cross-image state).

The five in-repo backends (``jax``/``fused``/``pallas``/``serial``/
``scalar``) self-register on ``import repro.engine`` (see
``repro.engine.backends``). Out-of-tree code may register additional
backends with :func:`register_backend`; ``resolve.cache_clear()`` runs
automatically on registration.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Callable, Mapping, Optional, Tuple

from repro.obs.histogram import DISPATCH_BOUNDS, Histogram, HistogramSnapshot

__all__ = [
    "BackendSpec",
    "backend_names",
    "call_count",
    "dispatch_seconds",
    "get_backend",
    "note_call",
    "note_dispatch",
    "register_backend",
    "reset_call_counts",
    "resolve",
]


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One registered yCHG implementation.

    ``run(imgs, config)`` takes a (B, H, W) mask stack (jax array for device
    backends, anything ``np.asarray``-able for host baselines) plus a
    ``YCHGConfig`` and returns a batched ``core.ychg.YCHGSummary`` that is
    bit-identical to ``core.ychg.analyze`` on the same stack.
    """

    name: str
    run: Callable
    supports_batch: bool
    supports_mesh: bool
    device_kinds: Tuple[str, ...]
    # per-device-kind preference used by "auto"; kinds absent from the map
    # fall back to 0. Must only contain kinds from device_kinds.
    priority: Mapping[str, int] = dataclasses.field(default_factory=dict)

    def priority_on(self, platform: str) -> int:
        return self.priority.get(platform, 0)


_REGISTRY: dict[str, BackendSpec] = {}
_GENERATION = 0  # bumped on registration; engines cache resolution against it


def generation() -> int:
    """Monotonic registry version, for callers that cache resolved specs."""
    return _GENERATION


def register_backend(spec: BackendSpec) -> BackendSpec:
    """Register (or replace) a backend; returns the spec for chaining."""
    global _GENERATION
    for kind in spec.priority:
        if kind not in spec.device_kinds:
            raise ValueError(
                f"backend {spec.name!r}: priority for {kind!r} but "
                f"device_kinds={spec.device_kinds}"
            )
    _REGISTRY[spec.name] = spec
    _GENERATION += 1
    resolve.cache_clear()
    return spec


def unregister_backend(name: str) -> None:
    """Remove a backend (e.g. a benchmark/test stub); unknown names are a
    no-op. Engines revalidate their cached resolution via generation()."""
    global _GENERATION
    if _REGISTRY.pop(name, None) is not None:
        _GENERATION += 1
        resolve.cache_clear()


def backend_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# Per-backend invocation counters, bumped by the engine on every dispatch.
# Best-effort observability (GIL-atomic enough for tests and metrics, not a
# synchronised billing counter): the service layer uses them to prove that
# cache hits never reach a backend.
_CALL_COUNTS: "collections.Counter[str]" = collections.Counter()


def note_call(name: str) -> None:
    """Record one dispatch to backend ``name`` (called by the engine)."""
    _CALL_COUNTS[name] += 1


def call_count(name: Optional[str] = None) -> int:
    """Dispatches to backend ``name`` so far (all backends when None)."""
    if name is None:
        return sum(_CALL_COUNTS.values())
    return _CALL_COUNTS[name]


def reset_call_counts() -> None:
    _CALL_COUNTS.clear()
    _DISPATCH_SECONDS.clear()


# Per-backend dispatch-cost histograms: how long the engine's synchronous
# dispatch call (issue, not device completion — jax dispatch is async) took,
# keyed by backend name. Same best-effort discipline as _CALL_COUNTS.
_DISPATCH_SECONDS: "dict[str, Histogram]" = {}


def note_dispatch(name: str, seconds: float) -> None:
    """Record the synchronous dispatch cost of one engine call (called by
    the engine next to :func:`note_call`)."""
    hist = _DISPATCH_SECONDS.get(name)
    if hist is None:
        hist = _DISPATCH_SECONDS.setdefault(name, Histogram(DISPATCH_BOUNDS))
    hist.observe(max(0.0, seconds))


def dispatch_seconds() -> "dict[str, HistogramSnapshot]":
    """Per-backend dispatch-cost histogram snapshots (frozen)."""
    return {name: h.snapshot() for name, h in _DISPATCH_SECONDS.items()}


def get_backend(name: str) -> BackendSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {backend_names()}"
        ) from None


@functools.lru_cache(maxsize=None)
def resolve(backend: str, *, platform: str, need_mesh: bool = False) -> BackendSpec:
    """Resolve a backend name (or ``"auto"``) to a spec for this call.

    ``auto`` picks the highest-priority registered spec that can run on
    ``platform`` (and, when a mesh is attached, that is mesh-capable).
    Explicit names are honoured as-is except that ``need_mesh`` rejects
    backends that cannot be shard_mapped.
    """
    if backend != "auto":
        spec = get_backend(backend)
        if need_mesh and not spec.supports_mesh:
            raise ValueError(
                f"backend {backend!r} does not support mesh execution; "
                f"mesh-capable backends: "
                f"{tuple(n for n, s in sorted(_REGISTRY.items()) if s.supports_mesh)}"
            )
        return spec
    candidates = [
        s for s in _REGISTRY.values()
        if platform in s.device_kinds
        and s.supports_batch
        and (s.supports_mesh or not need_mesh)
    ]
    if not candidates:
        raise ValueError(
            f"no registered backend can run on platform {platform!r} "
            f"(need_mesh={need_mesh}); registered: {backend_names()}"
        )
    return max(candidates, key=lambda s: (s.priority_on(platform), s.name))
