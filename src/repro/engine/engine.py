"""`YCHGConfig` / `YCHGResult` / `Engine` — the unified entry point.

One engine instance owns one dispatch policy (backend selection, Pallas tile
sizes, streaming threshold, optional device mesh) over every registered
*operator* — yCHG first, plus ``ccl`` and ``denoise`` — and exposes three
verbs (each takes ``op=`` to override the engine's default op per call):

  * ``analyze(img)``         — one (H, W) mask; internally a B=1 view of the
                               batched path, NOT a separate code path;
  * ``analyze_batch(stack)`` — a (B, H, W) stack in one device computation;
  * ``analyze_stream(it)``   — an iterable of masks/stacks, one result
                               yielded per item;

plus ``run_pipeline(stack, stages)``: an ordered op chain executed
device-resident end to end — each stage's output feeds the next with no
host round trip, bit-identical to issuing the stages as separate calls.

Every verb returns the op's result pytree (``YCHGResult`` for yCHG — see
``repro.engine.ops`` for the others): ``jax.tree_util``-registered device
arrays that can cross ``jit``/``shard_map`` boundaries and never leave the
device implicitly. ``.to_host()`` produces the legacy host dict.

``YCHGEngine`` remains as a deprecation shim over ``Engine`` (same policy,
op pinned to ``"ychg"``), mirroring the PR 2 treatment of
``core.api.analyze_image``.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Dict, Iterable, Iterator, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.ychg import YCHGSummary
from repro.engine import registry

Array = jax.Array

_FIELDS = ("runs", "cut_vertices", "transitions", "births", "deaths",
           "n_hyperedges", "n_transitions")


@dataclasses.dataclass(frozen=True)
class YCHGConfig:
    """Frozen, hashable engine construction knobs (shared by every op).

    backend            "auto" resolves per (op, platform) from the registry;
                       or any name registered for the engine's op
                       ("jax", "fused", "pallas", "serial", "scalar" for
                       ychg; "jax"/"pallas" for ccl and denoise).
    block_w, block_h   Pallas lane / streamed-row tile sizes.
    dtype              optional dtype name masks are cast to on ingest
                       (None = accept as-is; nonzero = foreground either way).
    mesh_axis          batch axis name used when a mesh is attached.
    interpret          Pallas interpret flag (None = auto: interpret off-TPU).
    stream_vmem_budget raw-tile bytes past which the fused/colscan kernels
                       switch to the H-streamed variant (VMEM threshold).
    """

    backend: str = "auto"
    block_w: int = 128
    block_h: int = 2048
    dtype: Optional[str] = None
    mesh_axis: str = "data"
    interpret: Optional[bool] = None
    stream_vmem_budget: int = 4 * 1024 * 1024


# the knobs are op-agnostic; EngineConfig is the preferred spelling going
# forward, YCHGConfig the historical one (both are the same class)
EngineConfig = YCHGConfig


@dataclasses.dataclass(frozen=True)
class YCHGResult:
    """Device-resident batched output of the two-step algorithm.

    Arrays always carry the leading batch dim — a single image is a B=1
    view. Registered with ``jax.tree_util`` (the ``batched`` flag is static
    aux data), so results flow through ``jit``/``vmap``/``tree_map``
    untouched. Nothing is copied to the host until ``to_host()``.
    """

    runs: Array           # (B, W) int32  step-1 per-column run counts
    cut_vertices: Array   # (B, W) int32  2*runs
    transitions: Array    # (B, W) bool   step-2 change signal
    births: Array         # (B, W) int32
    deaths: Array         # (B, W) int32
    n_hyperedges: Array   # (B,)   int32  total births
    n_transitions: Array  # (B,)   int32  number of transition columns
    batched: bool = dataclasses.field(default=True, metadata=dict(static=True))

    @property
    def batch_size(self) -> int:
        return self.runs.shape[0]

    def block_until_ready(self) -> "YCHGResult":
        jax.block_until_ready(tuple(getattr(self, f) for f in _FIELDS))
        return self

    def to_summary(self) -> YCHGSummary:
        """``core.ychg.YCHGSummary`` view (squeezed to (W,)/() for B=1 input)."""
        if self.batched:
            return YCHGSummary(*(getattr(self, f) for f in _FIELDS))
        return YCHGSummary(*(getattr(self, f)[0] for f in _FIELDS))

    def to_host(self) -> Dict[str, np.ndarray]:
        """The legacy ``core.api.analyze_image`` dict: host NumPy values."""
        s = self.to_summary()
        return {f: np.asarray(getattr(s, f)) for f in _FIELDS}


jax.tree_util.register_dataclass(
    YCHGResult, data_fields=list(_FIELDS), meta_fields=["batched"]
)


def _from_summary(s: YCHGSummary, batched: bool) -> YCHGResult:
    # hot-path constructor: fills __dict__ directly instead of going through
    # the frozen-dataclass __init__ (8 object.__setattr__ calls) — this sits
    # inside the engine's <=5us/call dispatch-overhead budget
    r = object.__new__(YCHGResult)
    d = r.__dict__
    d["runs"] = s.runs
    d["cut_vertices"] = s.cut_vertices
    d["transitions"] = s.transitions
    d["births"] = s.births
    d["deaths"] = s.deaths
    d["n_hyperedges"] = s.n_hyperedges
    d["n_transitions"] = s.n_transitions
    d["batched"] = batched
    return r


def _zero_pad_region(x: Array, valid_hw: Array) -> Array:
    """Zero rows >= h and cols >= w per image (valid_hw: (B, 2) int32).

    Between pipeline stages this restores the exact canvas a single-op
    submit would see — a stage may write nonzero values into the pad
    region (denoise's RMS does, next to native pixels), and the next stage
    must not observe them. h/w stay traced, so one compiled pipeline
    serves every ragged batch of a bucket shape.
    """
    _, h, w = x.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (h, w), 0)[None]
    cols = jax.lax.broadcasted_iota(jnp.int32, (h, w), 1)[None]
    keep = (rows < valid_hw[:, 0, None, None]) & (
        cols < valid_hw[:, 1, None, None])
    return jnp.where(keep, x, jnp.zeros((), x.dtype))


class Engine:
    """The sole dispatch point for image-operator computations.

    ``Engine()`` (all defaults) serves the ``ychg`` op, resolving the best
    backend per call; ``Engine(op="ccl")`` pins a different default op, and
    every verb accepts ``op=`` for per-call override. Attach a device mesh
    with ``with_mesh`` to batch-shard any batch-capable backend over it
    (padding to the mesh size and stripping the pad internally, so callers
    never see padded-length results).
    """

    def __init__(self, config: YCHGConfig = YCHGConfig(), *,
                 op: str = "ychg", mesh: Optional[Mesh] = None):
        if mesh is not None and config.mesh_axis not in mesh.axis_names:
            raise ValueError(
                f"mesh has axes {mesh.axis_names}, config.mesh_axis="
                f"{config.mesh_axis!r}"
            )
        self.config = config
        self.op = op
        self.mesh = mesh
        # platform is fixed per process; cache it out of the hot dispatch path
        self._platform = jax.default_backend()
        self._cast_dtype = None if config.dtype is None else jnp.dtype(config.dtype)
        # op -> (registry generation, resolved spec) — revalidated against
        # registry.generation() so late register_backend() calls still apply
        self._spec_cache: Dict[str, tuple[int, registry.BackendSpec]] = {}

    # ------------------------------------------------------------- plumbing

    def with_mesh(self, mesh: Optional[Mesh]) -> "Engine":
        """Same policy, batch-sharded over ``mesh`` (None detaches)."""
        return Engine(self.config, op=self.op, mesh=mesh)

    def with_config(self, **overrides: Any) -> "Engine":
        """New engine with ``dataclasses.replace``d config, same op/mesh."""
        return Engine(dataclasses.replace(self.config, **overrides),
                      op=self.op, mesh=self.mesh)

    def resolve_backend(self, op: Optional[str] = None) -> str:
        """Name of the backend this engine dispatches ``op`` to right now."""
        return self._resolve(op or self.op).name

    def _resolve(self, op: str) -> registry.BackendSpec:
        gen = registry.generation()
        cached = self._spec_cache.get(op)
        if cached is not None and cached[0] == gen:
            return cached[1]
        spec = registry.resolve(
            self.config.backend,
            platform=self._platform,
            need_mesh=self.mesh is not None,
            op=op,
        )
        self._spec_cache[op] = (gen, spec)
        return spec

    def _opspec(self, op: str):
        from repro.engine import ops as engine_ops

        return engine_ops.get_op(op)

    def _ingest(self, imgs: Any) -> Array:
        # device arrays pass through untouched: no host round-trip, and no
        # jnp.asarray no-op either (it costs ~17us/call of pure dispatch —
        # the engine's <=5us/call overhead budget lives or dies here)
        x = imgs if isinstance(imgs, jax.Array) else jnp.asarray(imgs)
        if self._cast_dtype is not None and x.dtype != self._cast_dtype:
            x = x.astype(self._cast_dtype)
        return x

    # ------------------------------------------------------------- dispatch

    def analyze(self, img: Any, *, op: Optional[str] = None):
        """One (H, W) mask -> B=1 result (never copies device->host)."""
        x = self._ingest(img)
        if x.ndim != 2:
            raise ValueError(f"analyze expects an (H, W) mask, got {x.shape}; "
                             "use analyze_batch for stacks")
        return self._run(x[None], batched=False, op=op or self.op)

    def analyze_batch(self, stack: Any, *, op: Optional[str] = None):
        """A (B, H, W) stack in one device computation."""
        x = self._ingest(stack)
        if x.ndim != 3:
            raise ValueError(f"analyze_batch expects a (B, H, W) stack, "
                             f"got {x.shape}")
        return self._run(x, batched=True, op=op or self.op)

    def analyze_stream(self, items: Iterable[Any], *,
                       op: Optional[str] = None) -> Iterator[Any]:
        """Lazily map ``analyze``/``analyze_batch`` over an iterable,
        double-buffering ingest against device compute.

        Each item may be an (H, W) mask or a (B, H, W) stack; one result is
        yielded per item, strictly in order. The stream runs one item ahead
        of the yield point: item n+1 is pulled from the iterator and its
        host->device transfer started *before* result n is yielded, so
        while the consumer handles result n (whose computation was
        dispatched asynchronously) the next item's host work and transfer
        are already in flight. Compose with ``data.pipeline.Prefetcher``
        for background host I/O.
        """
        run_op = op or self.op
        it = iter(items)
        pending = None
        while True:
            # pull and ingest (start the transfer of) item n+1 first ...
            try:
                item = next(it)
                x = self._ingest(item)
                if x.ndim == 2:
                    x, batched = x[None], False
                elif x.ndim == 3:
                    batched = True
                else:
                    raise ValueError(
                        f"stream items must be (H, W) or (B, H, W), "
                        f"got {x.shape}"
                    )
            except StopIteration:
                break
            except Exception:
                # a bad item — or a source iterator that raises — must not
                # swallow the previous item's computed result: deliver it,
                # then raise on the consumer's next pull
                if pending is not None:
                    yield pending
                    pending = None
                raise
            # ... only then hand result n to the consumer, overlapping its
            # wait with the transfer above; dispatch n+1 when control returns
            if pending is not None:
                yield pending
            pending = self._run(x, batched=batched, op=run_op)
        if pending is not None:
            yield pending

    def run_pipeline(self, stack: Any, stages: Sequence[str], *,
                     valid_hw: Optional[Any] = None, batched: bool = True,
                     on_stage: Optional[Callable[[str, float, float],
                                                 None]] = None):
        """Execute an ordered op chain device-resident, no host round trips.

        Each stage's ``chain_field`` output becomes the next stage's input
        stack. ``valid_hw`` ((B, 2) int32 of per-image (h, w)) optionally
        re-zeroes the pad region between stages so a bucket-padded batch
        stays bit-identical to issuing the stages as separate (cropped)
        submits — see :func:`_zero_pad_region`. ``on_stage(name, t0, t1)``
        fires after each stage's (synchronous) dispatch — the service uses
        it to emit per-stage ``pipeline.<op>`` spans and stage histograms.
        Returns the LAST stage's result.
        """
        from repro.engine import ops as engine_ops

        stages = engine_ops.validate_pipeline(stages)
        x = self._ingest(stack)
        if x.ndim != 3:
            raise ValueError(
                f"run_pipeline expects a (B, H, W) stack, got {x.shape}")
        hw = None if valid_hw is None else jnp.asarray(valid_hw, jnp.int32)
        result = None
        for i, name in enumerate(stages):
            t0 = time.monotonic()
            result = self._run(x, batched=batched, op=name)
            if i + 1 < len(stages):
                x = getattr(result, self._opspec(name).chain_field)
                if hw is not None:
                    x = _zero_pad_region(x, hw)
            if on_stage is not None:
                on_stage(name, t0, time.monotonic())
        return result

    def _run(self, imgs: Array, *, batched: bool, op: str):
        opspec = self._opspec(op)
        spec = self._resolve(op)
        # counted BEFORE the run so a raising backend still shows up in
        # call_count; the dispatch-cost histogram only sees successes
        registry.note_call(spec.name, op)
        t0 = time.monotonic()
        if self.mesh is not None:
            out = opspec.from_summary(
                self._run_meshed(spec, opspec, imgs), batched)
        else:
            out = opspec.from_summary(spec.run(imgs, self.config), batched)
        registry.note_dispatch(spec.name, time.monotonic() - t0, op)
        return out

    def _run_meshed(self, spec: registry.BackendSpec, opspec,
                    imgs: Array):
        """shard_map ``spec`` over the 1-D batch mesh.

        Ragged batches are padded with blank images (inert end to end for
        every op: zero pixels form no runs, no components, and denoise to
        zero) to a multiple of the mesh size and the pad is stripped before
        returning, so non-divisible batch sizes are invisible to callers.
        """
        from repro.sharding.ychg import pad_batch

        axis = self.config.mesh_axis
        x, b = pad_batch(imgs, self.mesh.shape[axis])
        cfg = self.config
        fields = opspec.fields

        def local(xs: Array):
            s = spec.run(xs, cfg)
            return tuple(getattr(s, f) for f in fields)

        pspec = P(axis)
        outs = shard_map(local, mesh=self.mesh, in_specs=pspec,
                         out_specs=pspec, check_rep=False)(x)
        return opspec.summary_type(*(o[:b] for o in outs))

    # ------------------------------------------------------------ tooling

    def lower(self, stack_shape: tuple[int, int, int],
              dtype: Any = jnp.uint8, op: Optional[str] = None) -> Any:
        """jit-lower this engine's batched path for an abstract input shape.

        Used by ``launch.dryrun`` to prove a (backend x shape) cell lowers
        and compiles without allocating the stack.
        """
        run_op = op or self.op
        opspec = self._opspec(run_op)
        spec = self._resolve(run_op)
        cfg = self.config

        def run(x: Array):
            return opspec.from_summary(spec.run(x, cfg), batched=True)

        return jax.jit(run).lower(jax.ShapeDtypeStruct(stack_shape, dtype))


class YCHGEngine(Engine):
    """Deprecated alias for :class:`Engine` pinned to ``op="ychg"``.

    Kept so the PR 2 migration table stays valid; emits a
    ``DeprecationWarning`` exactly like ``core.api.analyze_image`` does.
    CI's warning-strict jobs keep in-repo callers off this shim.
    """

    def __init__(self, config: YCHGConfig = YCHGConfig(), *,
                 mesh: Optional[Mesh] = None):
        warnings.warn(
            "YCHGEngine is deprecated; use repro.engine.Engine "
            "(op defaults to 'ychg')",
            DeprecationWarning,
            stacklevel=2,
        )
        super().__init__(config, op="ychg", mesh=mesh)
