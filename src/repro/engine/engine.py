"""`YCHGConfig` / `YCHGResult` / `YCHGEngine` — the unified entry point.

One engine instance owns one dispatch policy (backend selection, Pallas tile
sizes, streaming threshold, optional device mesh) and exposes three verbs:

  * ``analyze(img)``         — one (H, W) mask; internally a B=1 view of the
                               batched path, NOT a separate code path;
  * ``analyze_batch(stack)`` — a (B, H, W) stack in one device computation;
  * ``analyze_stream(it)``   — an iterable of masks/stacks, one
                               ``YCHGResult`` yielded per item.

Every verb returns a :class:`YCHGResult`: a ``jax.tree_util``-registered
pytree of device arrays (it can cross ``jit``/``shard_map`` boundaries and
never leaves the device implicitly). ``.to_host()`` produces the legacy
host dict that ``core.api.analyze_image`` used to return.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Iterable, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.ychg import YCHGSummary
from repro.engine import registry

Array = jax.Array

_FIELDS = ("runs", "cut_vertices", "transitions", "births", "deaths",
           "n_hyperedges", "n_transitions")


@dataclasses.dataclass(frozen=True)
class YCHGConfig:
    """Frozen, hashable engine construction knobs.

    backend            "auto" resolves per call from the registry (platform +
                       batch shape + mesh); or any registered name
                       ("jax", "fused", "pallas", "serial", "scalar").
    block_w, block_h   Pallas lane / streamed-row tile sizes.
    dtype              optional dtype name masks are cast to on ingest
                       (None = accept as-is; nonzero = foreground either way).
    mesh_axis          batch axis name used when a mesh is attached.
    interpret          Pallas interpret flag (None = auto: interpret off-TPU).
    stream_vmem_budget raw-tile bytes past which the fused/colscan kernels
                       switch to the H-streamed variant (VMEM threshold).
    """

    backend: str = "auto"
    block_w: int = 128
    block_h: int = 2048
    dtype: Optional[str] = None
    mesh_axis: str = "data"
    interpret: Optional[bool] = None
    stream_vmem_budget: int = 4 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class YCHGResult:
    """Device-resident batched output of the two-step algorithm.

    Arrays always carry the leading batch dim — a single image is a B=1
    view. Registered with ``jax.tree_util`` (the ``batched`` flag is static
    aux data), so results flow through ``jit``/``vmap``/``tree_map``
    untouched. Nothing is copied to the host until ``to_host()``.
    """

    runs: Array           # (B, W) int32  step-1 per-column run counts
    cut_vertices: Array   # (B, W) int32  2*runs
    transitions: Array    # (B, W) bool   step-2 change signal
    births: Array         # (B, W) int32
    deaths: Array         # (B, W) int32
    n_hyperedges: Array   # (B,)   int32  total births
    n_transitions: Array  # (B,)   int32  number of transition columns
    batched: bool = dataclasses.field(default=True, metadata=dict(static=True))

    @property
    def batch_size(self) -> int:
        return self.runs.shape[0]

    def block_until_ready(self) -> "YCHGResult":
        jax.block_until_ready(tuple(getattr(self, f) for f in _FIELDS))
        return self

    def to_summary(self) -> YCHGSummary:
        """``core.ychg.YCHGSummary`` view (squeezed to (W,)/() for B=1 input)."""
        if self.batched:
            return YCHGSummary(*(getattr(self, f) for f in _FIELDS))
        return YCHGSummary(*(getattr(self, f)[0] for f in _FIELDS))

    def to_host(self) -> Dict[str, np.ndarray]:
        """The legacy ``core.api.analyze_image`` dict: host NumPy values."""
        s = self.to_summary()
        return {f: np.asarray(getattr(s, f)) for f in _FIELDS}


jax.tree_util.register_dataclass(
    YCHGResult, data_fields=list(_FIELDS), meta_fields=["batched"]
)


def _from_summary(s: YCHGSummary, batched: bool) -> YCHGResult:
    # hot-path constructor: fills __dict__ directly instead of going through
    # the frozen-dataclass __init__ (8 object.__setattr__ calls) — this sits
    # inside the engine's <=5us/call dispatch-overhead budget
    r = object.__new__(YCHGResult)
    d = r.__dict__
    d["runs"] = s.runs
    d["cut_vertices"] = s.cut_vertices
    d["transitions"] = s.transitions
    d["births"] = s.births
    d["deaths"] = s.deaths
    d["n_hyperedges"] = s.n_hyperedges
    d["n_transitions"] = s.n_transitions
    d["batched"] = batched
    return r


class YCHGEngine:
    """The sole dispatch point for yCHG computations.

    ``YCHGEngine()`` (all defaults) resolves the best backend per call;
    attach a device mesh with ``with_mesh`` to batch-shard the fused kernel
    over it (padding to the mesh size and stripping the pad internally, so
    callers never see padded-length results).
    """

    def __init__(self, config: YCHGConfig = YCHGConfig(), *,
                 mesh: Optional[Mesh] = None):
        if mesh is not None and config.mesh_axis not in mesh.axis_names:
            raise ValueError(
                f"mesh has axes {mesh.axis_names}, config.mesh_axis="
                f"{config.mesh_axis!r}"
            )
        self.config = config
        self.mesh = mesh
        # platform is fixed per process; cache it out of the hot dispatch path
        self._platform = jax.default_backend()
        self._cast_dtype = None if config.dtype is None else jnp.dtype(config.dtype)
        # (registry generation, resolved spec) — revalidated against
        # registry.generation() so late register_backend() calls still apply
        self._spec_cache: Optional[tuple[int, registry.BackendSpec]] = None

    # ------------------------------------------------------------- plumbing

    def with_mesh(self, mesh: Optional[Mesh]) -> "YCHGEngine":
        """Same policy, batch-sharded over ``mesh`` (None detaches)."""
        return YCHGEngine(self.config, mesh=mesh)

    def with_config(self, **overrides: Any) -> "YCHGEngine":
        """New engine with ``dataclasses.replace``d config, same mesh."""
        return YCHGEngine(dataclasses.replace(self.config, **overrides),
                          mesh=self.mesh)

    def resolve_backend(self) -> str:
        """Name of the backend this engine dispatches to right now."""
        return self._resolve().name

    def _resolve(self) -> registry.BackendSpec:
        gen = registry.generation()
        cached = self._spec_cache
        if cached is not None and cached[0] == gen:
            return cached[1]
        spec = registry.resolve(
            self.config.backend,
            platform=self._platform,
            need_mesh=self.mesh is not None,
        )
        self._spec_cache = (gen, spec)
        return spec

    def _ingest(self, imgs: Any) -> Array:
        # device arrays pass through untouched: no host round-trip, and no
        # jnp.asarray no-op either (it costs ~17us/call of pure dispatch —
        # the engine's <=5us/call overhead budget lives or dies here)
        x = imgs if isinstance(imgs, jax.Array) else jnp.asarray(imgs)
        if self._cast_dtype is not None and x.dtype != self._cast_dtype:
            x = x.astype(self._cast_dtype)
        return x

    # ------------------------------------------------------------- dispatch

    def analyze(self, img: Any) -> YCHGResult:
        """One (H, W) mask -> B=1 ``YCHGResult`` (never copies device->host)."""
        x = self._ingest(img)
        if x.ndim != 2:
            raise ValueError(f"analyze expects an (H, W) mask, got {x.shape}; "
                             "use analyze_batch for stacks")
        return self._run(x[None], batched=False)

    def analyze_batch(self, stack: Any) -> YCHGResult:
        """A (B, H, W) stack in one device computation -> ``YCHGResult``."""
        x = self._ingest(stack)
        if x.ndim != 3:
            raise ValueError(f"analyze_batch expects a (B, H, W) stack, "
                             f"got {x.shape}")
        return self._run(x, batched=True)

    def analyze_stream(self, items: Iterable[Any]) -> Iterator[YCHGResult]:
        """Lazily map ``analyze``/``analyze_batch`` over an iterable,
        double-buffering ingest against device compute.

        Each item may be an (H, W) mask or a (B, H, W) stack; one
        ``YCHGResult`` is yielded per item, strictly in order. The stream
        runs one item ahead of the yield point: item n+1 is pulled from the
        iterator and its host->device transfer started *before* result n is
        yielded, so while the consumer handles result n (whose computation
        was dispatched asynchronously) the next item's host work and
        transfer are already in flight. Compose with
        ``data.pipeline.Prefetcher`` for background host I/O.
        """
        it = iter(items)
        pending: Optional[YCHGResult] = None
        while True:
            # pull and ingest (start the transfer of) item n+1 first ...
            try:
                item = next(it)
                x = self._ingest(item)
                if x.ndim == 2:
                    x, batched = x[None], False
                elif x.ndim == 3:
                    batched = True
                else:
                    raise ValueError(
                        f"stream items must be (H, W) or (B, H, W), "
                        f"got {x.shape}"
                    )
            except StopIteration:
                break
            except Exception:
                # a bad item — or a source iterator that raises — must not
                # swallow the previous item's computed result: deliver it,
                # then raise on the consumer's next pull
                if pending is not None:
                    yield pending
                    pending = None
                raise
            # ... only then hand result n to the consumer, overlapping its
            # wait with the transfer above; dispatch n+1 when control returns
            if pending is not None:
                yield pending
            pending = self._run(x, batched=batched)
        if pending is not None:
            yield pending

    def _run(self, imgs: Array, *, batched: bool) -> YCHGResult:
        spec = self._resolve()
        # counted BEFORE the run so a raising backend still shows up in
        # call_count; the dispatch-cost histogram only sees successes
        registry.note_call(spec.name)
        t0 = time.monotonic()
        if self.mesh is not None:
            out = _from_summary(self._run_meshed(spec, imgs), batched)
        else:
            out = _from_summary(spec.run(imgs, self.config), batched)
        registry.note_dispatch(spec.name, time.monotonic() - t0)
        return out

    def _run_meshed(self, spec: registry.BackendSpec, imgs: Array) -> YCHGSummary:
        """shard_map ``spec`` over the 1-D batch mesh.

        Ragged batches are padded with blank images (zero runs, zero
        hyperedges — inert end to end) to a multiple of the mesh size and
        the pad is stripped before returning, so non-divisible batch sizes
        are invisible to callers.
        """
        from repro.sharding.ychg import pad_batch

        axis = self.config.mesh_axis
        x, b = pad_batch(imgs, self.mesh.shape[axis])
        cfg = self.config

        def local(xs: Array):
            s = spec.run(xs, cfg)
            return tuple(getattr(s, f) for f in _FIELDS)

        pspec = P(axis)
        outs = shard_map(local, mesh=self.mesh, in_specs=pspec,
                         out_specs=pspec, check_rep=False)(x)
        return YCHGSummary(*(o[:b] for o in outs))

    # ------------------------------------------------------------ tooling

    def lower(self, stack_shape: tuple[int, int, int],
              dtype: Any = jnp.uint8) -> Any:
        """jit-lower this engine's batched path for an abstract input shape.

        Used by ``launch.dryrun`` to prove a (backend x shape) cell lowers
        and compiles without allocating the stack.
        """
        spec = self._resolve()
        cfg = self.config

        def run(x: Array) -> YCHGResult:
            return _from_summary(spec.run(x, cfg), batched=True)

        return jax.jit(run).lower(jax.ShapeDtypeStruct(stack_shape, dtype))
