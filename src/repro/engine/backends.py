"""The in-repo op backends, self-registered on import.

yCHG first — each ``run(imgs, config)`` maps a (B, H, W) stack to a batched
``core.ychg.YCHGSummary`` bit-identical to ``core.ychg.analyze`` — then the
other platform ops (``ccl``, ``denoise``), each held to its own in-repo
reference. The parity suites in ``tests/test_engine.py`` and
``tests/test_ops.py`` enforce this for every entry in the registry, so a
new backend is held to the same bar just by registering.

Capability summary for ``op="ychg"`` (drives ``backend="auto"``):

  name     batch  mesh   runs on        auto-picked on
  jax      yes    no     cpu/gpu/tpu    cpu, gpu (jit'd jnp — fastest there)
  fused    yes    yes    tpu, cpu*      tpu (single-launch Pallas pipeline)
  pallas   no     no     tpu, cpu*      — (two-pass kernels; explicit only)
  serial   no     no     cpu            — (paper's NumPy CPU baseline)
  scalar   no     no     cpu            — (per-pixel loops; tiny images only)

``ccl`` and ``denoise`` each register ``jax`` (the jnp reference itself)
and ``pallas`` (whole-image VMEM kernels) with the same priority shape:
jnp on cpu/gpu, the kernel on tpu.

  * cpu = Pallas interpret mode (exact, Python-evaluated; correctness, not
    speed). Device backends never copy device arrays through the host.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

import jax.numpy as jnp

from repro.core import serial, ychg
from repro.core.ychg import YCHGSummary
from repro.engine.registry import BackendSpec, register_backend
from repro.kernels import ccl as kccl
from repro.kernels import denoise as kdenoise
from repro.kernels import ops as kops

if TYPE_CHECKING:  # pragma: no cover
    from repro.engine.engine import YCHGConfig


def _stack_host(dicts: list[dict]) -> YCHGSummary:
    """Per-image host result dicts -> one batched device YCHGSummary."""
    return YCHGSummary(
        runs=jnp.asarray(np.stack([d["runs"] for d in dicts])),
        cut_vertices=jnp.asarray(np.stack([d["cut_vertices"] for d in dicts])),
        transitions=jnp.asarray(np.stack([d["transitions"] for d in dicts])),
        births=jnp.asarray(np.stack([d["births"] for d in dicts])),
        deaths=jnp.asarray(np.stack([d["deaths"] for d in dicts])),
        n_hyperedges=jnp.asarray(np.stack([d["n_hyperedges"] for d in dicts])),
        n_transitions=jnp.asarray(np.stack([d["n_transitions"] for d in dicts])),
    )


def _run_jax(imgs, config: "YCHGConfig") -> YCHGSummary:
    return ychg.analyze_jit(imgs)


def _run_fused(imgs, config: "YCHGConfig") -> YCHGSummary:
    return kops.analyze_fused(
        imgs,
        block_w=config.block_w,
        block_h=config.block_h,
        interpret=config.interpret,
        vmem_budget=config.stream_vmem_budget,
    )


def _run_pallas(imgs, config: "YCHGConfig") -> YCHGSummary:
    """Two-pass kernels are single-image; batch = one two-launch pass each."""
    if imgs.shape[0] == 0:
        return ychg.analyze(imgs)
    outs = [
        kops.analyze(
            imgs[i],
            block_w=config.block_w,
            block_h=config.block_h,
            interpret=config.interpret,
            vmem_budget=config.stream_vmem_budget,
        )
        for i in range(imgs.shape[0])
    ]
    return YCHGSummary(**{k: jnp.stack([o[k] for o in outs]) for k in outs[0]})


def _run_serial(imgs, config: "YCHGConfig") -> YCHGSummary:
    if imgs.shape[0] == 0:
        return ychg.analyze(imgs)
    host = np.asarray(imgs)
    return _stack_host([serial.analyze_numpy(host[i]) for i in range(len(host))])


def _run_scalar(imgs, config: "YCHGConfig") -> YCHGSummary:
    if imgs.shape[0] == 0:
        return ychg.analyze(imgs)
    host = np.asarray(imgs)
    return _stack_host([serial.analyze_scalar(host[i]) for i in range(len(host))])


register_backend(BackendSpec(
    name="jax", run=_run_jax, supports_batch=True, supports_mesh=False,
    device_kinds=("cpu", "gpu", "tpu"),
    priority={"cpu": 100, "gpu": 100, "tpu": 50},
))
register_backend(BackendSpec(
    name="fused", run=_run_fused, supports_batch=True, supports_mesh=True,
    device_kinds=("tpu", "cpu", "gpu"),
    priority={"tpu": 100, "cpu": 40, "gpu": 40},
))
register_backend(BackendSpec(
    name="pallas", run=_run_pallas, supports_batch=False, supports_mesh=False,
    device_kinds=("tpu", "cpu", "gpu"),
    priority={"tpu": 60, "cpu": 20, "gpu": 20},
))
register_backend(BackendSpec(
    name="serial", run=_run_serial, supports_batch=False, supports_mesh=False,
    device_kinds=("cpu",),
    priority={"cpu": 10},
))
register_backend(BackendSpec(
    name="scalar", run=_run_scalar, supports_batch=False, supports_mesh=False,
    device_kinds=("cpu",),
    priority={"cpu": 1},
))


# ----------------------------------------------------------------- ccl

def _run_ccl_jax(imgs, config: "YCHGConfig") -> kccl.CCLSummary:
    return kccl.labels(imgs)


def _run_ccl_pallas(imgs, config: "YCHGConfig") -> kccl.CCLSummary:
    return kccl.labels_pallas(imgs, interpret=config.interpret)


register_backend(BackendSpec(
    op="ccl", name="jax", run=_run_ccl_jax,
    supports_batch=True, supports_mesh=True,
    device_kinds=("cpu", "gpu", "tpu"),
    priority={"cpu": 100, "gpu": 100, "tpu": 50},
))
register_backend(BackendSpec(
    op="ccl", name="pallas", run=_run_ccl_pallas,
    supports_batch=True, supports_mesh=True,
    device_kinds=("tpu", "cpu", "gpu"),
    priority={"tpu": 100, "cpu": 40, "gpu": 40},
))


# ------------------------------------------------------------- denoise

def _run_denoise_jax(imgs, config: "YCHGConfig") -> kdenoise.DenoiseSummary:
    return kdenoise.denoise(imgs)


def _run_denoise_pallas(imgs, config: "YCHGConfig") -> kdenoise.DenoiseSummary:
    return kdenoise.denoise_pallas(imgs, interpret=config.interpret)


register_backend(BackendSpec(
    op="denoise", name="jax", run=_run_denoise_jax,
    supports_batch=True, supports_mesh=True,
    device_kinds=("cpu", "gpu", "tpu"),
    priority={"cpu": 100, "gpu": 100, "tpu": 50},
))
register_backend(BackendSpec(
    op="denoise", name="pallas", run=_run_denoise_pallas,
    supports_batch=True, supports_mesh=True,
    device_kinds=("tpu", "cpu", "gpu"),
    priority={"tpu": 100, "cpu": 40, "gpu": 40},
))
