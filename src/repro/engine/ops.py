"""Operator registry: what the engine can compute, beyond which backend.

The backend registry (``repro.engine.registry``) answers "which
implementation of op X runs here"; this module answers "what IS op X" —
its result pytree, its in-repo reference (the parity bar every backend is
held to), and how it composes into device-resident pipelines:

  * ``fields``      — the result's array fields, all leading with the batch
                      dim (so the generic shard_map mesh path in the engine
                      works for every op);
  * ``result_type`` / ``from_summary`` — the frozen pytree wrapper;
  * ``reference``   — jnp reference over a (B, H, W) stack; backends must
                      be bit-identical to it (tests enforce this);
  * ``chain_field`` — the result field fed to the next stage of a pipeline
                      spec (None = terminal op: it cannot appear mid-chain).

``docs/ops.md`` walks through adding a new op end to end.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import ychg as _ychg
from repro.engine.registry import UnknownOpError
from repro.kernels import ccl as _ccl
from repro.kernels import denoise as _denoise

Array = jax.Array

__all__ = [
    "CCLResult",
    "DenoiseResult",
    "OpSpec",
    "get_op",
    "op_names",
    "register_op",
    "pipeline_op_key",
    "split_pipeline_key",
]

# Separator for pipeline cache/bucket keys ("denoise+ychg"); op names must
# therefore never contain it (register_op validates).
PIPELINE_SEP = "+"


@dataclasses.dataclass(frozen=True)
class CCLResult:
    """Device-resident batched connected-components labeling output."""

    labels: Array        # (B, H, W) int32 canonical labels, 0 = background
    n_components: Array  # (B,) int32
    batched: bool = dataclasses.field(default=True,
                                      metadata=dict(static=True))

    @property
    def batch_size(self) -> int:
        return self.labels.shape[0]

    def block_until_ready(self) -> "CCLResult":
        jax.block_until_ready((self.labels, self.n_components))
        return self

    def to_summary(self) -> _ccl.CCLSummary:
        if self.batched:
            return _ccl.CCLSummary(self.labels, self.n_components)
        return _ccl.CCLSummary(self.labels[0], self.n_components[0])

    def to_host(self) -> Dict[str, np.ndarray]:
        s = self.to_summary()
        return {f: np.asarray(getattr(s, f)) for f in _ccl.CCL_FIELDS}


@dataclasses.dataclass(frozen=True)
class DenoiseResult:
    """Device-resident batched P-HGRMS denoise output."""

    image: Array  # (B, H, W) float32
    batched: bool = dataclasses.field(default=True,
                                      metadata=dict(static=True))

    @property
    def batch_size(self) -> int:
        return self.image.shape[0]

    def block_until_ready(self) -> "DenoiseResult":
        jax.block_until_ready(self.image)
        return self

    def to_summary(self) -> _denoise.DenoiseSummary:
        if self.batched:
            return _denoise.DenoiseSummary(self.image)
        return _denoise.DenoiseSummary(self.image[0])

    def to_host(self) -> Dict[str, np.ndarray]:
        s = self.to_summary()
        return {f: np.asarray(getattr(s, f))
                for f in _denoise.DENOISE_FIELDS}


jax.tree_util.register_dataclass(
    CCLResult, data_fields=["labels", "n_components"], meta_fields=["batched"]
)
jax.tree_util.register_dataclass(
    DenoiseResult, data_fields=["image"], meta_fields=["batched"]
)


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One operator the engine can dispatch."""

    name: str
    fields: Tuple[str, ...]
    result_type: type
    summary_type: type            # field-ordered summary (mesh repack)
    from_summary: Callable        # (summary, batched: bool) -> result
    reference: Callable           # (B, H, W) stack -> summary (parity bar)
    chain_field: Optional[str] = None  # pipeline output field; None = terminal


_OPS: Dict[str, OpSpec] = {}


def register_op(spec: OpSpec) -> OpSpec:
    if PIPELINE_SEP in spec.name:
        raise ValueError(
            f"op name {spec.name!r} may not contain {PIPELINE_SEP!r} "
            "(reserved for pipeline keys)"
        )
    _OPS[spec.name] = spec
    return spec


def op_names() -> Tuple[str, ...]:
    return tuple(sorted(_OPS))


def get_op(name: str) -> OpSpec:
    try:
        return _OPS[name]
    except KeyError:
        raise UnknownOpError(
            f"unknown op {name!r}; registered ops: {op_names()}"
        ) from None


def pipeline_op_key(stages: Tuple[str, ...]) -> str:
    """Ordered stage names -> the op-qualified key used by cache/buckets."""
    return PIPELINE_SEP.join(stages)


def split_pipeline_key(op_key: str) -> Tuple[str, ...]:
    return tuple(op_key.split(PIPELINE_SEP))


def validate_pipeline(stages) -> Tuple[str, ...]:
    """Check an ordered pipeline spec: known ops, chainable interiors."""
    stages = tuple(stages)
    if not stages:
        raise ValueError("pipeline spec needs at least one op stage")
    for s in stages:
        get_op(s)  # raises UnknownOpError with the registered list
    for s in stages[:-1]:
        if get_op(s).chain_field is None:
            raise ValueError(
                f"op {s!r} is terminal (no chain_field) and cannot feed a "
                f"later pipeline stage"
            )
    return stages


# --------------------------------------------------------------- built-ins

def _ychg_from_summary(s, batched: bool):
    from repro.engine.engine import _from_summary

    return _from_summary(s, batched)


def _ychg_result_type():
    from repro.engine.engine import YCHGResult

    return YCHGResult


register_op(OpSpec(
    name="ychg",
    fields=("runs", "cut_vertices", "transitions", "births", "deaths",
            "n_hyperedges", "n_transitions"),
    summary_type=_ychg.YCHGSummary,
    # resolved lazily below to avoid a circular import at module load
    result_type=object,
    from_summary=_ychg_from_summary,
    reference=_ychg.analyze,
    chain_field=None,   # (B, W) outputs: not an image, cannot feed a stage
))

register_op(OpSpec(
    name="ccl",
    fields=("labels", "n_components"),
    summary_type=_ccl.CCLSummary,
    result_type=CCLResult,
    from_summary=lambda s, batched: CCLResult(
        labels=s.labels, n_components=s.n_components, batched=batched),
    reference=_ccl.labels,
    chain_field="labels",   # nonzero labels = foreground downstream
))

register_op(OpSpec(
    name="denoise",
    fields=("image",),
    summary_type=_denoise.DenoiseSummary,
    result_type=DenoiseResult,
    from_summary=lambda s, batched: DenoiseResult(image=s.image,
                                                  batched=batched),
    reference=_denoise.denoise,
    chain_field="image",
))


def _finalize_ychg_result_type() -> None:
    """Called by ``repro.engine`` once ``engine.engine`` is importable."""
    spec = _OPS["ychg"]
    if spec.result_type is object:
        _OPS["ychg"] = dataclasses.replace(
            spec, result_type=_ychg_result_type())
