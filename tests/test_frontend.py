"""`repro.frontend` suite: wire codecs + the HTTP/RPC edge, over loopback.

Tier-1 socket policy (tests/README.md §Frontend tests): **loopback only,
ephemeral ports** — every server binds 127.0.0.1 port 0 (the OS picks a
free port), nothing listens on external interfaces, no fixed port can
collide across parallel CI jobs. No wall-clock assertions: overload cases
are pinned by holding admission slots with requests parked in a long
delay window (or behind a gated engine), never by racing a timer.

The bar mirrors the service suite's: every result decoded off the wire is
**bit-identical** (values, dtypes, shapes) to what in-process
``YCHGService.submit`` returns for the same mask.
"""

import asyncio
import json

import numpy as np
import pytest

from repro.engine import Engine
from repro.frontend import (
    AsyncRPCClient,
    FrontendError,
    FrontendOverloaded,
    ServerThread,
    YCHGClient,
    protocol,
)
from repro.service import ServiceConfig, YCHGService

TIMEOUT = 300.0


def _mask(shape, seed=0, density=0.5):
    rng = np.random.default_rng(seed)
    return (rng.random(shape) < density).astype(np.uint8)


def _assert_host_equal(got, want):
    """Bit-identical host dicts: values, dtypes, AND shapes per field."""
    assert set(got) == set(want)
    for field in want:
        a, b = np.asarray(want[field]), got[field]
        assert a.shape == b.shape, field
        assert a.dtype == b.dtype, field
        assert np.array_equal(a, b), field


# ------------------------------------------------------------ wire codecs


@pytest.mark.parametrize("arr", [
    np.zeros((), np.int32),                       # 0-d result scalar
    np.arange(7, dtype=np.int32),
    (np.arange(12).reshape(3, 4) % 2).astype(bool),
    np.arange(6, dtype=np.uint8).reshape(2, 3),
    np.asarray(np.arange(8, dtype=np.int64).reshape(2, 4).T),  # non-contig
])
def test_array_codec_roundtrip_is_bit_identical(arr):
    through_json = json.loads(json.dumps(protocol.encode_array(arr)))
    out = protocol.decode_array(through_json)
    assert out.shape == arr.shape and out.dtype == arr.dtype
    assert np.array_equal(out, arr)


def test_array_codec_rejects_malformed_payloads():
    good = protocol.encode_array(np.arange(4, dtype=np.int32))
    bad_len = dict(good, shape=[5])               # bytes don't cover shape
    with pytest.raises(protocol.ProtocolError, match="bytes"):
        protocol.decode_array(bad_len)
    with pytest.raises(protocol.ProtocolError, match="malformed"):
        protocol.decode_array(dict(good, dtype="not-a-dtype"))
    with pytest.raises(protocol.ProtocolError, match="malformed"):
        protocol.decode_array({"shape": [4], "dtype": "int32"})  # no b64
    with pytest.raises(protocol.ProtocolError, match="malformed"):
        protocol.decode_array(dict(good, b64="!!not base64!!"))


def test_array_codec_rejects_non_positive_dims():
    """Pre-fix regression (PR 6): a shape like [-1, -8] has a positive
    PRODUCT, so the byte-length check passed and the bare ``reshape``
    ValueError escaped the ProtocolError contract; a zero dim with an
    empty payload sailed through entirely and decoded to an empty array
    nothing downstream expects. Non-positive dims are malformed input and
    must fail as ProtocolError."""
    good = protocol.encode_array(np.arange(8, dtype=np.uint8).reshape(1, 8))
    # product (-1)*(-8) = 8 = the payload's byte count: only the sign
    # check can reject this one
    with pytest.raises(protocol.ProtocolError, match="non-positive"):
        protocol.decode_array(dict(good, shape=[-1, -8]))
    with pytest.raises(protocol.ProtocolError, match="non-positive"):
        protocol.decode_array(dict(good, shape=[0], b64=""))
    with pytest.raises(protocol.ProtocolError, match="non-positive"):
        protocol.decode_array(dict(good, shape=[8, 0], b64=""))


def test_result_codec_roundtrip_matches_to_host():
    result = Engine().analyze(_mask((9, 13), seed=3))
    want = result.to_host()
    got = protocol.decode_result(
        json.loads(json.dumps(protocol.encode_result(result))))
    _assert_host_equal(got, want)


def test_frame_roundtrip_eof_and_bounds():
    obj = {"op": "analyze", "id": 3,
           "mask": protocol.encode_array(np.zeros((2, 2), np.uint8))}

    async def read_from(data, eof=True):
        reader = asyncio.StreamReader()
        reader.feed_data(data)
        if eof:
            reader.feed_eof()
        return await protocol.read_frame(reader)

    frame = protocol.pack_frame(obj)
    assert asyncio.run(read_from(frame)) == json.loads(json.dumps(obj))
    # clean EOF between frames -> None, EOF inside a frame -> ProtocolError
    assert asyncio.run(read_from(b"")) is None
    with pytest.raises(protocol.ProtocolError, match="EOF inside"):
        asyncio.run(read_from(frame[: len(frame) - 2]))
    # an absurd frame header is rejected before any allocation
    huge = (protocol.MAX_FRAME_BYTES + 1).to_bytes(4, "big")
    with pytest.raises(protocol.ProtocolError, match="MAX_FRAME_BYTES"):
        asyncio.run(read_from(huge + b"x"))


# --------------------------------------------------------- HTTP transport


def test_http_analyze_bit_identical_to_in_process_submit():
    mask = _mask((24, 30), seed=10)
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(32,), max_batch=2, max_delay_ms=1.0))
    with svc, ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        got = client.analyze(mask)
        want = svc.submit(mask).result(timeout=TIMEOUT).to_host()
        _assert_host_equal(got, want)
        health = client.health()
        assert health["status"] == "ok"
        assert health["backend"] == svc.engine.resolve_backend()


def test_http_batch_streams_every_result_with_ids():
    masks = [_mask((10 + i, 20), seed=20 + i) for i in range(6)]
    ids = [f"req-{i}" for i in range(6)]
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(32,), max_batch=4, max_delay_ms=1.0))
    with svc, ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        items = list(client.analyze_batch(masks, ids=ids))
        assert sorted(it.id for it in items) == sorted(ids)
        assert all(it.ok for it in items)
        by_id = {it.id: it for it in items}
        for rid, mask in zip(ids, masks):
            want = svc.submit(mask).result(timeout=TIMEOUT).to_host()
            _assert_host_equal(by_id[rid].result, want)


def test_http_overload_maps_shed_to_429_with_retry_after():
    """One admission slot, held by an in-process submit parked in a long
    delay window: the wire request must shed as HTTP 429 carrying a
    positive Retry-After, and /metrics must show the (per-bucket) shed."""
    masks = [_mask((16, 16), seed=s) for s in (40, 41)]
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(16,), max_batch=8, max_delay_ms=10_000.0,
        max_queue_depth=1, overload_policy="shed"))
    try:
        with ServerThread(svc) as srv, \
                YCHGClient("127.0.0.1", srv.port) as client:
            holder = svc.submit(masks[0])       # occupies the only slot
            with pytest.raises(FrontendOverloaded) as exc_info:
                client.analyze(masks[1])
            assert exc_info.value.retry_after_s > 0
            assert exc_info.value.status == 429
            text = client.metrics_text()
            assert "ychg_shed_total 1" in text
            assert ('ychg_shed_bucket_total'
                    '{op="ychg",side="16",dtype="uint8"} 1') in text
            assert "ychg_backend_info" in text
    finally:
        svc.close()                             # drains the admitted holder
    assert holder.result(timeout=TIMEOUT).batch_size == 1


# the canonical gated-engine test double (parks every dispatch until
# released) lives next to the service suite; same-directory imports are
# the established pattern here (see ychg_invariants)
from test_service import _GatedEngine  # noqa: E402


def test_http_batch_streams_shed_errors_alongside_cache_hits():
    """Partial overload inside one streamed batch: the shed mask arrives
    as a per-line 429 error while a cache-served mask still streams its
    result — one bad request never poisons the stream. Deterministic: the
    only admission slot is held behind a gated engine, the served mask is
    a prior cache entry (hits consume no slot), the excess mask sheds."""
    engine = _GatedEngine()
    cached_mask = _mask((16, 16), seed=50)
    holder_mask = _mask((16, 16), seed=51)
    shed_mask = _mask((16, 16), seed=52)
    svc = YCHGService(engine, ServiceConfig(
        bucket_sides=(16,), max_batch=1, max_delay_ms=1.0,
        max_queue_depth=1, overload_policy="shed"))
    try:
        engine.resume.set()                     # prime the cache ungated
        svc.analyze(cached_mask, timeout=TIMEOUT)
        engine.resume.clear()
        engine.entered.clear()
        holder = svc.submit(holder_mask)        # parks in the gated engine
        assert engine.entered.wait(TIMEOUT)
        with ServerThread(svc) as srv, \
                YCHGClient("127.0.0.1", srv.port) as client:
            items = {it.id: it for it in client.analyze_batch(
                [cached_mask, shed_mask], ids=["hit", "excess"])}
        assert items["hit"].ok
        _assert_host_equal(
            items["hit"].result,
            svc.submit(cached_mask).result(timeout=TIMEOUT).to_host())
        assert not items["excess"].ok
        assert items["excess"].status == 429
        assert items["excess"].retry_after_s is not None
    finally:
        engine.resume.set()
        svc.close()
    assert holder.result(timeout=TIMEOUT).batch_size == 1


def test_http_bad_requests_are_400_not_disconnects():
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(16,), max_batch=1, max_delay_ms=1.0))
    with svc, ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        resp = client._request("POST", "/v1/analyze", b"this is not json")
        assert resp.status == 400
        resp.read()
        resp = client._request("GET", "/no/such/route")
        assert resp.status == 404
        resp.read()
        # a mask whose payload doesn't cover its shape fails loudly
        bad = protocol.encode_array(_mask((8, 8)))
        bad["shape"] = [8, 9]
        resp = client._request("POST", "/v1/analyze",
                               json.dumps({"mask": bad}).encode())
        assert resp.status == 400
        resp.read()
        # and the connection is still serviceable afterwards
        assert client.health()["status"] == "ok"


def test_http_malformed_or_oversized_content_length_is_rejected():
    """A bogus Content-Length answers 400, an absurd one 413 (the RPC
    frame bound applied to HTTP bodies) — never a dropped connection or
    an attempted multi-GB buffer."""
    import socket

    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(16,), max_batch=1, max_delay_ms=1.0))
    with svc, ServerThread(svc) as srv:
        def raw(head: bytes) -> bytes:
            with socket.create_connection(("127.0.0.1", srv.port),
                                          timeout=30) as s:
                s.sendall(head)
                return s.recv(65536)

        resp = raw(b"POST /v1/analyze HTTP/1.1\r\n"
                   b"Content-Length: abc\r\n\r\n")
        assert resp.split(b"\r\n", 1)[0] == b"HTTP/1.1 400 Bad Request"
        resp = raw(b"POST /v1/analyze HTTP/1.1\r\n"
                   b"Content-Length: 99999999999\r\n\r\n")
        assert resp.split(b"\r\n", 1)[0] == b"HTTP/1.1 413 Payload Too Large"


def test_http_failed_submit_is_500_not_a_dropped_connection():
    """A submit that raises anything besides ServiceOverloaded (here: the
    service was closed under the server) must surface as an HTTP 500 —
    pre-fix the exception escaped the handler and the socket just died,
    which the client's transparent retry then turned into a SECOND
    doomed submit."""
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(16,), max_batch=1, max_delay_ms=1.0))
    with ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        svc.close()
        body = json.dumps(
            {"mask": protocol.encode_array(_mask((8, 8)))}).encode()
        resp = client._request("POST", "/v1/analyze", body)
        assert resp.status == 500
        assert "closed" in resp.read().decode()


def test_http_batch_negative_dims_are_per_line_400_not_500():
    """The wire twin of the non-positive-dims codec fix: pre-fix the
    escaped reshape ValueError hit the batch path's catch-all and the
    client saw a per-line 500 for what is a malformed request. It must be
    a per-line 400, with the rest of the stream unharmed."""
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(16,), max_batch=1, max_delay_ms=1.0))
    with svc, ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        good = protocol.encode_array(_mask((8, 8), seed=60))
        # (-8)*(-8) = 64 = the payload's byte count: passes the length
        # check, only the sign check can reject it
        bad = dict(good, shape=[-8, -8])
        body = json.dumps({"masks": [dict(bad, id="bad"),
                                     dict(good, id="ok")]}).encode()
        resp = client._request("POST", "/v1/analyze_batch", body)
        assert resp.status == 200
        items = {}
        while True:
            line = resp.readline()
            if not line:
                break
            obj = json.loads(line)
            items[obj["id"]] = obj
        assert "result" in items["ok"]
        assert "result" not in items["bad"]
        assert items["bad"]["status"] == 400


def test_client_survives_malformed_retry_after_header():
    """Pre-fix regression (PR 6): the 429 path did
    ``float(resp.headers.get("Retry-After", 1.0))``, so a header a proxy
    mangled (or emptied) raised ValueError out of ``YCHGClient.analyze``
    instead of the typed FrontendOverloaded. A canned-response socket
    stands in for the mangling middlebox; the client must degrade to the
    default backoff, not blow up."""
    import socket
    import threading

    canned = (b"HTTP/1.1 429 Too Many Requests\r\n"
              b"Content-Type: application/json\r\n"
              b"Retry-After: soon\r\n"
              b"Content-Length: 22\r\n"
              b"Connection: close\r\n\r\n"
              b'{"error":"overloaded"}')
    srv = socket.create_server(("127.0.0.1", 0))
    port = srv.getsockname()[1]

    def serve_one():
        conn, _ = srv.accept()
        with conn:
            conn.recv(65536)
            conn.sendall(canned)

    t = threading.Thread(target=serve_one, daemon=True)
    t.start()
    try:
        with YCHGClient("127.0.0.1", port, timeout=30.0) as client:
            with pytest.raises(FrontendOverloaded) as exc_info:
                client.analyze(_mask((8, 8)))
        assert exc_info.value.retry_after_s == 1.0
        assert exc_info.value.status == 429
    finally:
        srv.close()
        t.join(5)


# -------------------------------------------------- traffic shaping (wire)


def test_http_classed_request_bit_identical_and_shares_the_cache():
    """docs/traffic.md bit-identity invariant: class/deadline/tenant
    shape *when* a request runs, never *what* it computes — they stay
    out of the cache key, so a fully-decorated wire request is served
    from the entry an undecorated in-process submit populated, and the
    result is bit-identical."""
    mask = _mask((24, 30), seed=70)
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(32,), max_batch=2, max_delay_ms=1.0,
        tenant_rate=1000.0))
    with svc, ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        want = svc.submit(mask).result(timeout=TIMEOUT).to_host()
        before = svc.metrics().cache_hits
        got = client.analyze(mask, klass="interactive",
                             deadline_ms=60_000.0, tenant="acme")
        _assert_host_equal(got, want)
        assert svc.metrics().cache_hits == before + 1


def test_traffic_fields_absent_means_absent_bytes():
    """A request without traffic kwargs sends NO new headers and NO new
    RPC frame keys — the pre-traffic-class wire format, unchanged."""
    from repro.frontend.client import _put_traffic_fields, _traffic_headers

    assert _traffic_headers(None, None, None) == {}
    frame = {"op": "analyze", "id": 1}
    _put_traffic_fields(frame, None, None, None)
    assert frame == {"op": "analyze", "id": 1}
    assert _traffic_headers("batch", 250, "acme") == {
        protocol.TRAFFIC_CLASS_HEADER: "batch",
        protocol.TRAFFIC_DEADLINE_HEADER: "250.0",
        protocol.TRAFFIC_TENANT_HEADER: "acme",
    }


def test_http_malformed_traffic_headers_are_400_not_500():
    """An unparseable deadline header and an unknown class are client
    errors (400), never a 500 or a dropped connection."""
    import http.client

    mask = _mask((16, 16), seed=71)
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(16,), max_batch=2, max_delay_ms=1.0))
    with svc, ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        # the client refuses to build a non-numeric deadline, so craft
        # the malformed header with a raw connection
        conn = http.client.HTTPConnection("127.0.0.1", srv.port)
        try:
            conn.request(
                "POST", "/v1/analyze",
                json.dumps({"mask": protocol.encode_array(mask)}),
                {protocol.TRAFFIC_DEADLINE_HEADER: "soon",
                 "Content-Type": "application/json"})
            resp = conn.getresponse()
            body = json.loads(resp.read())
            assert resp.status == 400
            assert "deadline" in body["error"]
        finally:
            conn.close()
        with pytest.raises(FrontendError) as exc_info:
            client.analyze(mask, klass="vip")
        assert exc_info.value.status == 400
        assert "unknown traffic class" in str(exc_info.value)


def test_http_deadline_and_quota_sheds_are_typed_429s():
    """``deadline_ms=0`` is dead on arrival -> 429 ``kind="deadline"``
    at the clamp-floor Retry-After (cold estimator: zero lateness); an
    exhausted one-token tenant bucket -> 429 ``kind="quota"`` at the
    30s clamp (starvation refill rate), while another tenant admits
    freely — all deterministic, and all visible on /metrics."""
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(16,), max_batch=2, max_delay_ms=1.0,
        tenant_rate=0.001, tenant_burst=1))
    with svc, ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        with pytest.raises(FrontendOverloaded) as dead:
            client.analyze(_mask((16, 16), seed=72), deadline_ms=0.0)
        assert dead.value.kind == "deadline"
        assert dead.value.status == 429
        assert dead.value.retry_after_s == pytest.approx(0.05)
        client.analyze(_mask((16, 16), seed=73), tenant="acme")  # the burst
        with pytest.raises(FrontendOverloaded) as quota:
            client.analyze(_mask((16, 16), seed=74), tenant="acme")
        assert quota.value.kind == "quota"
        assert quota.value.retry_after_s == pytest.approx(30.0)
        client.analyze(_mask((16, 16), seed=75), tenant="beta")  # isolated
        text = client.metrics_text()
        assert "ychg_shed_deadline_total 1" in text
        assert "ychg_shed_quota_total 1" in text
        assert 'ychg_shed_tenant_total{tenant="acme"} 1' in text
        assert 'tenant="beta"' not in text


def test_rpc_traffic_fields_bit_identical_and_typed_deadline_error():
    """The RPC twin of the traffic contract: frame fields select the
    policy (a dead deadline sheds with ``kind="deadline"``) without
    touching the result bytes of an admitted classed request."""
    mask = _mask((14, 18), seed=76)
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(32,), max_batch=2, max_delay_ms=1.0))
    with svc, ServerThread(svc, rpc_port=0) as srv:
        async def go():
            client = await AsyncRPCClient(
                "127.0.0.1", srv.rpc_port).connect()
            try:
                out = await client.analyze(mask, klass="interactive",
                                           tenant="acme")
                try:
                    await client.analyze(_mask((14, 18), seed=77),
                                         deadline_ms=0.0)
                    shed = None
                except FrontendOverloaded as e:
                    shed = e
            finally:
                await client.aclose()
            return out, shed

        got, shed = asyncio.run(go())
        want = svc.submit(mask).result(timeout=TIMEOUT).to_host()
        _assert_host_equal(got, want)
        assert shed is not None and shed.kind == "deadline"
        assert shed.retry_after_s == pytest.approx(0.05)


# ---------------------------------------------------------- RPC transport


def test_rpc_pipelined_analyzes_bit_identical():
    masks = [_mask((12 + i, 18), seed=60 + i) for i in range(5)]
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(32,), max_batch=4, max_delay_ms=1.0))
    with svc, ServerThread(svc, rpc_port=0) as srv:
        async def go():
            client = await AsyncRPCClient(
                "127.0.0.1", srv.rpc_port).connect()
            try:
                outs = await asyncio.gather(
                    *[client.analyze(m) for m in masks])
                health = await client.health()
            finally:
                await client.aclose()
            return outs, health

        outs, health = asyncio.run(go())
        assert health["status"] == "ok"
        for mask, got in zip(masks, outs):
            want = svc.submit(mask).result(timeout=TIMEOUT).to_host()
            _assert_host_equal(got, want)


def test_rpc_unknown_op_is_an_error_response():
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(16,), max_batch=1, max_delay_ms=1.0))
    with svc, ServerThread(svc, rpc_port=0) as srv:
        async def go():
            reader, writer = await asyncio.open_connection(
                "127.0.0.1", srv.rpc_port)
            writer.write(protocol.pack_frame({"op": "explode", "id": 9}))
            await writer.drain()
            resp = await protocol.read_frame(reader)
            writer.close()
            await writer.wait_closed()
            return resp

        resp = asyncio.run(go())
        assert resp["id"] == 9 and resp["status"] == 400
        assert "unknown op" in resp["error"]


# --------------------------------------------------------- multi-op routes


def _float_img(shape, seed=0):
    return np.random.default_rng(seed).random(shape).astype(np.float32)


def test_http_per_op_routes_bit_identical_to_in_process_submit():
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(32,), max_batch=2, max_delay_ms=1.0))
    mask = _mask((24, 30), seed=70)
    img = _float_img((24, 30), seed=71)
    with svc, ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        for op, x in (("ccl", mask), ("denoise", img)):
            got = client.analyze(x, op=op)
            want = svc.submit(x, op=op).result(timeout=TIMEOUT).to_host()
            _assert_host_equal(got, want)
        # /v1/ychg and the historical /v1/analyze alias answer identically
        _assert_host_equal(client.analyze(mask, op="ychg"),
                           client.analyze(mask))


def test_http_unknown_op_is_404_json_naming_registered_ops():
    from repro.engine.ops import op_names

    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(16,), max_batch=1, max_delay_ms=1.0))
    with svc, ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        with pytest.raises(FrontendError) as ei:
            client.analyze(_mask((8, 8)), op="warp")
        assert ei.value.status == 404
        body = json.loads(str(ei.value))
        assert "warp" in body["error"]
        assert sorted(body["ops"]) == sorted(op_names())


def test_http_pipeline_equals_separate_wire_requests():
    """POST /v1/pipeline (device-resident compound) against feeding stage
    1's wire output back as stage 2's request — bit-identical."""
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(32,), max_batch=2, max_delay_ms=1.0))
    img = _float_img((26, 20), seed=72)
    with svc, ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        compound = client.pipeline(img, ["denoise", "ychg"])
        stage1 = client.analyze(img, op="denoise")
        want = client.analyze(stage1["image"], op="ychg")
        _assert_host_equal(compound, want)


def test_http_pipeline_bad_stage_specs_are_400_or_404():
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(16,), max_batch=1, max_delay_ms=1.0))
    with svc, ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        for stages in ([], ["denoise", 7], "denoise"):
            with pytest.raises((FrontendError, ValueError)) as ei:
                client.pipeline(_float_img((8, 8)), stages)  # type: ignore
            if isinstance(ei.value, FrontendError):
                assert ei.value.status == 400
        with pytest.raises(FrontendError) as ei:
            client.pipeline(_float_img((8, 8)), ["denoise", "warp"])
        assert ei.value.status == 400
        # an interior stage with no chain output cannot feed the next one
        with pytest.raises(FrontendError) as ei:
            client.pipeline(_float_img((8, 8)), ["ychg", "ccl"])
        assert ei.value.status == 400


def test_rpc_opname_and_pipeline_verbs_bit_identical():
    svc = YCHGService(config=ServiceConfig(
        bucket_sides=(32,), max_batch=2, max_delay_ms=1.0))
    mask = _mask((18, 22), seed=73)
    img = _float_img((18, 22), seed=74)
    with svc, ServerThread(svc, rpc_port=0) as srv:
        async def go():
            client = await AsyncRPCClient(
                "127.0.0.1", srv.rpc_port).connect()
            try:
                ccl = await client.analyze(mask, op="ccl")
                piped = await client.pipeline(img, ["denoise", "ychg"])
                with pytest.raises(FrontendError) as ei:
                    await client.analyze(mask, op="warp")
                assert ei.value.status == 404
            finally:
                await client.aclose()
            return ccl, piped

        ccl, piped = asyncio.run(go())
        _assert_host_equal(
            ccl, svc.submit(mask, op="ccl").result(timeout=TIMEOUT).to_host())
        _assert_host_equal(
            piped,
            svc.pipeline(img, ["denoise", "ychg"],
                         timeout=TIMEOUT).to_host())
