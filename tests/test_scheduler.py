"""`repro.service.scheduler` suite: the dispatch policy layer, engine-free.

The `Scheduler` is exercised against a fake dispatch function — no engine,
no device, no cache — so these tests pin pure policy: sub-batch ladder
selection, the admission gate (shed and block), the in-flight window
semantics (N means N), and the idle-drain ordering fix. Per the policy in
tests/README.md there are **no wall-clock assertions**: interleavings are
pinned with `autostart=False` (enqueue before the loop runs) and
per-job gate events inside the complete callback (the scheduler thread
parks exactly where the test needs it), and cross-thread progress is
awaited with bounded `_wait_until` polls that fail, never hang.
"""

import dataclasses
import threading
import time

import pytest

from repro.service.scheduler import (
    DeadlineExceeded,
    DrainRate,
    Scheduler,
    SchedulerConfig,
    ServiceOverloaded,
    TenantQuotaExceeded,
    TokenBucket,
    pick_sub_batch,
    sub_batch_ladder,
)

TIMEOUT = 30.0


@dataclasses.dataclass
class Req:
    name: str
    bucket: tuple = ("b", "uint8")
    t_submit: float = dataclasses.field(default_factory=time.monotonic)
    # traffic-shaping fields (absent = default class, no deadline/tenant)
    klass: str = None
    deadline_ms: float = None
    tenant: str = None


class FakeDispatch:
    """Records the scheduler's callback traffic; optionally gates completes.

    With ``gated=True`` each job's ``complete`` parks on a pair of events:
    ``entered[h]`` is set when the scheduler thread arrives (the test can
    wait on it), ``resume[h]`` must be set by the test to let it through —
    a deterministic stand-in for "device work is still running".
    """

    def __init__(self, gated=False, fail_buckets=()):
        self._lock = threading.Lock()
        self._gated = gated
        self._fail_buckets = set(fail_buckets)
        self.dispatches = []      # (bucket, names, batch_size)
        self.events = []          # ("dispatch"|"complete", names...)
        self.completions = []
        self.failures = []        # (names, exc)
        self.outstanding = 0
        self.max_outstanding_before = 0   # outstanding jobs seen at dispatch
        self.entered = {}
        self.resume = {}
        self._n = 0

    def dispatch(self, bucket, requests, batch_size):
        if bucket in self._fail_buckets:
            raise RuntimeError(f"dispatch refused for {bucket}")
        names = tuple(r.name for r in requests)
        with self._lock:
            handle = self._n
            self._n += 1
            self.max_outstanding_before = max(
                self.max_outstanding_before, self.outstanding)
            self.outstanding += 1
            self.dispatches.append((bucket, names, batch_size))
            self.events.append(("dispatch",) + names)
            if self._gated:
                self.entered[handle] = threading.Event()
                self.resume[handle] = threading.Event()
        return handle

    def complete(self, handle, requests):
        if self._gated:
            self.entered[handle].set()
            assert self.resume[handle].wait(TIMEOUT), "gate never released"
        names = tuple(r.name for r in requests)
        with self._lock:
            self.outstanding -= 1
            self.completions.append(names)
            self.events.append(("complete",) + names)

    def fail(self, requests, exc):
        with self._lock:
            self.failures.append((tuple(r.name for r in requests), exc))

    def open_gates(self):
        """Stop gating: release every parked job and let future jobs
        complete ungated."""
        with self._lock:
            self._gated = False
            gates = list(self.resume.values())
        for g in gates:
            g.set()

    def scheduler(self, autostart=True, **cfg):
        return Scheduler(SchedulerConfig(**cfg), self.dispatch,
                         self.complete, self.fail, autostart=autostart)


def _wait_until(predicate, what):
    deadline = time.monotonic() + TIMEOUT
    while not predicate():
        if time.monotonic() > deadline:
            pytest.fail(f"timed out waiting for {what}")
        time.sleep(0.001)


# ------------------------------------------------------- sub-batch ladder


def test_pick_sub_batch_is_next_pow2_capped():
    assert [pick_sub_batch(n, 8) for n in range(1, 9)] == \
        [1, 2, 4, 4, 8, 8, 8, 8]
    assert pick_sub_batch(5, 6) == 6          # cap is the top rung
    assert pick_sub_batch(1, 1) == 1
    with pytest.raises(ValueError, match="occupancy"):
        pick_sub_batch(0, 8)


def test_sub_batch_ladder_is_log2_plus_one_rungs():
    assert sub_batch_ladder(8) == (1, 2, 4, 8)
    assert sub_batch_ladder(6) == (1, 2, 4, 6)   # non-pow2 cap is a rung
    assert sub_batch_ladder(1) == (1,)
    # every pick lands on the ladder — the compiled-shape budget
    for n in range(1, 9):
        assert pick_sub_batch(n, 8) in sub_batch_ladder(8)


def test_flush_dispatches_sub_batch_sizes():
    """One lone request is padded to 1, three to 4, a full bucket to
    max_batch — never unconditionally to max_batch."""
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=8, max_delay_ms=1.0)
    sched.submit(Req("a1", bucket=("A", "u8")))
    for i in range(3):
        sched.submit(Req(f"b{i}", bucket=("B", "u8")))
    for i in range(8):
        sched.submit(Req(f"c{i}", bucket=("C", "u8")))
    sched.start()
    sched.close()
    sizes = {bucket: batch for bucket, _, batch in fake.dispatches}
    assert sizes == {("A", "u8"): 1, ("B", "u8"): 4, ("C", "u8"): 8}
    # occupancy rides along intact: the C flush carries all 8 requests
    (c_names,) = [names for b, names, _ in fake.dispatches if b == ("C", "u8")]
    assert c_names == tuple(f"c{i}" for i in range(8))


def test_sub_batches_off_pads_to_max_batch():
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=8, max_delay_ms=1.0,
                           sub_batches=False)
    sched.submit(Req("solo"))
    sched.start()
    sched.close()
    assert [b for _, _, b in fake.dispatches] == [8]


# ------------------------------------------------------- admission control


def test_shed_policy_raises_typed_error_at_bound():
    fake = FakeDispatch(gated=True)
    sched = fake.scheduler(max_batch=1, max_delay_ms=1.0,
                           max_queue_depth=2, overload_policy="shed")
    sched.submit(Req("a", bucket=("A", "u8")))
    sched.submit(Req("b", bucket=("B", "u8")))
    # both slots held (their jobs are gated mid-complete / in flight)
    with pytest.raises(ServiceOverloaded, match="max_queue_depth=2"):
        sched.submit(Req("c", bucket=("C", "u8")))
    assert sched.shed == 1 and sched.blocked == 0
    fake.open_gates()
    _wait_until(lambda: sched.depth == 0, "admitted jobs to retire")
    sched.submit(Req("d", bucket=("D", "u8")))   # slots freed: admitted
    _wait_until(lambda: ("d",) in fake.completions, "d to complete")
    sched.close()
    assert ("c",) not in {n for _, n, _ in fake.dispatches}
    dispatched = {name for _, names, _ in fake.dispatches for name in names}
    assert dispatched == {"a", "b", "d"}


def test_block_policy_waits_for_a_slot():
    fake = FakeDispatch(gated=True)
    sched = fake.scheduler(max_batch=1, max_delay_ms=1.0,
                           max_queue_depth=2, overload_policy="block")
    sched.submit(Req("a", bucket=("A", "u8")))
    sched.submit(Req("b", bucket=("B", "u8")))
    done = threading.Event()

    def blocked_submit():
        sched.submit(Req("c", bucket=("C", "u8")))
        done.set()

    t = threading.Thread(target=blocked_submit, daemon=True)
    t.start()
    _wait_until(lambda: sched.blocked == 1, "submitter to hit the gate")
    assert not done.is_set()                     # parked, not shed
    _wait_until(lambda: 0 in fake.entered, "first job to reach complete")
    fake.resume[0].set()                         # retire one -> slot frees
    _wait_until(done.is_set, "blocked submitter to be admitted")
    fake.open_gates()
    t.join(TIMEOUT)
    sched.close()
    assert sched.shed == 0 and sched.blocked == 1
    dispatched = {name for _, names, _ in fake.dispatches for name in names}
    assert dispatched == {"a", "b", "c"}


def test_blocked_submitter_woken_by_close_raises():
    fake = FakeDispatch(gated=True)
    sched = fake.scheduler(max_batch=1, max_delay_ms=1.0,
                           max_queue_depth=1, overload_policy="block")
    sched.submit(Req("a"))
    box = {}

    def blocked_submit():
        try:
            sched.submit(Req("late"))
        except RuntimeError as e:
            box["exc"] = e

    t = threading.Thread(target=blocked_submit, daemon=True)
    t.start()
    _wait_until(lambda: sched.blocked == 1, "submitter to hit the gate")
    sched.close(timeout=0.0)     # wake the gate; don't wait for the drain
    t.join(TIMEOUT)
    assert isinstance(box.get("exc"), RuntimeError)
    assert "closed" in str(box["exc"])
    fake.open_gates()            # let the drain finish: admitted work retires
    _wait_until(lambda: ("a",) in fake.completions, "admitted job to drain")
    assert ("late",) not in {n for _, n, _ in fake.dispatches}


def test_dispatch_error_fails_slice_and_releases_slots():
    fake = FakeDispatch(fail_buckets={("BAD", "u8")})
    sched = fake.scheduler(max_batch=1, max_delay_ms=1.0,
                           max_queue_depth=1, overload_policy="shed")
    sched.submit(Req("x", bucket=("BAD", "u8")))
    _wait_until(lambda: fake.failures, "dispatch error to route to fail()")
    (names, exc) = fake.failures[0]
    assert names == ("x",) and "dispatch refused" in str(exc)
    _wait_until(lambda: sched.depth == 0, "failed slice to release its slot")
    sched.submit(Req("y", bucket=("OK", "u8")))   # no leaked depth
    sched.close()
    assert ("y",) in fake.completions


def test_scheduler_config_validation():
    with pytest.raises(ValueError, match="max_batch"):
        SchedulerConfig(max_batch=0)
    with pytest.raises(ValueError, match="inflight_jobs"):
        SchedulerConfig(inflight_jobs=0)
    with pytest.raises(ValueError, match="max_queue_depth"):
        SchedulerConfig(max_queue_depth=0)
    with pytest.raises(ValueError, match="bucket_queue_depth"):
        SchedulerConfig(bucket_queue_depth=0)
    with pytest.raises(ValueError, match="overload_policy"):
        SchedulerConfig(overload_policy="drop")


# --------------------------------------------- per-bucket fairness (PR 5)


def test_bucket_bound_sheds_only_the_hot_bucket():
    """bucket_queue_depth is per bucket: a flooded bucket sheds against
    its own allowance (typed error naming the bucket, counted in
    shed_by_bucket) while another bucket keeps admitting freely."""
    fake = FakeDispatch(gated=True)
    sched = fake.scheduler(max_batch=1, max_delay_ms=1.0,
                           bucket_queue_depth=2, overload_policy="shed")
    hot, cold = ("HOT", "u8"), ("COLD", "u8")
    sched.submit(Req("h0", bucket=hot))
    sched.submit(Req("h1", bucket=hot))
    # both HOT slots held (their jobs are gated mid-complete / in flight)
    for i in range(3):
        with pytest.raises(ServiceOverloaded, match="bucket_queue_depth=2"):
            sched.submit(Req(f"hx{i}", bucket=hot))
    # the cold bucket is untouched by the hot bucket's flood
    sched.submit(Req("c0", bucket=cold))
    sched.submit(Req("c1", bucket=cold))
    assert sched.shed == 3
    assert sched.shed_by_bucket == {hot: 3}
    assert sched.depth_by_bucket == {hot: 2, cold: 2}
    fake.open_gates()
    _wait_until(lambda: sched.depth == 0, "admitted jobs to retire")
    sched.submit(Req("h2", bucket=hot))   # freed slots re-admit
    sched.close()
    dispatched = {n for _, names, _ in fake.dispatches for n in names}
    assert dispatched == {"h0", "h1", "h2", "c0", "c1"}
    assert sched.shed_by_bucket == {hot: 3}   # cold never shed


def test_bucket_bound_block_wakes_on_own_buckets_release():
    """Policy "block" at a bucket bound parks the submitter; a retirement
    in THAT bucket frees the slot and admits it."""
    fake = FakeDispatch(gated=True)
    sched = fake.scheduler(max_batch=1, max_delay_ms=1.0,
                           bucket_queue_depth=1, overload_policy="block")
    hot = ("HOT", "u8")
    sched.submit(Req("h0", bucket=hot))
    done = threading.Event()

    def blocked_submit():
        sched.submit(Req("h1", bucket=hot))
        done.set()

    t = threading.Thread(target=blocked_submit, daemon=True)
    t.start()
    _wait_until(lambda: sched.blocked == 1, "submitter to hit the gate")
    assert not done.is_set()
    # a DIFFERENT bucket admits straight through while hot is parked
    sched.submit(Req("c0", bucket=("COLD", "u8")))
    _wait_until(lambda: 0 in fake.entered, "h0 to reach complete")
    fake.resume[0].set()                     # retire h0 -> hot slot frees
    _wait_until(done.is_set, "blocked hot submitter to be admitted")
    fake.open_gates()
    t.join(TIMEOUT)
    sched.close()
    dispatched = {n for _, names, _ in fake.dispatches for n in names}
    assert dispatched == {"h0", "h1", "c0"}


def test_fair_drr_interleaves_hot_backlog_with_minority():
    """The tentpole's fairness bar, engine-free: a 16-deep hot-bucket
    backlog must NOT dispatch back to back ahead of a lone minority
    request. Deficit round robin serves one max_batch flush per bucket
    per round, so the order is HOT(8), COLD(1), HOT(8); the legacy
    fair=False policy flushes in arrival order, HOT(8), HOT(8), COLD(1)
    (pinned below as the contrast)."""
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=8, max_delay_ms=0.0,
                           fair=True)
    for i in range(16):
        sched.submit(Req(f"h{i}", bucket=("HOT", "u8")))
    sched.submit(Req("c0", bucket=("COLD", "u8")))
    sched.start()
    sched.close()
    order = [(b, len(names)) for b, names, _ in fake.dispatches]
    assert order == [(("HOT", "u8"), 8), (("COLD", "u8"), 1),
                     (("HOT", "u8"), 8)]
    # occupancy rides along intact and in FIFO order within the bucket
    hot_names = [n for b, names, _ in fake.dispatches
                 if b == ("HOT", "u8") for n in names]
    assert hot_names == [f"h{i}" for i in range(16)]


def test_banked_deficit_cannot_fund_a_mega_burst():
    """DRR banking is CAPPED (one quantum beyond the largest flush):
    credit a bucket accrued across earlier rounds must never later pay
    for flushing its whole backlog ahead of a minority peer.

    The test seeds the hot bucket's bank directly — the white-box stand-in
    for "many rounds of banked quantum" — then offers a 32-deep hot
    backlog against one cold request. Pre-fix (unbounded bank) the seeded
    credit pays for all four hot flushes back to back and the cold
    request dispatches dead last; with the cap, the bank clamps to at
    most two flushes' worth, so the cold request is served within the
    first round (third dispatch at the latest)."""
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=8, max_delay_ms=0.0,
                           fair=True)
    for i in range(32):
        sched.submit(Req(f"h{i}", bucket=("HOT", "u8")))
    sched.submit(Req("c0", bucket=("COLD", "u8")))
    sched._deficit[("HOT", "u8")] = 1000   # banked across earlier rounds
    sched.start()
    sched.close()
    order = [(b, len(names)) for b, names, _ in fake.dispatches]
    assert order.count((("HOT", "u8"), 8)) == 4     # backlog fully served
    cold_at = order.index((("COLD", "u8"), 1))
    assert cold_at <= 2, (
        f"banked deficit funded a mega-burst: cold request dispatched "
        f"{cold_at + 1}th in {order}")


def test_unfair_legacy_policy_serves_hot_backlog_first():
    """fair=False keeps the arrival-order policy (the benchmark's unfair
    arm): the minority request waits behind the whole hot backlog."""
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=8, max_delay_ms=0.0,
                           fair=False)
    for i in range(16):
        sched.submit(Req(f"h{i}", bucket=("HOT", "u8")))
    sched.submit(Req("c0", bucket=("COLD", "u8")))
    sched.start()
    sched.close()
    order = [(b, len(names)) for b, names, _ in fake.dispatches]
    assert order == [(("HOT", "u8"), 8), (("HOT", "u8"), 8),
                     (("COLD", "u8"), 1)]


def test_flush_never_exceeds_max_batch_under_accumulation():
    """Fair mode banks the whole ingest drain before serving, so a bucket
    can hold more than max_batch pending — every flush must still cap at
    max_batch (the compiled-shape ladder bound)."""
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=4, max_delay_ms=0.0,
                           fair=True)
    for i in range(11):
        sched.submit(Req(f"r{i}"))
    sched.start()
    sched.close()
    assert all(len(names) <= 4 for _, names, _ in fake.dispatches)
    assert [len(names) for _, names, _ in fake.dispatches] == [4, 4, 3]
    assert [n for names in fake.completions for n in names] == [
        f"r{i}" for i in range(11)]


# ------------------------------------- blocked submit vs close (PR 5 fix)


def test_blocked_producers_never_deadlock_close():
    """Regression guard for the admission gate's locking discipline: a
    producer parked at the bound waits inside ``Condition.wait``, which
    RELEASES the lock — so a concurrent ``close()`` can always take the
    lock, wake every parked producer (they raise RuntimeError), and
    drain the admitted work. If submit ever parked while HOLDING the
    lock (busy-wait, sleep-under-lock), this test would deadlock and
    time out rather than pass."""
    fake = FakeDispatch(gated=True)
    sched = fake.scheduler(max_batch=1, max_delay_ms=1.0,
                           max_queue_depth=1, overload_policy="block")
    sched.submit(Req("a"))
    _wait_until(lambda: 0 in fake.entered, "a to park mid-complete")
    errors = []

    def blocked_submit(i):
        try:
            sched.submit(Req(f"late{i}"))
        except RuntimeError as e:
            errors.append(e)

    producers = [threading.Thread(target=blocked_submit, args=(i,),
                                  daemon=True) for i in range(3)]
    for t in producers:
        t.start()
    _wait_until(lambda: sched.blocked == 3, "producers to park at the gate")
    # close() from yet another thread: it must wake all three parked
    # producers immediately even though its own drain is still pinned
    # behind the gated complete
    closer = threading.Thread(target=sched.close, daemon=True)
    closer.start()
    for t in producers:
        t.join(TIMEOUT)
        assert not t.is_alive(), "a parked producer deadlocked close()"
    assert len(errors) == 3
    assert all("closed" in str(e) for e in errors)
    fake.open_gates()            # let the drain retire the admitted job
    closer.join(TIMEOUT)
    assert not closer.is_alive()
    assert ("a",) in fake.completions
    dispatched = {n for _, names, _ in fake.dispatches for n in names}
    assert dispatched == {"a"}   # nothing parked was ever admitted


# ------------------------------------------- the three scheduling bugfixes


def test_inflight_window_n_means_n():
    """Regression (inflight off-by-one): with inflight_jobs=2 the scheduler
    must reach TWO concurrently outstanding jobs before retiring any — the
    pre-fix `>=` retired at one, so double buffering never overlapped."""
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=1, max_delay_ms=1.0,
                           inflight_jobs=2)
    for i in range(4):
        sched.submit(Req(f"r{i}", bucket=(f"B{i}", "u8")))
    sched.start()
    sched.close()
    # all four dispatched and retired, strictly in order
    assert fake.completions == [(f"r{i}",) for i in range(4)]
    # the third dispatch happened with two jobs already outstanding...
    assert fake.max_outstanding_before == 2
    # ...i.e. nothing was retired until the window actually overflowed
    assert [e[0] for e in fake.events[:3]] == ["dispatch"] * 3


def test_idle_drain_polls_queue_between_completions():
    """Regression (idle-drain head-of-line blocking): a request arriving
    while the scheduler is retiring its backlog must be dispatched after at
    most ONE completion, not behind every outstanding job."""
    fake = FakeDispatch(gated=True)
    sched = fake.scheduler(autostart=False, max_batch=1, max_delay_ms=1.0,
                           inflight_jobs=8)
    sched.submit(Req("a", bucket=("A", "u8")))
    sched.submit(Req("b", bucket=("B", "u8")))
    sched.start()
    # the idle drain begins retiring job a; park the scheduler inside it
    _wait_until(lambda: 0 in fake.entered and fake.entered[0].is_set(),
                "idle drain to enter complete(a)")
    sched.submit(Req("late", bucket=("C", "u8")))   # arrives mid-drain
    fake.resume[1].set()   # job b's gate is open: only ordering is at stake
    fake.resume[0].set()
    # the fix: after finishing ONE completion the loop polls the queue, so
    # "late" is dispatched before job b is retired
    _wait_until(lambda: len(fake.dispatches) == 3, "late to be dispatched")
    fake.open_gates()
    sched.close()
    order = fake.events
    assert order.index(("dispatch", "late")) < order.index(("complete", "b"))


def test_close_before_start_drains_inline():
    """A scheduler that never started its loop must still honour admitted
    requests at close(): the drain runs inline on the closing thread
    instead of silently dropping the queue."""
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=4, max_delay_ms=1.0)
    for i in range(10):          # > 2x max_batch: the drain must flush
        sched.submit(Req(f"p{i}"))  # full buckets, never overfill one
    sched.close()                # loop never ran
    assert [n for n in fake.completions] == [
        ("p0", "p1", "p2", "p3"), ("p4", "p5", "p6", "p7"), ("p8", "p9")]
    # every flush obeyed max_batch and its sub-batch size
    assert all(len(names) <= b for _, names, b in fake.dispatches)
    assert [b for _, _, b in fake.dispatches] == [4, 4, 2]
    assert sched.depth == 0
    with pytest.raises(RuntimeError, match="closed"):
        sched.start()            # a closed scheduler cannot be started


def test_close_drains_pending_and_inflight():
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=8, max_delay_ms=10_000.0)
    for i in range(3):
        sched.submit(Req(f"p{i}"))      # parked in the delay window forever
    sched.start()
    sched.close()
    assert fake.completions == [("p0", "p1", "p2")]
    assert [b for _, _, b in fake.dispatches] == [4]   # sub-batch on drain
    with pytest.raises(RuntimeError, match="closed"):
        sched.submit(Req("post"))


# --------------------------------------- traffic classes (PR 10 tentpole)


def test_class_priority_preempts_lower_classes():
    """Strict priority across classes: with a batch backlog and one
    request in each higher class enqueued before the loop runs, dispatch
    order is interactive, standard, then the whole batch backlog — the
    class outranks both arrival order and DRR round order."""
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=8, max_delay_ms=0.0,
                           fair=True)
    for i in range(16):
        sched.submit(Req(f"b{i}", bucket=("B", "u8"), klass="batch"))
    sched.submit(Req("s0", bucket=("B", "u8"), klass="standard"))
    sched.submit(Req("i0", bucket=("B", "u8"), klass="interactive"))
    sched.start()
    sched.close()
    first = [names[0] for _, names, _ in fake.dispatches]
    assert first == ["i0", "s0", "b0", "b8"]


def test_class_preemption_is_per_flush_not_per_backlog():
    """An interactive arrival mid-batch-drain jumps the remaining batch
    flushes: preemption granularity is one flush, never the backlog."""
    fake = FakeDispatch(gated=True)
    sched = fake.scheduler(autostart=False, max_batch=8, max_delay_ms=0.0,
                           fair=True, inflight_jobs=1)
    for i in range(24):                       # three batch-class flushes
        sched.submit(Req(f"b{i}", bucket=("B", "u8"), klass="batch"))
    sched.start()
    # inflight_jobs=1 parks the scheduler retiring flush 0 (flush 1 is
    # already in flight); the THIRD batch flush has not dispatched yet
    # when the interactive one lands
    _wait_until(lambda: 0 in fake.entered and fake.entered[0].is_set(),
                "first batch flush to park mid-complete")
    sched.submit(Req("i0", bucket=("B", "u8"), klass="interactive"))
    fake.open_gates()
    sched.close()
    first = [names[0] for _, names, _ in fake.dispatches]
    assert first.index("i0") < first.index("b16"), (
        f"interactive request did not preempt the remaining batch "
        f"backlog: dispatch order {first}")


def test_classes_share_one_buckets_drr_within_a_class():
    """Within one class DRR fairness is unchanged: two buckets of the
    same class interleave per round exactly as the classless scheduler
    did (the class tuple wraps the flow key, it does not replace DRR)."""
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=8, max_delay_ms=0.0,
                           fair=True)
    for i in range(16):
        sched.submit(Req(f"h{i}", bucket=("HOT", "u8"), klass="batch"))
    sched.submit(Req("c0", bucket=("COLD", "u8"), klass="batch"))
    sched.start()
    sched.close()
    order = [(b, len(names)) for b, names, _ in fake.dispatches]
    assert order == [(("HOT", "u8"), 8), (("COLD", "u8"), 1),
                     (("HOT", "u8"), 8)]


def test_unknown_class_raises_and_default_class_applies():
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=1, max_delay_ms=0.0)
    with pytest.raises(ValueError, match="unknown traffic class"):
        sched.submit(Req("x", klass="platinum"))
    sched.submit(Req("plain"))               # no klass -> default_class
    assert sched.class_of(Req("plain")) == "standard"
    sched.start()
    sched.close()
    assert ("plain",) in fake.completions


def test_traffic_class_config_validation():
    with pytest.raises(ValueError, match="classes"):
        SchedulerConfig(classes=())
    with pytest.raises(ValueError, match="classes"):
        SchedulerConfig(classes=("a", "a"))
    with pytest.raises(ValueError, match="default_class"):
        SchedulerConfig(default_class="nope")
    with pytest.raises(ValueError, match="tenant_rate"):
        SchedulerConfig(tenant_rate=-1.0)


# ------------------------------------------- deadline sheds (PR 10)


def _seed_rate(sched, rate):
    """White-box drain-rate seeding (the ``sched._deficit`` idiom):
    synthetic (now, completed) samples pin ``rate()`` exactly, so the
    admission arithmetic below is deterministic — no wall clocks."""
    sched._drain_rate.observe(0, now=0.0)
    sched._drain_rate.observe(int(rate * 10), now=10.0)
    assert sched._drain_rate.rate() == pytest.approx(rate)


def test_deadline_shed_is_deterministic_with_injected_rate():
    """depth=3 and a seeded 2/s drain rate predict (3+1)/2 = 2.0s of
    queue delay: a 1999ms deadline sheds (typed error, counted), a
    2001ms deadline is admitted. Pure arithmetic, no sleeps."""
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=1, max_delay_ms=0.0)
    for i in range(3):
        sched.submit(Req(f"p{i}", bucket=(f"B{i}", "u8")))
    _seed_rate(sched, 2.0)
    assert sched.predicted_wait_s() == pytest.approx(2.0)
    with pytest.raises(DeadlineExceeded, match="deadline 1999"):
        sched.submit(Req("tight", deadline_ms=1999.0))
    assert sched.shed_deadline == 1
    assert sched.shed_by_class == {"standard": 1}
    sched.submit(Req("loose", deadline_ms=2001.0))   # meetable: admitted
    sched.start()
    sched.close()
    dispatched = {n for _, names, _ in fake.dispatches for n in names}
    assert "loose" in dispatched and "tight" not in dispatched


def test_deadline_retry_after_is_clamped_honest_lateness():
    """Retry-After for a deadline shed is the predicted lateness
    (predicted delay minus the deadline), clamped to [0.05, 30]."""
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=1, max_delay_ms=0.0)
    for i in range(9):
        sched.submit(Req(f"p{i}", bucket=(f"B{i}", "u8")))
    _seed_rate(sched, 1.0)                   # predicted = 10.0s
    with pytest.raises(DeadlineExceeded) as ei:
        sched.submit(Req("d", deadline_ms=4000.0))
    assert ei.value.retry_after_s == pytest.approx(6.0)   # 10.0 - 4.0
    with pytest.raises(DeadlineExceeded) as ei:
        sched.submit(Req("d2", deadline_ms=9990.0))
    assert ei.value.retry_after_s == 0.05                 # floor clamp
    sched.close()


def test_cold_estimator_never_sheds_but_nonpositive_deadline_does():
    """With no drain-rate samples the predicted delay is unknown: a
    positive deadline must be admitted (a cold estimator never justifies
    a shed); a deadline <= 0 is already dead and sheds regardless."""
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=1, max_delay_ms=0.0)
    for i in range(50):
        sched.submit(Req(f"p{i}", bucket=(f"B{i}", "u8")))
    assert sched.predicted_wait_s() is None
    sched.submit(Req("hopeful", deadline_ms=1.0))    # admitted while cold
    with pytest.raises(DeadlineExceeded):
        sched.submit(Req("dead", deadline_ms=0.0))
    sched.start()
    sched.close()
    dispatched = {n for _, names, _ in fake.dispatches for n in names}
    assert "hopeful" in dispatched and "dead" not in dispatched


def test_drain_rate_unit_algebra():
    dr = DrainRate()
    assert dr.rate() is None                 # cold
    dr.observe(5, now=1.0)
    assert dr.rate() is None                 # one sample
    dr.observe(5, now=2.0)
    assert dr.rate() is None                 # no forward progress
    dr.observe(9, now=3.0)
    assert dr.rate() == pytest.approx(2.0)   # (9-5)/(3-1)


# ---------------------------------------------- tenant quotas (PR 10)


def test_token_bucket_refill_algebra():
    """Exact refill arithmetic with synthetic timestamps: burst spends
    first, then admission tracks rate, and the wait quote is the exact
    time until one whole token exists."""
    tb = TokenBucket(rate=2.0, burst=2)
    assert tb.take(0.0) == 0.0               # burst token 1
    assert tb.take(0.0) == 0.0               # burst token 2
    assert tb.take(0.0) == pytest.approx(0.5)   # empty: 1 token / 2 per s
    assert tb.take(0.25) == pytest.approx(0.25)  # refilled 0.5, need 0.5 more
    assert tb.take(0.75) == 0.0              # 1.5 banked: spend one
    tb2 = TokenBucket(rate=1.0, burst=2)
    tb2.take(0.0)
    tb2.take(0.0)
    assert tb2.take(100.0) == 0.0            # refill capped at burst...
    assert tb2.take(100.0) == 0.0
    assert tb2.take(100.0) == pytest.approx(1.0)   # ...never 98 banked
    with pytest.raises(ValueError, match="rate"):
        TokenBucket(rate=0.0, burst=1)
    with pytest.raises(ValueError, match="burst"):
        TokenBucket(rate=1.0, burst=0.5)


def test_tenant_quota_sheds_one_tenant_not_the_other():
    """Over-quota sheds are per tenant (typed error, per-tenant counter)
    and NEVER block — even under overload_policy="block" — while an
    un-tenanted or under-quota request admits freely."""
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=1, max_delay_ms=0.0,
                           tenant_rate=0.001, tenant_burst=1,
                           overload_policy="block")
    sched.submit(Req("a0", bucket=("A", "u8"), tenant="acme"))
    with pytest.raises(TenantQuotaExceeded, match="acme") as ei:
        sched.submit(Req("a1", bucket=("A", "u8"), tenant="acme"))
    assert ei.value.retry_after_s == 30.0    # honest wait, ceiling clamp
    assert sched.shed_quota == 1
    assert sched.shed_by_tenant == {"acme": 1}
    sched.submit(Req("z0", bucket=("A", "u8"), tenant="zeta"))  # own bucket
    sched.submit(Req("p0", bucket=("A", "u8")))       # no tenant: no quota
    sched.start()
    sched.close()
    dispatched = {n for _, names, _ in fake.dispatches for n in names}
    assert dispatched == {"a0", "z0", "p0"}


def test_tenant_quota_unlimited_when_rate_unset():
    fake = FakeDispatch()
    sched = fake.scheduler(autostart=False, max_batch=1, max_delay_ms=0.0)
    for i in range(20):                      # tenant_rate=0.0: no limiter
        sched.submit(Req(f"t{i}", bucket=(f"B{i}", "u8"), tenant="acme"))
    sched.start()
    sched.close()
    assert sched.shed_quota == 0 and len(fake.completions) == 20
