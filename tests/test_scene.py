"""`repro.scene` suite: granule windowing, exact stitching, resumable bulk.

Policy (tests/README.md §Scene tests): scenes are tiny (tens of rows) but
always exercise the ragged last strip; no wall-clock assertions — resume
points are pinned with ``max_stacks``, never with timers or signals. Two
bars, both exact:

  * **stitch bit-identity** — every field of a stitched scene result
    (values, dtypes, shapes) equals one whole-scene ``engine.analyze``;
  * **resume byte-identity** — an interrupted-and-resumed ``BulkJob``
    writes files byte-for-byte equal to an uninterrupted run's.

Sockets follow the frontend policy: loopback only, ephemeral ports.
"""

import dataclasses
import os

import numpy as np
import pytest

from repro.data import scenes
from repro.engine import Engine, YCHGConfig
from repro.scene import (
    BulkJob,
    BulkJobConfig,
    GranuleReader,
    GranuleSpec,
    SceneProgress,
    SceneResult,
    SceneRunner,
    manifest_from_json,
    manifest_to_json,
    read_scene_result,
    seam_joins,
    stitch_tile_runs,
    synthetic_manifest,
    write_scene_result,
)

TIMEOUT = 300.0


def _assert_host_identical(got, want, context=""):
    """Dict-of-arrays parity bar: values, dtypes, and shapes all equal."""
    assert set(got) == set(want)
    for field in want:
        g, w = np.asarray(got[field]), np.asarray(want[field])
        assert g.dtype == w.dtype, f"{context}{field}: {g.dtype} != {w.dtype}"
        assert g.shape == w.shape, f"{context}{field}: {g.shape} != {w.shape}"
        np.testing.assert_array_equal(g, w, err_msg=context + field)


# -------------------------------------------------------- synthetic scenes


def test_scene_rows_compose_to_whole_scene():
    """Windowed reads are exact row slices of the materialised scene —
    the determinism GranuleReader (and resume byte-identity) rests on."""
    whole = scenes.scene(50, 40, seed=9, cell=8)
    for row0, row1 in [(0, 50), (0, 7), (7, 20), (49, 50), (13, 13)]:
        np.testing.assert_array_equal(
            scenes.scene_rows(50, 40, row0, row1, seed=9, cell=8),
            whole[row0:row1])


def test_scene_is_binary_and_seed_sensitive():
    a = scenes.scene(32, 32, seed=0, cell=8)
    b = scenes.scene(32, 32, seed=1, cell=8)
    assert a.dtype == np.uint8 and set(np.unique(a)) <= {0, 1}
    assert not np.array_equal(a, b)


# ----------------------------------------------------------------- reader


def test_reader_tiles_cover_scene_with_inert_padding():
    mask = scenes.scene(21, 16, seed=2, cell=4)
    reader = GranuleReader.from_array(mask, 8)
    assert reader.n_tiles == 3
    assert reader.tile_rows(2) == (16, 21)
    rebuilt = np.concatenate([reader.read_tile(t) for t in range(3)])
    np.testing.assert_array_equal(rebuilt[:21], mask)
    assert not rebuilt[21:].any()   # zero padding only


def test_read_stack_matches_individual_tiles():
    mask = scenes.scene(30, 12, seed=3, cell=4)
    reader = GranuleReader.from_array(mask, 7)
    stack = reader.read_stack(1, 3)
    for i in range(3):
        np.testing.assert_array_equal(stack[i], reader.read_tile(1 + i))
    with pytest.raises(IndexError):
        reader.read_stack(3, 3)


def test_memmap_reader_matches_in_memory(tmp_path):
    mask = scenes.scene(25, 10, seed=4, cell=4)
    path = os.path.join(tmp_path, "granule.npy")
    np.save(path, mask)
    mem = GranuleReader.from_array(mask, 6)
    mm = GranuleReader.from_npy(path, 6)
    for t in range(mem.n_tiles):
        np.testing.assert_array_equal(mm.read_tile(t), mem.read_tile(t))


def test_spec_open_memmap_validates_shape(tmp_path):
    path = os.path.join(tmp_path, "g.npy")
    np.save(path, scenes.scene(20, 10, seed=0))
    spec = GranuleSpec(granule_id="g", height=99, width=10, kind="memmap",
                       path=path)
    with pytest.raises(ValueError, match="manifest says"):
        GranuleReader.open(spec, 8)


def test_manifest_json_round_trip():
    manifest = synthetic_manifest(3, 64, 32, seed=5, cell=16, coverage=0.3)
    assert manifest_from_json(manifest_to_json(manifest)) == manifest
    ids = [s.granule_id for s in manifest]
    assert len(set(ids)) == 3   # distinct ids, distinct seeds
    assert len({s.seed for s in manifest}) == 3


def test_spec_validation():
    with pytest.raises(ValueError, match="memmap"):
        GranuleSpec(granule_id="g", height=4, width=4, kind="memmap")
    with pytest.raises(ValueError, match="kind"):
        GranuleSpec(granule_id="g", height=4, width=4, kind="tarball")
    with pytest.raises(ValueError):
        GranuleSpec(granule_id="g", height=0, width=4)


# ----------------------------------------------------------------- stitch


def test_seam_joins_counts_crossing_runs_only():
    bottom = np.array([1, 0, 1, 0, 5], np.uint8)
    top = np.array([1, 1, 0, 0, 1], np.uint8)
    np.testing.assert_array_equal(seam_joins(bottom, top),
                                  np.array([1, 0, 0, 0, 1], np.int32))


@pytest.mark.parametrize("h,w,tile_h,stack", [
    (45, 32, 16, 2),   # ragged last strip, mid stack
    (37, 51, 8, 4),    # ragged, stack > strips per granule end
    (64, 24, 64, 1),   # one strip == whole scene
    (5, 9, 2, 3),      # tiny, stack overshoots
    (33, 16, 1, 4),    # single-row strips: every boundary is a seam
])
def test_stitched_scene_bit_identical_to_whole_scene(h, w, tile_h, stack):
    """The tentpole bar: streaming + seam stitching reproduces the
    whole-scene analysis exactly, every field, dtypes included."""
    mask = scenes.scene(h, w, seed=h * 100 + w, cell=8)
    engine = Engine()
    reader = GranuleReader.from_array(mask, tile_h)
    got = SceneRunner(engine, stack_tiles=stack).analyze_scene(reader)
    _assert_host_identical(got.to_host(), engine.analyze(mask).to_host(),
                           context=f"{h}x{w}/{tile_h}: ")


def test_stitched_scene_bit_identical_under_mesh():
    """Same bar with a mesh attached: stacks go through shard_map."""
    from repro.sharding import make_batch_mesh

    mask = scenes.scene(40, 16, seed=11, cell=8)
    engine = Engine(YCHGConfig(backend="auto"), mesh=make_batch_mesh())
    reader = GranuleReader.from_array(mask, 8)
    got = SceneRunner(engine, stack_tiles=3).analyze_scene(reader)
    _assert_host_identical(got.to_host(),
                           Engine().analyze(mask).to_host())


def test_stitch_tile_runs_matches_scene_runs():
    """Per-tile runs analysed independently (the online/NDJSON replay
    path) stitch to the same run vector the streaming runner produces."""
    mask = scenes.scene(29, 14, seed=6, cell=4)
    engine = Engine()
    reader = GranuleReader.from_array(mask, 6)
    tiles = [reader.read_tile(t) for t in range(reader.n_tiles)]
    tile_runs = [np.asarray(engine.analyze(t).to_host()["runs"])
                 for t in tiles]
    whole = np.asarray(engine.analyze(mask).to_host()["runs"])
    np.testing.assert_array_equal(stitch_tile_runs(tile_runs, tiles), whole)
    with pytest.raises(ValueError, match="run vectors"):
        stitch_tile_runs(tile_runs[:-1], tiles)


def test_progress_counters_accumulate():
    progress = SceneProgress()
    mask = scenes.scene(24, 8, seed=7, cell=4)
    reader = GranuleReader.from_array(mask, 8)
    SceneRunner(stack_tiles=2).analyze_scene(reader, progress=progress)
    snap = progress.snapshot()
    assert snap.tiles_done == reader.n_tiles
    assert snap.stitch_time_s > 0.0
    assert snap.resumes == 0


# ------------------------------------------------------------ result files


def test_scene_result_bytes_round_trip_and_deterministic(tmp_path):
    mask = scenes.scene(20, 12, seed=8, cell=4)
    result = SceneRunner().analyze_scene(GranuleReader.from_array(mask, 8))
    blob = result.to_bytes()
    assert blob == result.to_bytes()   # content-determined, no timestamps
    back = SceneResult.from_bytes(blob)
    _assert_host_identical(back.to_host(), result.to_host())
    assert (back.granule_id, back.height, back.width, back.tile_h,
            back.n_tiles) == (result.granule_id, result.height,
                              result.width, result.tile_h, result.n_tiles)

    path = os.path.join(tmp_path, "a", "r.ychg")
    write_scene_result(path, result)
    write_scene_result(path, result)   # rewrite: same bytes, atomic
    with open(path, "rb") as f:
        assert f.read() == blob
    _assert_host_identical(read_scene_result(path).to_host(),
                           result.to_host())
    with pytest.raises(ValueError, match="magic"):
        SceneResult.from_bytes(b"not a scene result")
    with pytest.raises(ValueError, match="trailing"):
        SceneResult.from_bytes(blob + b"x")


# -------------------------------------------------------------- bulk jobs


def _job(tmp_path, tag, manifest, progress=None, **cfg):
    knobs = dict(out_dir=os.path.join(tmp_path, tag, "out"),
                 ckpt_dir=os.path.join(tmp_path, tag, "ckpt"),
                 tile_h=8, stack_tiles=1, checkpoint_every=1)
    knobs.update(cfg)
    return BulkJob(Engine(), manifest, BulkJobConfig(**knobs),
                   progress=progress)


def _read_bytes(path):
    with open(path, "rb") as f:
        return f.read()


def test_bulk_job_outputs_match_direct_analysis(tmp_path):
    manifest = synthetic_manifest(2, 21, 10, seed=20, cell=4)
    job = _job(tmp_path, "direct", manifest)
    report = job.run()
    assert report.completed and report.granules_done == 2
    engine = Engine()
    for spec in manifest:
        got = read_scene_result(job.output_path(spec))
        whole = scenes.scene(spec.height, spec.width, seed=spec.seed,
                             cell=spec.cell, coverage=spec.coverage)
        _assert_host_identical(got.to_host(),
                               engine.analyze(whole).to_host(),
                               context=spec.granule_id + ": ")


@pytest.mark.parametrize("stop_after", [1, 3, 5])
def test_bulk_job_resume_is_byte_identical(tmp_path, stop_after):
    """Kill anywhere (granule boundary, mid-granule, first stack): the
    resumed job's output files are byte-for-byte the uninterrupted run's."""
    manifest = synthetic_manifest(2, 20, 12, seed=30, cell=4)
    straight = _job(tmp_path, "straight", manifest)
    assert straight.run().completed

    progress = SceneProgress()
    interrupted = _job(tmp_path, f"kill{stop_after}", manifest, progress)
    first = interrupted.run(max_stacks=stop_after)
    assert first.status == "interrupted"
    second = _job(tmp_path, f"kill{stop_after}", manifest, progress).run()
    assert second.completed
    assert second.resumes == 1
    assert progress.snapshot().resumes == 1
    for spec in manifest:
        assert _read_bytes(interrupted.output_path(spec)) == \
            _read_bytes(straight.output_path(spec)), spec.granule_id


def test_bulk_job_resume_after_corrupt_newest_checkpoint(tmp_path):
    """A torn newest checkpoint costs one interval, not the job: resume
    warns, falls back to the previous step, and stays byte-identical."""
    manifest = synthetic_manifest(1, 40, 10, seed=40, cell=4)
    straight = _job(tmp_path, "straight", manifest)
    assert straight.run().completed

    killed = _job(tmp_path, "killed", manifest)
    assert killed.run(max_stacks=3).status == "interrupted"
    ckpt_dir = os.path.join(tmp_path, "killed", "ckpt")
    newest = sorted(d for d in os.listdir(ckpt_dir)
                    if d.startswith("step_"))[-1]
    shard = [f for f in os.listdir(os.path.join(ckpt_dir, newest))
             if f.endswith(".npz")][0]
    with open(os.path.join(ckpt_dir, newest, shard), "r+b") as f:
        f.truncate(8)
    with pytest.warns(RuntimeWarning):
        second = _job(tmp_path, "killed", manifest).run()
    assert second.completed and second.resumes == 1
    spec = manifest[0]
    assert _read_bytes(killed.output_path(spec)) == \
        _read_bytes(straight.output_path(spec))


def test_bulk_job_checkpoints_are_gced_to_keep(tmp_path):
    manifest = synthetic_manifest(1, 48, 8, seed=50, cell=4)
    job = _job(tmp_path, "gc", manifest, keep=2)
    assert job.run().completed
    steps = [d for d in os.listdir(os.path.join(tmp_path, "gc", "ckpt"))
             if d.startswith("step_") and not d.endswith(".tmp")]
    assert len(steps) == 2


def test_bulk_job_finished_job_reruns_as_noop(tmp_path):
    manifest = synthetic_manifest(1, 16, 8, seed=60, cell=4)
    job = _job(tmp_path, "done", manifest)
    assert job.run().completed
    before = _read_bytes(job.output_path(manifest[0]))
    again = _job(tmp_path, "done", manifest).run()
    assert again.completed and again.stacks_done == 0
    assert _read_bytes(job.output_path(manifest[0])) == before


def test_bulk_job_rejects_bad_manifests(tmp_path):
    cfg = BulkJobConfig(out_dir=str(tmp_path / "o"),
                        ckpt_dir=str(tmp_path / "c"))
    with pytest.raises(ValueError, match="empty"):
        BulkJob(Engine(), [], cfg)
    spec = synthetic_manifest(1, 8, 8)[0]
    with pytest.raises(ValueError, match="duplicate"):
        BulkJob(Engine(), [spec, spec], cfg)


def test_bulk_job_detects_manifest_width_change(tmp_path):
    manifest = synthetic_manifest(1, 32, 8, seed=70, cell=4)
    job = _job(tmp_path, "w", manifest)
    assert job.run(max_stacks=1).status == "interrupted"
    wider = [dataclasses.replace(manifest[0], width=16)]
    with pytest.raises(ValueError, match="wide"):
        _job(tmp_path, "w", wider).run()


# -------------------------------------------- online/offline (loopback)


def test_online_tiles_agree_with_offline_scene():
    """Tiles replayed through the HTTP front end (NDJSON batch endpoint)
    are per-tile bit-identical to engine.analyze, and their stitched runs
    equal the offline streaming result — the scene-smoke leg as a test."""
    from repro.frontend import ServerThread, YCHGClient
    from repro.service import ServiceConfig, YCHGService

    mask = scenes.scene(20, 16, seed=80, cell=8)
    engine = Engine()
    reader = GranuleReader.from_array(mask, 8)
    tiles = [reader.read_tile(t) for t in range(reader.n_tiles)]
    offline = SceneRunner(engine).analyze_scene(reader)

    progress = SceneProgress()
    progress.set_totals(tiles=reader.n_tiles, granules=1)
    progress.note_tiles(reader.n_tiles)
    cfg = ServiceConfig(bucket_sides=(16,), max_batch=len(tiles))
    with YCHGService(engine, cfg) as svc, \
            ServerThread(svc) as srv, \
            YCHGClient("127.0.0.1", srv.port) as client:
        svc.attach_scene_progress(progress)
        items = {it.id: it for it in client.analyze_batch(tiles)}
        assert all(it.ok for it in items.values())
        for i, tile in enumerate(tiles):
            _assert_host_identical(items[i].result,
                                   engine.analyze(tile).to_host(),
                                   context=f"tile {i}: ")
        online_runs = stitch_tile_runs(
            [items[i].result["runs"] for i in range(len(tiles))], tiles)
        np.testing.assert_array_equal(online_runs,
                                      np.asarray(offline.runs))
        m = svc.metrics()
        assert m.scene_tiles_done == reader.n_tiles
        assert m.scene_tiles_total == reader.n_tiles
        text = client.metrics_text()
    assert "ychg_scene_tiles_done" in text
    assert "ychg_scene_resumes_total" in text
