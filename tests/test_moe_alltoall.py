"""shard_map all-to-all MoE (§Perf optimized path) vs the pjit dispatch
baseline — numerical equivalence on a multi-device (forced host) mesh.

Runs in a subprocess: device count is locked at first jax init.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS","") + \
        " --xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import ModelConfig, LayerSpec
    from repro.models import init_params, forward, loss_fn, param_logical_axes
    from repro.models.layers import Sharder
    from repro.sharding import logical

    cfg_d = ModelConfig(name="t-moe", family="moe", num_layers=2, d_model=32,
                        num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=97,
                        num_experts=4, experts_per_token=2,
                        layer_pattern=(LayerSpec("attn","moe"),),
                        moe_capacity_factor=16.0, activation_dtype="float32",
                        param_dtype="float32", remat="none", attn_chunk=64)
    cfg_a = cfg_d.scaled(moe_impl="alltoall")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = logical.make_rules("train")
    params = init_params(cfg_d, jax.random.PRNGKey(0))
    shards = logical.tree_shardings(param_logical_axes(cfg_d), rules, mesh, params)
    params_sh = jax.device_put(params, shards)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 97)
    tokens = jax.device_put(tokens, NamedSharding(mesh, P("data", None)))
    shd = Sharder(mesh, rules)
    ld = jax.jit(lambda p, t: forward(p, cfg_d, t, shd)[0])(params_sh, tokens)
    la = jax.jit(lambda p, t: forward(p, cfg_a, t, shd)[0])(params_sh, tokens)
    err = float(jnp.max(jnp.abs(ld - la)))
    assert err < 1e-3, err
    # gradient flows through the a2a path (seq divisible by model axis)
    g = jax.jit(jax.grad(lambda p: loss_fn(
        p, cfg_a, tokens, jnp.roll(tokens, -1, 1), shd)[0]))(params_sh)
    gn = sum(float(jnp.sum(x.astype(jnp.float32)**2))
             for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0
    print("A2A_OK", err)
""")


@pytest.mark.slow
def test_alltoall_matches_dispatch():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "A2A_OK" in r.stdout
