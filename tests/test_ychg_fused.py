"""Parity suite: fused batched Pallas kernel vs the core.ychg oracle.

Acceptance bar: ``kernels.ops.analyze_fused`` is BIT-identical to
``core.ychg.analyze`` — same dtypes, shapes, values — across the shape x
dtype sweep, batch dims, degenerate masks, and streamed-carry edge cases
(H/W not multiples of the block sizes).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import ychg
from repro.kernels import ops
from repro.kernels.ychg_fused import fused_analyze_pallas, fused_analyze_streamed
from ychg_invariants import SUMMARY_FIELDS as FIELDS, assert_bit_identical

SHAPES = [(1, 1), (7, 5), (16, 128), (33, 200), (128, 384), (257, 131), (5, 1024)]
DTYPES = [np.uint8, np.int32, np.bool_, np.float32]


def _dict_vs_oracle(got: dict, imgs: np.ndarray):
    want = ychg.analyze(jnp.asarray(imgs))
    for k, w in (("runs", want.runs), ("transitions", want.transitions),
                 ("births", want.births), ("deaths", want.deaths),
                 ("n_hyperedges", want.n_hyperedges),
                 ("n_transitions", want.n_transitions)):
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(w), err_msg=k)


# ------------------------------------------------------------ shape x dtype


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fused_parity_single_image(shape, dtype):
    rng = np.random.default_rng(hash((shape, str(dtype))) % 2**32)
    img = (rng.random(shape) < 0.45).astype(dtype)
    assert_bit_identical(ops.analyze_fused(jnp.asarray(img)),
                         ychg.analyze(jnp.asarray(img)))


@pytest.mark.parametrize("batch", [1, 2, 5])
def test_fused_parity_batched(batch):
    rng = np.random.default_rng(batch)
    imgs = (rng.random((batch, 33, 200)) < 0.5).astype(np.uint8)
    assert_bit_identical(ops.analyze_fused(jnp.asarray(imgs)),
                         ychg.analyze(jnp.asarray(imgs)))


def test_fused_batch_heterogeneous_images():
    """Images in one launch must not leak carry state into each other: an
    all-foreground image sits between two structured ones."""
    rng = np.random.default_rng(9)
    a = (rng.random((40, 260)) < 0.3).astype(np.uint8)
    b = np.ones((40, 260), np.uint8)
    c = (rng.random((40, 260)) < 0.9).astype(np.uint8)
    imgs = np.stack([a, b, c])
    assert_bit_identical(ops.analyze_fused(jnp.asarray(imgs)),
                         ychg.analyze(jnp.asarray(imgs)))


# -------------------------------------------------------------- degenerate


@pytest.mark.parametrize("fill", [0, 1])
def test_fused_constant_masks(fill):
    imgs = np.full((3, 19, 141), fill, np.uint8)
    assert_bit_identical(ops.analyze_fused(jnp.asarray(imgs)),
                         ychg.analyze(jnp.asarray(imgs)))


def test_fused_single_column():
    rng = np.random.default_rng(11)
    imgs = (rng.random((2, 200, 1)) < 0.5).astype(np.uint8)
    assert_bit_identical(ops.analyze_fused(jnp.asarray(imgs)),
                         ychg.analyze(jnp.asarray(imgs)))


def test_fused_single_row():
    rng = np.random.default_rng(12)
    imgs = (rng.random((2, 1, 300)) < 0.5).astype(np.uint8)
    assert_bit_identical(ops.analyze_fused(jnp.asarray(imgs)),
                         ychg.analyze(jnp.asarray(imgs)))


# ----------------------------------------------------------- streamed carry


@pytest.mark.parametrize("block_h", [4, 16, 64])
def test_fused_streamed_carry(block_h):
    """H-block seams must not double-count runs; W-tile seams must diff
    against the true left neighbour."""
    rng = np.random.default_rng(1)
    imgs = (rng.random((2, 130, 140)) < 0.6).astype(np.uint8)
    got = fused_analyze_streamed(jnp.asarray(imgs), block_h=block_h)
    _dict_vs_oracle(got, imgs)


@pytest.mark.parametrize("shape", [(2, 33, 129), (1, 130, 257), (3, 257, 131)])
def test_fused_streamed_nonmultiple_blocks(shape):
    """H and W deliberately not multiples of (block_h, block_w)."""
    rng = np.random.default_rng(sum(shape))
    imgs = (rng.random(shape) < 0.5).astype(np.uint8)
    got = fused_analyze_streamed(jnp.asarray(imgs), block_w=128, block_h=16)
    _dict_vs_oracle(got, imgs)


def test_fused_streamed_boundary_run():
    """A single run crossing every H-block boundary (all-ones columns)."""
    imgs = np.ones((2, 64, 8), np.uint8)
    got = fused_analyze_streamed(jnp.asarray(imgs), block_h=16)
    np.testing.assert_array_equal(np.asarray(got["runs"]),
                                  np.ones((2, 8), np.int32))
    np.testing.assert_array_equal(np.asarray(got["n_hyperedges"]), [1, 1])


def test_fused_streamed_matches_full():
    rng = np.random.default_rng(2)
    imgs = (rng.random((2, 96, 200)) < 0.5).astype(np.uint8)
    full = fused_analyze_pallas(jnp.asarray(imgs))
    streamed = fused_analyze_streamed(jnp.asarray(imgs), block_h=32)
    for k in full:
        np.testing.assert_array_equal(np.asarray(full[k]),
                                      np.asarray(streamed[k]), err_msg=k)


def test_fused_budget_routes_to_streamed(monkeypatch):
    """analyze_fused must switch to the streamed variant past the VMEM
    budget and stay bit-identical."""
    monkeypatch.setattr(ops, "_FULL_COLUMN_VMEM_BUDGET", 1)
    rng = np.random.default_rng(3)
    imgs = (rng.random((2, 70, 150)) < 0.5).astype(np.uint8)
    assert_bit_identical(ops.analyze_fused(jnp.asarray(imgs), block_h=32),
                         ychg.analyze(jnp.asarray(imgs)))


# ------------------------------------------------------- wrappers / routing


def test_sharded_wrapper_parity():
    from repro.sharding import batch_sharded_analyze

    rng = np.random.default_rng(4)
    imgs = (rng.random((5, 33, 200)) < 0.5).astype(np.uint8)
    assert_bit_identical(batch_sharded_analyze(jnp.asarray(imgs)),
                         ychg.analyze(jnp.asarray(imgs)))


def test_pad_batch_is_inert():
    from repro.sharding import pad_batch

    rng = np.random.default_rng(5)
    imgs = (rng.random((5, 20, 30)) < 0.5).astype(np.uint8)
    padded, b = pad_batch(jnp.asarray(imgs), 4)
    assert b == 5 and padded.shape[0] == 8
    s = ops.analyze_fused(padded)
    assert int(np.asarray(s.n_hyperedges)[b:].sum()) == 0
    assert_bit_identical(
        ychg.YCHGSummary(*[getattr(s, f)[:b] for f in FIELDS]),
        ychg.analyze(jnp.asarray(imgs)),
    )


def test_pipeline_backends_agree():
    from repro.data.pipeline import ychg_stats

    rng = np.random.default_rng(6)
    masks = (rng.random((7, 32, 48)) < 0.4).astype(np.uint8)
    fused = ychg_stats(masks, backend="fused")
    jnp_ = ychg_stats(masks, backend="jnp")
    auto = ychg_stats(masks)  # "auto": fused on TPU, jnp elsewhere
    for k in fused:
        np.testing.assert_array_equal(fused[k], jnp_[k], err_msg=k)
        np.testing.assert_array_equal(auto[k], jnp_[k], err_msg=k)
    with pytest.raises(ValueError):
        ychg_stats(masks, backend="nope")


def test_api_fused_backend_matches_jax():
    from repro.core.api import analyze_image

    rng = np.random.default_rng(7)
    img = (rng.random((45, 77)) < 0.5).astype(np.uint8)
    a = analyze_image(img, backend="jax")
    b = analyze_image(img, backend="fused")
    for k in a:
        assert a[k].dtype == b[k].dtype, k
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
