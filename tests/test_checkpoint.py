"""`repro.checkpoint` suite: atomic saves, GC, and crash-recovery fallback.

Policy (tests/README.md §Checkpoint tests): corruption is *simulated
deliberately* — truncating a shard zip, rewriting a manifest with partial
JSON, pointing LATEST at a deleted directory — never produced by racing a
writer. Each recovery case asserts two things: the fallback **result**
(``latest_step`` lands on the newest checkpoint that still validates) and
the fallback **signal** (a ``RuntimeWarning`` naming the skipped step), so
a silent wrong-restore can never pass. Restores compare bit-identically
(values, dtypes, shapes) against the saved host arrays.

The corruption cases here were written against the pre-hardening
``Checkpointer`` (which trusted LATEST blindly and crashed in ``restore``)
and fail on it; they pin the fallback contract ``repro.scene.BulkJob``
relies on for kill-anywhere resumability.
"""

import json
import os

import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _tree(seed=0):
    """Nested pytree with mixed dtypes/shapes (no int64/float64: x64 is
    off, restore round-trips through jnp.asarray)."""
    rng = np.random.default_rng(seed)
    return {
        "state": {
            "runs": rng.integers(0, 100, 37).astype(np.int32),
            "carry": (rng.random(37) < 0.5).astype(np.uint8),
        },
        "meta": [np.int32(seed), np.float32(seed / 2)],
        "scalar": np.zeros((), np.int32) + seed,
    }


def _assert_tree_equal(got, want):
    import jax

    g_leaves = jax.tree_util.tree_leaves(got)
    w_leaves = jax.tree_util.tree_leaves(want)
    assert len(g_leaves) == len(w_leaves)
    for g, w in zip(g_leaves, w_leaves):
        g, w = np.asarray(g), np.asarray(w)
        assert g.dtype == w.dtype
        assert g.shape == w.shape
        np.testing.assert_array_equal(g, w)


def _corrupt_shard(ckpt_dir, step):
    """Truncate a step's first shard: the zip central directory is at the
    end of the file, so this is unreadable, like a torn disk write."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    shard = sorted(f for f in os.listdir(d) if f.endswith(".npz"))[0]
    with open(os.path.join(d, shard), "r+b") as f:
        f.truncate(8)


# -------------------------------------------------------------- round trip


def test_save_restore_round_trip(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    tree = _tree(seed=3)
    ckpt.save(100, tree)
    assert ckpt.latest_step() == 100
    _assert_tree_equal(ckpt.restore(100, like=tree), tree)


def test_async_save_wait_then_restore(tmp_path):
    ckpt = Checkpointer(str(tmp_path), async_save=True)
    tree = _tree(seed=4)
    ckpt.save(7, tree)
    ckpt.wait()   # flush: the write thread owns the files until joined
    assert ckpt.latest_step() == 7
    _assert_tree_equal(ckpt.restore(7, like=tree), tree)


def test_keep_gc_drops_oldest(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        ckpt.save(step, _tree(seed=step))
    names = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert names == ["step_00000003", "step_00000004"]
    assert ckpt.latest_step() == 4


def test_latest_none_on_empty_dir(tmp_path):
    assert Checkpointer(str(tmp_path)).latest_step() is None


# ------------------------------------------------- crash-recovery fallback
# These cases fail on the pre-hardening Checkpointer: it either returned
# the corrupt step (restore then crashed the job) or raised outright.


def test_corrupt_newest_shard_falls_back_with_warning(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    good = _tree(seed=1)
    ckpt.save(1, good)
    ckpt.save(2, _tree(seed=2))
    _corrupt_shard(str(tmp_path), 2)
    with pytest.warns(RuntimeWarning, match="step_00000002"):
        assert ckpt.latest_step() == 1
    _assert_tree_equal(ckpt.restore(1, like=good), good)


def test_truncated_manifest_json_falls_back(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, _tree(seed=1))
    ckpt.save(2, _tree(seed=2))
    man = os.path.join(tmp_path, "step_00000002", "manifest.json")
    with open(man) as f:
        text = f.read()
    with open(man, "w") as f:
        f.write(text[: len(text) // 2])   # kill mid-json.dump
    with pytest.warns(RuntimeWarning):
        assert ckpt.latest_step() == 1


def test_manifest_without_done_flag_is_invalid(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, _tree(seed=1))
    ckpt.save(2, _tree(seed=2))
    man = os.path.join(tmp_path, "step_00000002", "manifest.json")
    with open(man) as f:
        m = json.load(f)
    del m["done"]
    with open(man, "w") as f:
        json.dump(m, f)
    with pytest.warns(RuntimeWarning):
        assert ckpt.latest_step() == 1


def test_latest_pointing_at_missing_dir_falls_back(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(5, _tree(seed=5))
    with open(os.path.join(tmp_path, "LATEST"), "w") as f:
        f.write("step_00000099")   # pointer updated, dir lost
    with pytest.warns(RuntimeWarning, match="step_00000099"):
        assert ckpt.latest_step() == 5


def test_leftover_tmp_dir_is_ignored(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(3, _tree(seed=3))
    # a kill between staging and the atomic rename leaves only .tmp
    os.makedirs(os.path.join(tmp_path, "step_00000009.tmp"))
    assert ckpt.latest_step() == 3


def test_all_checkpoints_corrupt_returns_none(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, _tree(seed=1))
    _corrupt_shard(str(tmp_path), 1)
    with pytest.warns(RuntimeWarning):
        assert ckpt.latest_step() is None


def test_missing_shard_file_is_invalid(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, _tree(seed=1))
    ckpt.save(2, _tree(seed=2))
    d = os.path.join(tmp_path, "step_00000002")
    for f in os.listdir(d):
        if f.endswith(".npz"):
            os.remove(os.path.join(d, f))
    with pytest.warns(RuntimeWarning):
        assert ckpt.latest_step() == 1
