"""Engine / registry suite.

Covers the engine acceptance bar:
  * every registered backend is bit-identical to ``core.ychg.analyze`` on
    the seeded corpus (single image AND batched, through the engine);
  * ``backend="auto"`` resolution is a pure function of the registry
    (jax on CPU, fused on a fake-TPU capability entry, fused under a mesh);
  * results are device-resident pytrees — the fused/jax paths trace under
    ``jit`` (any implicit device->host copy would raise);
  * the mesh path strips blank-image padding internally for non-divisible
    batch sizes (4-device subprocess regression);
  * the deprecated ``core.api.analyze_image`` shim still returns the exact
    legacy dict and warns.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import serial, ychg
from repro.engine import (
    YCHGConfig,
    Engine,
    YCHGResult,
    backend_names,
    get_backend,
    registry,
    resolve,
)
from repro.kernels import ops as kops
from ychg_invariants import assert_bit_identical, random_masks, structured_masks

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ALL_BACKENDS = ("jax", "fused", "pallas", "serial", "scalar")


def _corpus():
    return structured_masks() + random_masks(8)


# ----------------------------------------------------------------- registry


def test_registry_has_all_builtin_backends():
    assert set(ALL_BACKENDS) <= set(backend_names())


def test_auto_resolution_cpu_picks_jax():
    assert resolve("auto", platform="cpu").name == "jax"


def test_auto_resolution_fake_tpu_picks_fused():
    """No TPU in CI: the registry's tpu capability entry drives resolution."""
    assert resolve("auto", platform="tpu").name == "fused"


def test_auto_resolution_with_mesh_picks_mesh_capable():
    assert resolve("auto", platform="cpu", need_mesh=True).supports_mesh
    assert resolve("auto", platform="cpu", need_mesh=True).name == "fused"


def test_resolution_rejects_unknown_and_meshless():
    with pytest.raises(ValueError, match="unknown backend"):
        resolve("nope", platform="cpu")
    with pytest.raises(ValueError, match="does not support mesh"):
        resolve("serial", platform="cpu", need_mesh=True)


def test_register_backend_validates_priority_kinds():
    with pytest.raises(ValueError, match="device_kinds"):
        registry.register_backend(registry.BackendSpec(
            name="bogus", run=lambda x, c: None, supports_batch=True,
            supports_mesh=False, device_kinds=("cpu",), priority={"tpu": 1},
        ))
    assert "bogus" not in backend_names()


def test_register_unregister_roundtrip_and_cache_invalidation():
    """A registered backend is live immediately (even for engines built
    earlier) and gone after unregister — the generation counter invalidates
    both the lru_cache and per-engine spec caches."""
    fixed = ychg.analyze(jnp.ones((1, 2, 3), jnp.uint8))
    eng = Engine(YCHGConfig(backend="auto"))
    assert eng.resolve_backend() == "jax"  # prime the instance cache
    registry.register_backend(registry.BackendSpec(
        name="_test_stub", run=lambda x, c: fixed, supports_batch=True,
        supports_mesh=False, device_kinds=("cpu",), priority={"cpu": 999},
    ))
    try:
        assert "_test_stub" in backend_names()
        assert eng.resolve_backend() == "_test_stub"  # cache invalidated
    finally:
        registry.unregister_backend("_test_stub")
    assert "_test_stub" not in backend_names()
    assert eng.resolve_backend() == "jax"
    registry.unregister_backend("_test_stub")  # unknown name: no-op


def test_engine_resolves_per_platform():
    assert Engine().resolve_backend() == (
        "fused" if jax.default_backend() == "tpu" else "jax"
    )


# ----------------------------------------------------- backend parity suite


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_backend_parity_on_corpus(backend):
    """Every registered backend, through the engine, bit-identical to the
    core.ychg oracle on the seeded corpus."""
    engine = Engine(YCHGConfig(backend=backend))
    for img in _corpus():
        want = ychg.analyze(jnp.asarray(img))
        got = engine.analyze(img).to_summary()
        assert_bit_identical(got, want)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_backend_parity_batched(backend):
    rng = np.random.default_rng(42)
    imgs = (rng.random((5, 21, 34)) < 0.5).astype(np.uint8)
    engine = Engine(YCHGConfig(backend=backend))
    assert_bit_identical(engine.analyze_batch(imgs).to_summary(),
                         ychg.analyze(jnp.asarray(imgs)))


def test_single_image_is_b1_view():
    """analyze is the batched path with B=1 — not a separate code path."""
    rng = np.random.default_rng(0)
    img = (rng.random((19, 27)) < 0.5).astype(np.uint8)
    engine = Engine()
    one = engine.analyze(img)
    batch = engine.analyze_batch(img[None])
    assert one.runs.shape == batch.runs.shape == (1, 27)
    assert not one.batched and batch.batched
    np.testing.assert_array_equal(np.asarray(one.runs), np.asarray(batch.runs))


# ------------------------------------------------------- result pytree/host


def test_result_is_registered_pytree():
    rng = np.random.default_rng(1)
    imgs = (rng.random((3, 9, 13)) < 0.5).astype(np.uint8)
    res = Engine().analyze_batch(imgs)
    leaves, treedef = jax.tree_util.tree_flatten(res)
    assert len(leaves) == 7
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(rebuilt, YCHGResult) and rebuilt.batched
    mapped = jax.tree_util.tree_map(lambda x: x, res)
    assert mapped.batched == res.batched  # static aux survives tree_map


@pytest.mark.parametrize("backend", ["jax", "fused"])
def test_device_backends_trace_under_jit(backend):
    """Device residency: any implicit np.asarray/device->host copy inside
    the engine would raise TracerArrayConversionError here."""
    rng = np.random.default_rng(2)
    imgs = jnp.asarray((rng.random((2, 17, 23)) < 0.5).astype(np.uint8))
    engine = Engine(YCHGConfig(backend=backend))
    res = jax.jit(engine.analyze_batch)(imgs)
    assert_bit_identical(res.to_summary(), ychg.analyze(imgs))


def test_results_stay_on_device():
    rng = np.random.default_rng(3)
    img = (rng.random((11, 29)) < 0.5).astype(np.uint8)
    res = Engine(YCHGConfig(backend="fused")).analyze(jnp.asarray(img))
    for leaf in jax.tree_util.tree_leaves(res):
        assert isinstance(leaf, jax.Array)


def test_to_host_matches_legacy_dict_form():
    rng = np.random.default_rng(4)
    img = (rng.random((31, 15)) < 0.5).astype(np.uint8)
    d = Engine().analyze(img).to_host()
    s = ychg.analyze(jnp.asarray(img))
    assert set(d) == {"runs", "cut_vertices", "transitions", "births",
                      "deaths", "n_hyperedges", "n_transitions"}
    for k in d:
        assert isinstance(d[k], np.ndarray)
        w = np.asarray(getattr(s, k))
        assert d[k].dtype == w.dtype and d[k].shape == w.shape
        np.testing.assert_array_equal(d[k], w, err_msg=k)


# ------------------------------------------------------------ verbs / config


def test_analyze_rejects_wrong_rank():
    engine = Engine()
    with pytest.raises(ValueError, match=r"\(H, W\)"):
        engine.analyze(np.zeros((2, 3, 4), np.uint8))
    with pytest.raises(ValueError, match=r"\(B, H, W\)"):
        engine.analyze_batch(np.zeros((3, 4), np.uint8))


def test_analyze_stream_mixed_items():
    rng = np.random.default_rng(5)
    img = (rng.random((12, 18)) < 0.5).astype(np.uint8)
    stack = (rng.random((3, 12, 18)) < 0.5).astype(np.uint8)
    engine = Engine()
    outs = list(engine.analyze_stream(iter([img, stack])))
    assert [o.runs.shape for o in outs] == [(1, 18), (3, 18)]
    assert_bit_identical(outs[1].to_summary(), ychg.analyze(jnp.asarray(stack)))


def test_config_is_frozen_and_hashable():
    cfg = YCHGConfig(backend="fused", block_w=64)
    assert hash(cfg) == hash(YCHGConfig(backend="fused", block_w=64))
    with pytest.raises(Exception):
        cfg.backend = "jax"  # type: ignore[misc]


def test_config_stream_vmem_budget_routes_to_streamed():
    """The engine's streaming threshold reaches the fused kernel dispatch."""
    rng = np.random.default_rng(6)
    imgs = (rng.random((2, 70, 150)) < 0.5).astype(np.uint8)
    engine = Engine(YCHGConfig(backend="fused", stream_vmem_budget=1,
                                   block_h=32))
    assert_bit_identical(engine.analyze_batch(imgs).to_summary(),
                         ychg.analyze(jnp.asarray(imgs)))


def test_config_dtype_casts_on_ingest():
    img = np.array([[0, 2], [3, 0]], np.int64)
    res = Engine(YCHGConfig(dtype="uint8")).analyze(img)
    assert_bit_identical(res.to_summary(),
                         ychg.analyze(jnp.asarray(img.astype(np.uint8))))


def test_workload_config_engine_section():
    from repro.configs.ychg_modis import config as workload_config

    wl = workload_config()
    cfg = wl.engine.to_engine_config(backend="fused")
    assert isinstance(cfg, YCHGConfig) and cfg.backend == "fused"
    assert cfg.block_w == wl.block_w and cfg.block_h == wl.block_h
    rng = np.random.default_rng(7)
    img = (rng.random((16, 24)) < 0.5).astype(np.uint8)
    assert_bit_identical(Engine(cfg).analyze(img).to_summary(),
                         ychg.analyze(jnp.asarray(img)))


# -------------------------------------------------------------- mesh path


def test_mesh_path_single_device_parity():
    from repro.sharding import make_batch_mesh

    rng = np.random.default_rng(8)
    imgs = (rng.random((5, 33, 40)) < 0.5).astype(np.uint8)
    engine = Engine(YCHGConfig(backend="auto"), mesh=make_batch_mesh())
    assert engine.resolve_backend() == "fused"
    res = engine.analyze_batch(imgs)
    assert res.batch_size == 5
    assert_bit_identical(res.to_summary(), ychg.analyze(jnp.asarray(imgs)))


def test_mesh_axis_mismatch_raises():
    from repro.sharding import make_batch_mesh

    with pytest.raises(ValueError, match="mesh_axis"):
        Engine(YCHGConfig(mesh_axis="batch"), mesh=make_batch_mesh("data"))


_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4"
    )
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core import ychg
    from repro.engine import Engine, YCHGConfig
    from repro.sharding import make_batch_mesh

    mesh = make_batch_mesh()
    assert mesh.size == 4, mesh
    rng = np.random.default_rng(0)
    imgs = (rng.random((5, 17, 33)) < 0.5).astype(np.uint8)  # 5 % 4 != 0
    engine = Engine(YCHGConfig(backend="fused"), mesh=mesh)
    res = engine.analyze_batch(jnp.asarray(imgs))
    # padding to 8 must be stripped internally: callers see B=5
    assert res.batch_size == 5, res.runs.shape
    want = ychg.analyze(jnp.asarray(imgs))
    for f in ("runs", "births", "deaths", "n_hyperedges", "n_transitions"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res.to_summary(), f)),
            np.asarray(getattr(want, f)), err_msg=f)
    print("MESH-OK")
""")


def test_mesh_path_nondivisible_batch_subprocess():
    """Regression: non-divisible batch over a real 4-device mesh — the
    engine pads to the mesh size and strips the pad before returning.
    Subprocess because the host device count locks at first jax init."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0 and "MESH-OK" in r.stdout, (
        r.stdout[-2000:] + r.stderr[-2000:])


# ---------------------------------------------------------- legacy shims


def _legacy_analyze_image(img, backend):
    """The pre-engine implementation of core.api.analyze_image, verbatim."""
    def summary_to_dict(s):
        return {
            "runs": np.asarray(s.runs),
            "cut_vertices": np.asarray(s.cut_vertices),
            "transitions": np.asarray(s.transitions),
            "births": np.asarray(s.births),
            "deaths": np.asarray(s.deaths),
            "n_hyperedges": np.asarray(s.n_hyperedges),
            "n_transitions": np.asarray(s.n_transitions),
        }

    if backend == "jax":
        return summary_to_dict(ychg.analyze_jit(img))
    if backend == "fused":
        return summary_to_dict(kops.analyze_fused(np.asarray(img)))
    if backend == "pallas":
        return {k: np.asarray(v) for k, v in kops.analyze(img).items()}
    if backend == "serial":
        return serial.analyze_numpy(np.asarray(img))
    return serial.analyze_scalar(np.asarray(img))


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_analyze_image_shim_equivalence(backend):
    """The deprecated shim returns the exact legacy dict (keys, dtypes,
    values) and emits DeprecationWarning."""
    from repro.core.api import analyze_image

    rng = np.random.default_rng(9)
    img = (rng.random((23, 37)) < 0.5).astype(np.uint8)
    with pytest.warns(DeprecationWarning):
        got = analyze_image(img, backend=backend)
    want = _legacy_analyze_image(img, backend)
    assert set(got) == set(want)
    for k in want:
        w = np.asarray(want[k])
        assert got[k].dtype == w.dtype, k
        assert got[k].shape == w.shape, k
        np.testing.assert_array_equal(got[k], w, err_msg=k)


def test_analyze_image_unknown_backend_message():
    from repro.core.api import BACKENDS, analyze_image

    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="unknown backend"):
            analyze_image(np.zeros((2, 2), np.uint8), backend="cuda")
    assert BACKENDS == ALL_BACKENDS


def test_ychg_engine_shim_warns_and_agrees():
    """`YCHGEngine` is a deprecation shim over the op-dispatching
    `Engine`: construction warns, behaviour (op, results, backend
    resolution) is exactly ``Engine()``'s."""
    from repro.engine import YCHGEngine

    rng = np.random.default_rng(12)
    img = (rng.random((19, 27)) < 0.5).astype(np.uint8)
    with pytest.warns(DeprecationWarning, match="YCHGEngine is deprecated"):
        shim = YCHGEngine()
    eng = Engine()
    assert isinstance(shim, Engine)
    assert shim.op == eng.op == "ychg"
    assert shim.resolve_backend() == eng.resolve_backend()
    assert_bit_identical(shim.analyze(img).to_summary(),
                         eng.analyze(img).to_summary())


def test_batch_sharded_analyze_shim_warns_and_agrees():
    from repro.sharding import batch_sharded_analyze

    rng = np.random.default_rng(10)
    imgs = (rng.random((3, 14, 22)) < 0.5).astype(np.uint8)
    with pytest.warns(DeprecationWarning):
        got = batch_sharded_analyze(jnp.asarray(imgs))
    assert_bit_identical(got, ychg.analyze(jnp.asarray(imgs)))


def test_ychg_stats_accepts_engine():
    from repro.data.pipeline import ychg_stats

    rng = np.random.default_rng(11)
    masks = (rng.random((4, 16, 20)) < 0.4).astype(np.uint8)
    via_engine = ychg_stats(masks, engine=Engine(YCHGConfig(backend="fused")))
    via_legacy = ychg_stats(masks, backend="jnp")
    for k in via_legacy:
        np.testing.assert_array_equal(via_engine[k], via_legacy[k], err_msg=k)


def test_fused_backend_accepts_device_arrays_without_host_copy():
    """Satellite regression: the old api forced np.asarray(img) before the
    fused kernel. The fused backend callable must consume a jax.Array
    as-is — tracing it proves no host round-trip exists on the path."""
    cfg = YCHGConfig(backend="fused")
    run = get_backend("fused").run
    rng = np.random.default_rng(12)
    imgs = jnp.asarray((rng.random((2, 9, 17)) < 0.5).astype(np.uint8))
    out = jax.jit(lambda x: run(x, cfg).n_hyperedges)(imgs)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ychg.analyze(imgs).n_hyperedges))
