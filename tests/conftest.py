"""Shared pytest config: markers + interpret-only environment detection.

This container (and CI) has no TPU: Pallas kernels execute with
``interpret=True`` (Python-level evaluation of the kernel body — exact, but
not Mosaic-compiled). Tests asserting compiled-mode behaviour (latency
bounds, VMEM limits) must carry ``@pytest.mark.tpu_only`` and are skipped
automatically here; correctness tests run everywhere.
"""

import jax
import pytest

# True when Pallas must run in interpret mode (no real TPU backend).
INTERPRET_ONLY = jax.default_backend() != "tpu"


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running (subprocess dry-run)")
    config.addinivalue_line(
        "markers",
        "tpu_only: needs a compiled TPU backend; auto-skipped in "
        "interpret-only environments (CPU CI)",
    )


def pytest_collection_modifyitems(config, items):
    if not INTERPRET_ONLY:
        return
    skip = pytest.mark.skip(
        reason="interpret-only environment (no TPU backend available)"
    )
    for item in items:
        if "tpu_only" in item.keywords:
            item.add_marker(skip)


@pytest.fixture
def interpret_only() -> bool:
    """True when Pallas kernels run with interpret=True in this environment."""
    return INTERPRET_ONLY
