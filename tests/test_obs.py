"""`repro.obs` suite: histograms, Prometheus text, traces, recorder.

Per the timing policy in tests/README.md: no wall-clock assertions —
histogram *structure* (cumulative buckets, exact merges, quantile
bracketing) and span *ordering/nesting* are the bars; the `mpx_per_s`
active-time estimator is tested with injected timestamps, never sleeps.
"""

import json
import math
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs import (
    DEFAULT_LATENCY_BOUNDS,
    Histogram,
    PromBuilder,
    Trace,
    base_family,
    empty_snapshot,
    escape_label_value,
    format_value,
    maybe_trace,
    mono_to_wall_us,
    parse_prom_text,
    unescape_label_value,
)
from repro.service.metrics import MetricsRecorder, bucket_labels


@pytest.fixture
def tracing():
    """Tracing on, a clean recorder, and full state restore afterwards."""
    obs.configure(enabled=True, dump_path=None)
    obs.recorder().clear()
    yield
    obs.configure(enabled=True, dump_path=None)
    obs.recorder().clear()


# ------------------------------------------------------------- histogram


def test_histogram_counts_sum_and_cumulative():
    h = Histogram((0.1, 1.0, 10.0))
    for v in (0.05, 0.1, 0.5, 1.0, 5.0, 100.0):
        h.observe(v)
    s = h.snapshot()
    # le-inclusive binning: 0.1 lands in the <=0.1 bucket, 1.0 in <=1.0
    assert s.counts == (2, 2, 1, 1)
    assert s.count == 6
    assert s.sum == pytest.approx(106.65)
    assert s.cumulative() == (2, 4, 5, 6)


def test_histogram_single_sample_p50_equals_p95():
    h = Histogram(DEFAULT_LATENCY_BOUNDS)
    h.observe(0.003)
    s = h.snapshot()
    assert s.quantile(0.50) == s.quantile(0.95) == 0.005


def test_histogram_quantile_is_upper_edge_and_bounds_bracket():
    h = Histogram((0.1, 1.0, 10.0))
    values = [0.05] * 50 + [5.0] * 50
    for v in values:
        h.observe(v)
    s = h.snapshot()
    # nearest-rank p50 = the 50th sample -> the <=0.1 bucket's upper edge
    assert s.quantile(0.50) == 0.1
    lo, hi = s.quantile_bounds(0.50)
    assert lo <= np.percentile(values, 50, method="inverted_cdf") <= hi
    lo, hi = s.quantile_bounds(0.95)
    assert (lo, hi) == (1.0, 10.0)
    assert lo <= np.percentile(values, 95, method="inverted_cdf") <= hi


def test_histogram_overflow_bucket_reports_finite_bounds():
    h = Histogram((0.1, 1.0))
    h.observe(50.0)
    s = h.snapshot()
    assert s.quantile_bounds(0.5) == (1.0, 1.0)
    assert math.isfinite(s.quantile(0.99))


def test_histogram_merge_is_exact_and_checks_bounds():
    a, b = Histogram((0.1, 1.0)), Histogram((0.1, 1.0))
    for v in (0.05, 0.5):
        a.observe(v)
    for v in (0.5, 5.0):
        b.observe(v)
    m = a.snapshot().merge(b.snapshot())
    assert m.counts == (1, 2, 1)
    assert m.count == 4
    assert m.sum == pytest.approx(6.05)
    with pytest.raises(ValueError):
        a.snapshot().merge(empty_snapshot((0.2, 2.0)))


def test_histogram_rejects_bad_bounds():
    for bad in ((), (1.0, 0.5), (1.0, 1.0)):
        with pytest.raises(ValueError):
            Histogram(bad)


def test_empty_snapshot_quantiles_are_zero():
    s = empty_snapshot((0.1, 1.0))
    assert s.quantile(0.5) == 0.0
    assert s.quantile_bounds(0.95) == (0.0, 0.0)


# ---------------------------------------------------------- prom text


def test_escape_label_value_roundtrip():
    for raw in ('plain', 'quo"te', 'back\\slash', 'new\nline',
                'all\\"of\nit', ''):
        esc = escape_label_value(raw)
        assert "\n" not in esc
        assert unescape_label_value(esc) == raw
    # escaping order: backslash first, so a literal \n survives as \\n
    assert escape_label_value("\\n") == "\\\\n"
    assert escape_label_value("\n") == "\\n"


def test_format_value_int_rendering():
    assert format_value(3) == "3"
    assert format_value(3.0) == "3"
    assert format_value(3.5) == "3.5"
    assert format_value(math.inf) == "+Inf"
    assert format_value(True) == "1"


def test_prombuilder_roundtrips_through_parser():
    h = Histogram((0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    b = PromBuilder()
    b.counter("t_requests_total", 7, "requests")
    b.gauge("t_depth", 2.5, labels=(("worker", 'w"0\n'),))
    b.histogram("t_latency_seconds",
                [((("side", "64"),), h.snapshot())], "latency")
    page = parse_prom_text(b.render())
    assert page.types == {"t_requests_total": "counter", "t_depth": "gauge",
                          "t_latency_seconds": "histogram"}
    assert page.get("t_requests_total") == 7
    # escaped label values come back as the original string
    assert page.get("t_depth", (("worker", 'w"0\n'),)) == 2.5
    buckets = page.series("t_latency_seconds_bucket")
    assert [dict(s.labels)["le"] for s in buckets] == ["0.1", "1", "+Inf"]
    assert [s.value for s in buckets] == [1, 2, 3]   # cumulative
    assert page.get("t_latency_seconds_count", (("side", "64"),)) == 3
    assert page.get("t_latency_seconds_sum",
                    (("side", "64"),)) == pytest.approx(5.55)


def test_parser_rejects_malformed_lines():
    for bad in ("no_value_here", "name{unclosed 1", 'name{a="x"y="z"} 1',
                "name notanumber"):
        with pytest.raises(ValueError):
            parse_prom_text(bad)
    # comments and blanks are fine
    page = parse_prom_text("# arbitrary comment\n\nok_total 1\n")
    assert page.get("ok_total") == 1


def test_base_family():
    assert base_family("x_seconds_bucket") == "x_seconds"
    assert base_family("x_seconds_sum") == "x_seconds"
    assert base_family("x_seconds_count") == "x_seconds"
    assert base_family("x_total") == "x_total"


# ----------------------------------------------------------------- trace


def test_trace_spans_nest_and_order(tracing):
    tr = Trace(process="test")
    with tr.span("outer"):
        with tr.span("inner"):
            pass
    tr.add("explicit", 1.0, 2.0, detail="x")
    spans = tr.spans()
    names = [s[0] for s in spans]
    # ctx managers record at __exit__, so inner lands before outer
    assert names == ["inner", "outer", "explicit"]
    (in_n, in_t0, in_t1, _), (out_n, out_t0, out_t1, _) = spans[0], spans[1]
    assert out_t0 <= in_t0 <= in_t1 <= out_t1     # proper nesting
    assert spans[2][3] == {"detail": "x"}


def test_trace_add_clamps_reversed_timestamps(tracing):
    tr = Trace()
    tr.add("weird", 5.0, 3.0)
    _, t0, t1, _ = tr.spans()[0]
    assert t1 == t0 == 5.0            # never a negative duration


def test_maybe_trace_disabled_returns_null(tracing):
    obs.configure(enabled=False)
    tr = maybe_trace("deadbeef")
    assert tr is obs.NULL_TRACE
    assert not tr.enabled
    tr.add("x", 0.0, 1.0)
    with tr.span("y"):
        pass
    tr.finish()
    assert obs.recorder().traces() == []
    obs.configure(enabled=True)
    assert maybe_trace("deadbeef").enabled


def test_trace_finish_records_once_and_empty_traces_never(tracing):
    tr = Trace()
    tr.add("s", 0.0, 1.0)
    tr.finish()
    tr.finish()
    assert len(obs.recorder().traces()) == 1
    empty = Trace()
    empty.finish()
    assert len(obs.recorder().traces()) == 1   # empty trace not recorded


def test_recorder_ring_capacity(tracing):
    obs.configure(capacity=4)
    try:
        ids = []
        for _ in range(10):
            tr = Trace()
            tr.add("s", 0.0, 1.0)
            tr.finish()
            ids.append(tr.trace_id)
        kept = [t.trace_id for t in obs.recorder().traces()]
        assert kept == ids[-4:]       # most recent N, in order
    finally:
        obs.configure(capacity=256)


def test_chrome_export_fields_and_valid_json(tracing):
    tr = Trace("feedc0de", process="worker")
    tr.add("engine.compute", 1.0, 1.5, rows=3)
    tr.finish()
    payload = json.loads(obs.recorder().to_chrome_json())
    events = [e for e in payload["traceEvents"]
              if e["args"].get("trace_id") == "feedc0de"]
    assert len(events) == 1
    e = events[0]
    assert e["name"] == "engine.compute"
    assert e["cat"] == "worker"
    assert e["ph"] == "X"
    assert e["dur"] == pytest.approx(0.5e6)     # us
    assert e["ts"] == pytest.approx(mono_to_wall_us(1.0))
    assert e["tid"] == "feedc0de"
    assert e["args"]["rows"] == "3"
    assert isinstance(e["pid"], int)


def test_auto_dump_writes_configured_path(tracing, tmp_path):
    path = str(tmp_path / "flight.json")
    obs.configure(dump_path=path)
    tr = Trace()
    tr.add("s", 0.0, 1.0)
    tr.finish()
    assert obs.auto_dump("test") == path
    with open(path) as fh:
        assert json.load(fh)["traceEvents"]
    # no dump path -> None, never raises
    obs.configure(dump_path=None)
    assert obs.auto_dump("test") is None


def test_concurrent_span_adds_are_safe(tracing):
    tr = Trace()

    def add_many(k):
        for i in range(200):
            tr.add(f"t{k}", float(i), float(i + 1))

    threads = [threading.Thread(target=add_many, args=(k,))
               for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr.spans()) == 800


# ------------------------------------------------- service metrics seam


def test_mpx_active_time_ignores_idle_gaps():
    """The satellite bar: two bursts separated by a long idle gap must
    report the same Mpx/s as one contiguous burst (the old wall-span
    estimator diluted the rate ~100x here). Timestamps injected — no
    sleeps."""
    px, lat = 1_000_000, 0.1

    def run(times):
        r = MetricsRecorder()
        for now in times:
            r.record_complete(lat, px, now=now)
        return r.snapshot(queue_depth=0, cache_hits=0, cache_misses=len(times),
                          backend="x").mpx_per_s

    one_burst = run([100.0, 100.1, 100.2, 100.3])
    two_bursts = run([100.0, 100.1, 150.2, 150.3])   # 50 s idle in between
    assert one_burst > 0
    assert two_bursts == pytest.approx(one_burst)


def test_mpx_dense_burst_not_overcounted():
    """Completions arriving closer together than their latency credit
    only the inter-arrival gap — active time can never exceed the span
    of the burst plus one latency."""
    r = MetricsRecorder()
    for i in range(100):
        r.record_complete(0.5, 1000, now=200.0 + i * 0.001)
    assert r._active_s == pytest.approx(0.5 + 99 * 0.001)


def test_latency_hist_count_equals_completed_minus_cached():
    r = MetricsRecorder()
    r.record_complete(0.01, 100, n_requests=3, bucket=(64, "uint8"))
    r.record_complete(0.02, 100, bucket=(128, "uint8"))
    r.record_cache_hit(100)
    m = r.snapshot(queue_depth=0, cache_hits=1, cache_misses=4, backend="x")
    assert m.completed == 5
    assert m.completed_from_cache == 1
    assert sum(s.count for _, s in m.latency_hists) == 4
    assert m.latency_hist().count == m.completed - m.completed_from_cache


def test_snapshot_percentiles_come_from_histogram():
    r = MetricsRecorder()
    lats = [0.001] * 90 + [0.2] * 10
    for lat in lats:
        r.record_complete(lat, 10, bucket=(64, "uint8"))
    m = r.snapshot(queue_depth=0, cache_hits=0, cache_misses=100,
                   backend="x")
    merged = m.latency_hist()
    assert m.p50_latency_ms == merged.quantile(0.50) * 1e3
    lo, hi = merged.quantile_bounds(0.50)
    assert lo * 1e3 <= np.percentile(lats, 50) * 1e3 <= m.p50_latency_ms
    lo95, hi95 = merged.quantile_bounds(0.95)
    assert lo95 <= np.percentile(lats, 95, method="inverted_cdf") <= hi95
    assert m.p95_latency_ms >= m.p50_latency_ms


def test_stage_histograms_and_bucket_labels():
    r = MetricsRecorder()
    r.observe_stage("queue_wait", (64, "uint8"), 0.004)
    r.observe_stage("queue_wait", (64, "uint8"), 0.006)
    r.observe_stage("compute", None, 0.1)
    m = r.snapshot(queue_depth=0, cache_hits=0, cache_misses=0, backend="x")
    by_labels = dict(m.stage_hists)
    qw = by_labels[(("stage", "queue_wait"), ("side", "64"),
                    ("dtype", "uint8"))]
    assert qw.count == 2
    assert by_labels[(("stage", "compute"),)].count == 1
    assert bucket_labels((64, "uint8")) == (("side", "64"),
                                            ("dtype", "uint8"))
    assert bucket_labels(None) == ()
    assert bucket_labels("odd") == (("bucket", "odd"),)
