"""Model zoo: forward/grad finiteness + decode==forward equivalence for every
mixer/channel family, plus scan-vs-unrolled equivalence."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import (
    count_params,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
)

BASE = dict(num_layers=2, d_model=32, num_heads=4, num_kv_heads=2, d_ff=64,
            vocab_size=97, activation_dtype="float32", param_dtype="float32",
            remat="none", attn_chunk=8)


def _check(cfg, seq=16, tol=3e-4):
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, seq), 0, cfg.vocab_size)
    logits, aux, _ = forward(params, cfg, tokens)
    assert bool(jnp.isfinite(logits).all()), "nonfinite logits"
    g = jax.grad(lambda p: loss_fn(p, cfg, tokens[:, :-1], tokens[:, 1:])[0])(params)
    gn = sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
             for x in jax.tree_util.tree_leaves(g))
    assert jnp.isfinite(gn), "bad grads"
    c = init_cache(cfg, 2, seq)
    outs = []
    for i in range(seq):
        lg, c = decode_step(params, cfg, c, tokens[:, i:i + 1], jnp.int32(i))
        outs.append(lg)
    err = float(jnp.max(jnp.abs(jnp.stack(outs, axis=1) - logits)))
    assert err < tol, f"decode err {err}"


def test_gqa_bias_qknorm_tied():
    _check(ModelConfig(name="t", family="dense", qkv_bias=True, qk_norm=True,
                       tie_embeddings=True, **BASE))


def test_chunked_attention_path():
    _check(ModelConfig(name="t", family="dense", **{**BASE, "attn_chunk": 4}))


def test_mla():
    _check(ModelConfig(name="t", family="dense",
                       layer_pattern=(LayerSpec("mla", "mlp"),),
                       q_lora_rank=16, kv_lora_rank=8, qk_rope_dim=4,
                       qk_nope_dim=8, v_head_dim=8, **BASE))


def test_moe_top2():
    _check(ModelConfig(name="t", family="moe",
                       layer_pattern=(LayerSpec("attn", "moe"),),
                       num_experts=4, experts_per_token=2,
                       moe_capacity_factor=8.0, **BASE))


def test_mamba():
    _check(ModelConfig(name="t", family="ssm",
                       layer_pattern=(LayerSpec("mamba", "mlp"),),
                       ssm_chunk=4, **BASE))


def test_rwkv6():
    _check(ModelConfig(name="t", family="ssm",
                       layer_pattern=(LayerSpec("rwkv", "rwkv_ffn"),),
                       rwkv_head_dim=8, rwkv_decay_lora=8, rwkv_mix_lora=4,
                       norm_type="layernorm", ssm_chunk=4, **BASE))


def test_jamba_style_hybrid():
    pat = (LayerSpec("mamba", "mlp"), LayerSpec("mamba", "moe"),
           LayerSpec("attn", "mlp"), LayerSpec("mamba", "moe"))
    _check(ModelConfig(name="t", family="hybrid", layer_pattern=pat,
                       num_experts=4, experts_per_token=2,
                       moe_capacity_factor=8.0, ssm_chunk=4,
                       **{**BASE, "num_layers": 4}))


def test_parallel_block_layernorm_sinusoidal():
    _check(ModelConfig(name="t", family="dense", parallel_block=True,
                       norm_type="layernorm", mlp_act="gelu",
                       pos_embed="sinusoidal", **{**BASE, "num_kv_heads": 4}))


def test_llama4_style_shared_expert_top1():
    _check(ModelConfig(name="llama4-t", family="moe",
                       layer_pattern=(LayerSpec("attn", "mlp"),
                                      LayerSpec("attn", "moe")),
                       num_experts=4, experts_per_token=1,
                       moe_capacity_factor=8.0, **BASE))


def test_scan_vs_unrolled_identical():
    cfg_s = ModelConfig(name="t", family="dense", **{**BASE, "num_layers": 4})
    cfg_u = cfg_s.scaled(scan_layers=False)
    params = init_params(cfg_s, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 97)
    a, _, _ = forward(params, cfg_s, tokens)
    b, _, _ = forward(params, cfg_u, tokens)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5


def test_frontend_embeds_prefix():
    cfg = ModelConfig(name="t", family="vlm", frontend="vision",
                      frontend_tokens=4, **BASE)
    params = init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 97)
    fe = jax.random.normal(jax.random.PRNGKey(2), (2, 4, cfg.d_model))
    a, _, _ = forward(params, cfg, tokens, frontend_embeds=fe)
    b, _, _ = forward(params, cfg, tokens)
    # prefix positions differ, suffix-only change propagates causally
    assert bool(jnp.any(jnp.abs(a - b) > 1e-6))
    assert a.shape == b.shape


def test_remat_matches_no_remat():
    cfg_n = ModelConfig(name="t", family="dense", **BASE)
    cfg_r = cfg_n.scaled(remat="full")
    params = init_params(cfg_n, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 97)
    gn = jax.grad(lambda p: loss_fn(p, cfg_n, tokens[:, :-1], tokens[:, 1:])[0])(params)
    gr = jax.grad(lambda p: loss_fn(p, cfg_r, tokens[:, :-1], tokens[:, 1:])[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(gn), jax.tree_util.tree_leaves(gr)):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-5


def test_int8_weight_quant_decode():
    """§Perf serve path: int8 weights track bf16 logits closely."""
    cfg = ModelConfig(name="t", family="dense", **{**BASE, "num_layers": 4})
    cfg_q = cfg.scaled(weight_quant="int8")
    params = init_params(cfg, jax.random.PRNGKey(0))
    params_q = init_params(cfg_q, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 97)
    c1, c2 = init_cache(cfg, 2, 8), init_cache(cfg_q, 2, 8)
    a, b = [], []
    for i in range(8):
        l1, c1 = decode_step(params, cfg, c1, tok[:, i:i + 1], jnp.int32(i))
        l2, c2 = decode_step(params_q, cfg_q, c2, tok[:, i:i + 1], jnp.int32(i))
        a.append(l1)
        b.append(l2)
    a, b = jnp.stack(a), jnp.stack(b)
    cos = float(jnp.sum(a * b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b)))
    assert cos > 0.99 and bool(jnp.isfinite(b).all())
