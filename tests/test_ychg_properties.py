"""Hypothesis property tests on the yCHG invariants (paper §1-2)."""

import numpy as np
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

import jax.numpy as jnp

from repro.core import regions, serial, ychg

masks = hnp.arrays(
    dtype=np.uint8,
    shape=st.tuples(st.integers(1, 40), st.integers(1, 40)),
    elements=st.integers(0, 1),
)


@given(masks)
@settings(max_examples=60, deadline=None)
def test_parallel_equals_serial_scalar(img):
    """The paper's claim of correctness: parallel == serial, exactly."""
    got = np.asarray(ychg.column_runs(jnp.asarray(img)))
    want = serial.column_runs_scalar(img)
    np.testing.assert_array_equal(got, want)


@given(masks)
@settings(max_examples=60, deadline=None)
def test_conservation(img):
    """births - deaths telescopes to the last column's run count."""
    s = ychg.analyze(jnp.asarray(img))
    assert bool(ychg.check_conservation(s))


@given(masks)
@settings(max_examples=40, deadline=None)
def test_hyperedge_count_invariant_under_horizontal_flip(img):
    a = int(ychg.hyperedge_count(jnp.asarray(img)))
    b = int(ychg.hyperedge_count(jnp.asarray(img[:, ::-1].copy())))
    assert a == b


@given(masks)
@settings(max_examples=40, deadline=None)
def test_runs_invariant_under_vertical_flip(img):
    """Reversing each column preserves its maximal-run count."""
    a = np.asarray(ychg.column_runs(jnp.asarray(img)))
    b = np.asarray(ychg.column_runs(jnp.asarray(img[::-1, :].copy())))
    np.testing.assert_array_equal(a, b)


@given(masks)
@settings(max_examples=40, deadline=None)
def test_row_duplication_preserves_runs(img):
    """Doubling image height by repeating rows keeps run counts (y-convexity
    is about connectivity, not thickness)."""
    a = np.asarray(ychg.column_runs(jnp.asarray(img)))
    b = np.asarray(ychg.column_runs(jnp.asarray(np.repeat(img, 2, axis=0))))
    np.testing.assert_array_equal(a, b)


@given(masks)
@settings(max_examples=40, deadline=None)
def test_blank_column_padding(img):
    """Appending background columns adds no runs and no hyperedges."""
    padded = np.pad(img, ((0, 0), (0, 3)))
    a = int(ychg.hyperedge_count(jnp.asarray(img)))
    b = int(ychg.hyperedge_count(jnp.asarray(padded)))
    assert a == b


@given(masks)
@settings(max_examples=40, deadline=None)
def test_runs_bounded_by_half_height(img):
    runs = np.asarray(ychg.column_runs(jnp.asarray(img)))
    h = img.shape[0]
    assert (runs >= 0).all() and (runs <= (h + 1) // 2).all()


@given(masks)
@settings(max_examples=30, deadline=None)
def test_materialized_decomposition_is_valid(img):
    """regions.decompose: (a) covers the ROI exactly, (b) each hyperedge is
    y-convex (<= 1 run per column), (c) count >= the poster's count signal."""
    labels, n = regions.label_image(img)
    np.testing.assert_array_equal(labels > 0, img != 0)
    for e in regions.decompose(img):
        cols = [r.col for r in e.runs]
        assert len(cols) == len(set(cols))          # y-convex
        assert cols == list(range(cols[0], cols[-1] + 1))  # consecutive
    count_model = int(ychg.hyperedge_count(jnp.asarray(img)))
    assert n >= count_model


@given(masks)
@settings(max_examples=30, deadline=None)
def test_area_estimation(img):
    """ref [3]'s application: area via decomposition == pixel count."""
    assert regions.total_area(img) == int((img != 0).sum())


@given(st.integers(0, 200))
@settings(max_examples=20, deadline=None)
def test_striped_generator_exact(n):
    from repro.data import modis

    img = modis.striped(64, n) if n <= 900 else None
    if img is not None:
        assert int(ychg.hyperedge_count(jnp.asarray(img))) == n
